"""MoE dispatch as a block-sparse SpMM through the Pallas kernel, served via
the COGNATE autotune cache — the paper's technique driving a real kernel
inside the LM stack, on the O(nnz) fast path.

The token->expert dispatch pattern is built directly in BSR block
coordinates: with d_model == 128 (the BSR lane width) every (token, routed
expert) pair is exactly one (block_m x 128) block column, so we never
materialize the dense (T, E*D) dispatch matrix and never loop over tokens in
Python.  A multi-batch serving loop drives ``KernelAutotuner.get``: routing
patterns repeat across batches (steady-state serving), so after the first
sighting a pattern's featurization, tile config, and BSR construction plan
all come from the pattern-keyed LRU cache and each request pays only one
O(nnz) value scatter + the kernel launch.

Run:  PYTHONPATH=src python examples/moe_kernel_serving.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.autotune import KernelAutotuner
from repro.data.matrices import SparseMatrix
from repro.kernels import bsr_from_blocks, spmm, spmm_ref


def route(rng, T, E, K):
    """Top-K expert assignment per token: (T, K) expert ids."""
    logits = rng.normal(size=(T, E))
    return np.argsort(-logits, axis=1)[:, :K]


def dispatch_pattern(topk, T, E, D):
    """Element-level COO of the (T, E*D) dispatch pattern, fully vectorized.

    Row t has nonzeros in columns [e*D, (e+1)*D) for each routed expert e.
    Column-sorted within each token row, matching SparseMatrix's invariant.
    """
    K = topk.shape[1]
    experts = np.sort(topk, axis=1)                     # (T, K) ascending
    rows = np.repeat(np.arange(T, dtype=np.int32), K * D)
    cols = (experts[:, :, None] * D +
            np.arange(D, dtype=np.int64)).reshape(-1).astype(np.int32)
    return SparseMatrix("dispatch", "moe", T, E * D, rows, cols)


def build_dispatch_bsr(topk, x, block_m, T, E, D):
    """BSR of the dispatch matrix straight from block coordinates.

    One (block_m x D) block per (token-tile, expert) pair that any token in
    the tile routes to; token t's activation lands in row t % block_m.
    """
    K = topk.shape[1]
    pairs_t = np.repeat(np.arange(T, dtype=np.int64), K)    # (T*K,)
    pairs_e = topk.reshape(-1).astype(np.int64)
    bkey = (pairs_t // block_m) * E + pairs_e
    ublocks, inv = np.unique(bkey, return_inverse=True)
    blocks = np.zeros((ublocks.size, block_m, D), np.float32)
    blocks[inv, pairs_t % block_m, :] = x[pairs_t]
    n_blockrows = (T + block_m - 1) // block_m
    return bsr_from_blocks(ublocks // E, ublocks % E, blocks,
                           n_blockrows=n_blockrows, n_blockcols=E)


def main():
    rng = np.random.default_rng(0)
    T, D, E, K = 256, 128, 4, 2          # tokens, d_model(=BK), experts, top-k
    F = 64                               # expert output width
    n_batches, n_routing_patterns = 8, 3  # patterns repeat across batches

    # expert weights stacked on the contraction axis: (E*D, F)
    w = rng.normal(size=(E * D, F)).astype(np.float32) * 0.1
    w_dev = jnp.asarray(w)
    w_gathered = w.reshape(E, D, F)       # for the dense cross-check

    tuner = KernelAutotuner()
    routings = [route(np.random.default_rng(100 + i), T, E, K)
                for i in range(n_routing_patterns)]

    for step in range(n_batches):
        topk = routings[step % n_routing_patterns]
        x = rng.normal(size=(T, D)).astype(np.float32)

        # featurize-or-hit: config + BSR plan from the pattern-keyed cache
        mat = dispatch_pattern(topk, T, E, D)
        t0 = time.perf_counter()
        entry = tuner.get(mat, op="spmm")
        cfg = entry.config
        # per-batch work: scatter this batch's activations through the plan.
        # plan entries follow mat's (row-major, column-sorted) element order,
        # where token t's K routed blocks each carry x[t] — so the aligned
        # values array is x tiled K times per token.
        values = np.repeat(x, K, axis=0).reshape(-1)
        a = entry.build(values)
        t_build = time.perf_counter() - t0

        out = np.asarray(spmm(a, w_dev, block_n=cfg["block_n"],
                              n_major=cfg["n_major"]))
        want = np.asarray(spmm_ref(a, w_dev))
        err = np.abs(out - want).max()

        # dense cross-check without a (T, E*D) intermediate: gather each
        # token's routed expert weights and contract directly.
        dense_out = np.einsum("td,tkdf->tf", x, w_gathered[topk])
        err2 = np.abs(out[:T] - dense_out).max()
        hit = "hit " if entry.hits > 0 else "miss"
        print(f"batch {step}: pattern={entry.digest[:8]} cache={hit} "
              f"bm={cfg['block_m']} nnzb={a.nnzb} "
              f"build={t_build * 1e3:.2f}ms maxerr={err:.2e}/{err2:.2e}")
        assert err < 1e-4 and err2 < 1e-3

        # the block-coordinate constructor produces the identical BsrMatrix
        b = build_dispatch_bsr(topk, x, cfg["block_m"], T, E, D)
        assert np.array_equal(np.asarray(a.data), np.asarray(b.data))
        assert np.array_equal(np.asarray(a.rowids), np.asarray(b.rowids))
        assert np.array_equal(np.asarray(a.colids), np.asarray(b.colids))

    c = tuner.cache
    print(f"served {n_batches} batches from {c.misses} featurizations "
          f"({c.hits} cache hits, {len(c)} patterns resident)")
    assert c.misses == n_routing_patterns
    assert c.hits == n_batches - n_routing_patterns
    assert tuner.featurize_calls == n_routing_patterns
    print("MoE-dispatch-through-Pallas OK")


if __name__ == "__main__":
    main()
