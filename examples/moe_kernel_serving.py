"""MoE dispatch served through ``repro.serving.SparseKernelEngine`` — the
COGNATE deployment loop as a batched, double-buffered, warm-startable,
*multi-backend* serving runtime driving a real Pallas kernel.

The token->expert dispatch pattern is built directly in element COO (with
d_model == 128, the BSR lane width, every (token, routed expert) pair is one
(block_m x 128) block column).  The script walks the engine through its
whole surface:

1. **Cold serving** — each ``step`` serves a micro-batch of dispatch
   requests; routing patterns repeat across steps, so after first sighting,
   a pattern's featurization, tile config, and BSR construction plan all
   come from the pattern-keyed LRU.  Misses within a step are scored in ONE
   batched cost-model dispatch, and each request's value scatter lands in a
   double-buffered plan arena slot so the next batch's host-side build can
   overlap this batch's in-flight kernel.
2. **Shadow verification on a second backend** — the same requests are
   re-routed to the ``cpu_ref`` backend (the pure-jnp oracle) through the
   *same engine* via ``KernelRequest(..., platform="cpu_ref")``; outputs
   must match the Pallas backend's, and the per-backend section of
   ``stats()`` shows both tags with independent caches.
3. **Warm restart** — the engine persists every backend's cache to one
   namespaced file and restarts from it: the warm-started engine serves the
   same traffic with ZERO featurizations on every backend.  A dispatch
   whose activations are already device-resident (``jax.Array`` — the
   residency MoE router outputs naturally have) then takes the *device
   build path*: block data is assembled by one jitted on-device scatter,
   zero host numpy in the warm loop (``stats()["build_paths"]``).
4. **Routed serving** — a second engine gets a routing policy instead of
   explicit tags: ``CostModelRouter`` scores each untagged dispatch pattern
   against every candidate backend's config space in ONE batched dispatch
   and places it on the argmin (latency-calibrated) predicted cost, while a
   ``LoadAwareRouter`` wrapper spills to ``cpu_ref`` whenever the chosen
   backend's in-flight depth saturates — outputs stay verified against the
   dense reference whichever backend each request lands on.

Run:  PYTHONPATH=src python examples/moe_kernel_serving.py
"""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.autotune import Autotuner, KernelAutotuner
from repro.core.cognate import CostModelConfig, init_cost_model
from repro.core.latent import zero_codec
from repro.data.matrices import SparseMatrix
from repro.serving import (CostModelRouter, KernelRequest, LoadAwareRouter,
                           SparseKernelEngine)


def route(rng, T, E, K):
    """Top-K expert assignment per token: (T, K) expert ids."""
    logits = rng.normal(size=(T, E))
    return np.argsort(-logits, axis=1)[:, :K]


def dispatch_pattern(topk, T, E, D):
    """Element-level COO of the (T, E*D) dispatch pattern, fully vectorized.

    Row t has nonzeros in columns [e*D, (e+1)*D) for each routed expert e.
    Column-sorted within each token row, matching SparseMatrix's invariant.
    """
    K = topk.shape[1]
    experts = np.sort(topk, axis=1)                     # (T, K) ascending
    rows = np.repeat(np.arange(T, dtype=np.int32), K * D)
    cols = (experts[:, :, None] * D +
            np.arange(D, dtype=np.int64)).reshape(-1).astype(np.int32)
    return SparseMatrix("dispatch", "moe", T, E * D, rows, cols)


def make_request(topk, x, T, E, D, K, w_dev):
    """One engine request: the routing pattern + this batch's activations.

    Plan entries follow the pattern's (row-major, column-sorted) element
    order, where token t's K routed blocks each carry x[t] — so the aligned
    values array is x tiled K times per token.
    """
    mat = dispatch_pattern(topk, T, E, D)
    values = np.repeat(x, K, axis=0).reshape(-1)
    return mat, KernelRequest(mat, values, "spmm", w_dev)


def main():
    rng = np.random.default_rng(0)
    T, D, E, K = 256, 128, 4, 2          # tokens, d_model(=BK), experts, top-k
    F = 64                               # expert output width
    n_steps, reqs_per_step = 6, 2        # micro-batched serving traffic
    n_routing_patterns = 3               # patterns repeat across requests

    # expert weights stacked on the contraction axis: (E*D, F)
    w = rng.normal(size=(E * D, F)).astype(np.float32) * 0.1
    w_dev = jnp.asarray(w)
    w_gathered = w.reshape(E, D, F)       # for the dense cross-check

    cache_path = os.path.join(tempfile.mkdtemp(prefix="moe_serving_"),
                              "autotune_cache.npz")
    engine = SparseKernelEngine(persist_path=cache_path)
    routings = [route(np.random.default_rng(100 + i), T, E, K)
                for i in range(n_routing_patterns)]

    def serve(engine, label):
        req_i = 0
        for step in range(n_steps):
            batch, xs, topks = [], [], []
            for _ in range(reqs_per_step):
                topk = routings[req_i % n_routing_patterns]
                x = rng.normal(size=(T, D)).astype(np.float32)
                _, req = make_request(topk, x, T, E, D, K, w_dev)
                batch.append(req)
                xs.append(x)
                topks.append(topk)
                req_i += 1
            responses = engine.step(batch)
            for resp, x, topk in zip(responses, xs, topks):
                out = np.asarray(resp.output)
                # dense cross-check without a (T, E*D) intermediate: gather
                # each token's routed expert weights and contract directly.
                want = np.einsum("td,tkdf->tf", x, w_gathered[topk])
                err = np.abs(out[:T] - want).max()
                assert err < 1e-3, err
            marks = "".join("H" if r.cache_hit else "M" for r in responses)
            cfg = responses[0].config
            print(f"{label} step {step}: [{marks}] bm={cfg['block_m']} "
                  f"nnzb={responses[0].matrix.nnzb} "
                  f"arena={'/'.join('y' if r.arena_slot else 'n' for r in responses)}")
        engine.flush()

    serve(engine, "cold")
    s = engine.stats()
    print(f"cold engine: {s['requests']} requests, hit_rate="
          f"{s['hit_rate']:.2f}, featurize_calls={s['featurize_calls']}, "
          f"score_dispatches={s['score_dispatches']}, "
          f"step p50={s['stages']['step']['p50_ms']:.2f}ms "
          f"p99={s['stages']['step']['p99_ms']:.2f}ms")
    assert s["featurize_calls"] == n_routing_patterns
    assert s["misses"] == n_routing_patterns
    assert s["hits"] == n_steps * reqs_per_step - n_routing_patterns

    # shadow-verify on a second backend through the SAME engine: route each
    # routing pattern to the pure-jnp reference (platform="cpu_ref") and
    # compare against the Pallas backend's output.  cpu_ref keeps its own
    # pattern cache, so these are fresh (heuristic) tunings, not hits.
    x = rng.normal(size=(T, D)).astype(np.float32)
    for topk in routings:
        _, pallas_req = make_request(topk, x, T, E, D, K, w_dev)
        shadow_req = KernelRequest(pallas_req.mat, pallas_req.values,
                                   "spmm", w_dev, platform="cpu_ref")
        pallas_out, ref_out = (np.asarray(r.output)
                               for r in engine.step([pallas_req, shadow_req]))
        err = np.abs(pallas_out[:T] - ref_out[:T]).max()
        assert err < 1e-3, err
    engine.flush()
    s = engine.stats()
    per_backend = {tag: b["requests"] for tag, b in s["backends"].items()}
    print(f"shadow verify: per-backend requests {per_backend}")
    assert per_backend["cpu_ref/spmm"] == n_routing_patterns
    assert s["featurize_calls"] == 2 * n_routing_patterns  # one per backend
    engine.save()

    # restart: a warm-started engine re-serves known traffic with zero
    # featurizations — the persisted, backend-namespaced (digest -> config +
    # plan) map replaces re-tuning entirely, for BOTH backends' caches.
    engine2 = SparseKernelEngine(persist_path=cache_path)
    serve(engine2, "warm")
    s2 = engine2.stats()
    print(f"warm engine: warm_start_entries={s2['warm_start_entries']}, "
          f"featurize_calls={s2['featurize_calls']}, "
          f"hit_rate={s2['hit_rate']:.2f}")
    assert s2["warm_start_entries"] == 2 * n_routing_patterns  # both backends
    assert s2["featurize_calls"] == 0
    assert s2["misses"] == 0

    # device-resident dispatch: hand the engine the values as a jax array
    # (MoE router outputs live on device anyway) and the build stage takes
    # the jitted device-scatter path — no host numpy touches the warm loop,
    # and the async dispatch overlaps any in-flight kernels.
    x = rng.normal(size=(T, D)).astype(np.float32)
    topk = routings[0]
    _, req = make_request(topk, x, T, E, D, K, w_dev)
    resp = engine2.step([KernelRequest(req.mat, jnp.asarray(req.values),
                                       "spmm", w_dev)])[0]
    assert resp.device_built and resp.cache_hit
    want = np.einsum("td,tkdf->tf", x, w_gathered[topk])
    assert np.abs(np.asarray(resp.output)[:T] - want).max() < 1e-3
    engine2.drain()                     # force completion, release leases
    bp = engine2.stats()["build_paths"]
    print(f"device build path: device={bp['device']} host={bp['host']} "
          f"drain_waits={bp['drain_waits']}")
    assert bp["device"] == 1

    # routed serving: drop the explicit tags and let the engine place each
    # request.  A (randomly initialized — placement mechanics, not accuracy)
    # learned cost model scores every untagged pattern against all candidate
    # backends in one batched dispatch per step; the load-aware wrapper
    # spills to cpu_ref whenever the chosen backend still has a full
    # double-buffered batch in flight.
    cm_cfg = CostModelConfig(ch_scale=0.125)
    scorer = Autotuner("tpu_pallas", "spmm",
                       init_cost_model(jax.random.PRNGKey(0), cm_cfg),
                       cm_cfg, zero_codec(), resolution=8)
    # max_inflight=1 guarantees visible spilling: a repeated pattern's
    # sticky platform still has the previous step's double-buffered batch
    # outstanding when the next step routes, so overflow must shed
    router = LoadAwareRouter(CostModelRouter(), max_inflight=1)
    routed = SparseKernelEngine(KernelAutotuner(scorer), router=router)
    req_i = 0
    for step in range(n_steps):
        batch, xs, topks = [], [], []
        for _ in range(reqs_per_step):
            topk = routings[req_i % n_routing_patterns]
            x = rng.normal(size=(T, D)).astype(np.float32)
            _, req = make_request(topk, x, T, E, D, K, w_dev)
            batch.append(req)
            xs.append(x)
            topks.append(topk)
            req_i += 1
        responses = routed.step(batch)
        for resp, x, topk in zip(responses, xs, topks):
            want = np.einsum("td,tkdf->tf", x, w_gathered[topk])
            err = np.abs(np.asarray(resp.output)[:T] - want).max()
            assert err < 1e-3, err          # correct wherever it ran
        marks = " ".join(f"{r.platform}({r.route_reason[0]})"
                         for r in responses)
        print(f"routed step {step}: {marks}")
    routed.release_stream()
    sr = routed.stats()
    print(f"routed engine: decisions={sr['routing']['decisions']} "
          f"shares={sr['routing']['by_platform']} "
          f"spills={sr['routing']['spills']} "
          f"route_dispatches={router.inner.dispatches}")
    assert sr["routing"]["spills"] > 0          # saturation demonstrably shed
    # every unseen pattern was scored in one multi-space dispatch per step
    assert router.inner.dispatches <= n_routing_patterns
    print("MoE-dispatch-through-serving-engine OK")


if __name__ == "__main__":
    main()
