"""MoE dispatch as a block-sparse SpMM through the Pallas kernel, with tile
configuration selected by the COGNATE KernelAutotuner — the paper's technique
driving a real kernel inside the LM stack.

For a batch of routed tokens we build the (tokens x experts*d_ff-block)
block-sparse dispatch pattern, let the autotuner pick block_m from the
pattern's fill curve, run the Pallas BSR SpMM in interpret mode, and check it
against the dense einsum the distributed model uses.

Run:  PYTHONPATH=src python examples/moe_kernel_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.autotune import KernelAutotuner
from repro.data.matrices import SparseMatrix
from repro.kernels import bsr_from_dense, spmm, spmm_ref


def main():
    rng = np.random.default_rng(0)
    T, D, E, K = 256, 128, 4, 2          # tokens, d_model, experts, top-k

    # router: top-k expert assignment per token
    logits = rng.normal(size=(T, E))
    topk = np.argsort(-logits, axis=1)[:, :K]

    # block-sparse token->expert dispatch matrix (T x E*D): token row t has
    # nonzero D-blocks only at its routed experts
    dispatch = np.zeros((T, E * D), np.float32)
    x = rng.normal(size=(T, D)).astype(np.float32)
    for t in range(T):
        for e in topk[t]:
            dispatch[t, e * D:(e + 1) * D] = x[t]

    # featurize the dispatch pattern and pick kernel tiles
    rows, cols = np.nonzero(dispatch)
    mat = SparseMatrix("dispatch", "moe", T, E * D,
                       rows.astype(np.int32), cols.astype(np.int32))
    cfg = KernelAutotuner.heuristic(mat)
    print(f"pattern: {T}x{E * D}, nnz={mat.nnz}; autotuner chose {cfg}")

    # expert weights stacked on the contraction axis: (E*D, F)
    F = 64
    w = rng.normal(size=(E * D, F)).astype(np.float32) * 0.1

    a = bsr_from_dense(dispatch, block_m=cfg["block_m"])
    out = np.asarray(spmm(a, jnp.asarray(w), block_n=cfg["block_n"],
                          n_major=cfg["n_major"]))
    want = np.asarray(spmm_ref(a, jnp.asarray(w)))
    err = np.abs(out - want).max()
    print(f"Pallas BSR SpMM vs oracle: maxerr={err:.2e}")

    # cross-check against the dense formulation
    dense_out = dispatch @ w
    err2 = np.abs(out[:T] - dense_out).max()
    print(f"vs dense dispatch einsum:  maxerr={err2:.2e}")
    assert err < 1e-4 and err2 < 1e-3
    print("MoE-dispatch-through-Pallas OK")


if __name__ == "__main__":
    main()
