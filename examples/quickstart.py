"""Quickstart: the COGNATE pipeline end-to-end in ~2 minutes on CPU.

1. synthesize a SuiteSparse-like matrix suite,
2. collect cheap source labels (CPU platform model) + few-shot target labels
   (SPADE platform model, 5 matrices),
3. pre-train the cost model on CPU, train the SPADE autoencoder
   (unsupervised), few-shot fine-tune,
4. evaluate top-1/top-5 speedups vs the SPADE default configuration,
5. use the Autotuner to pick a configuration for a fresh matrix.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CostModelConfig, evaluate, finetune_target,
                        pretrain_source)
from repro.core.autotune import Autotuner
from repro.data import CostMeter, collect_dataset, generate_matrix, split_suite
from repro.hw import get_platform

RES = 32     # density-pyramid resolution (paper analogue: 256)

def main():
    train, evl = split_suite(20, 10, seed=0)
    cpu, spade = get_platform("cpu"), get_platform("spade")
    meter = CostMeter()

    print("== collecting labels (CPU cheap, SPADE expensive) ==")
    src = collect_dataset(cpu, train, "spmm", 40, seed=1, resolution=RES,
                          meter=meter)
    cpu_units = meter.units
    tgt = collect_dataset(spade, train[:5], "spmm", 40, seed=2, resolution=RES,
                          meter=meter)
    print(f"DCE: CPU={cpu_units:.0f} units, SPADE={meter.units - cpu_units:.0f}"
          f" units (beta_SPADE=1000)")

    print("== pre-training on CPU ==")
    cfg = CostModelConfig(ch_scale=0.25)
    pre = pretrain_source(cfg, src, epochs=8, ae_epochs=60)
    print(f"   final ranking loss {pre.history['loss'][-1]:.3f}")

    print("== few-shot fine-tuning on SPADE (5 matrices) ==")
    ft = finetune_target(pre, tgt, epochs=20, ae_epochs=60)

    print("== evaluating on unseen matrices ==")
    ev = collect_dataset(spade, evl, "spmm", 0, seed=3, resolution=RES)
    m = evaluate(ft, ev)
    print(f"top-1 geomean speedup {m['top1_geomean']:.2f} | top-5 "
          f"{m['top5_geomean']:.2f} | optimal {m['optimal_geomean']:.2f} "
          f"| OPA {m['opa']:.2f}")

    print("== autotuning a fresh matrix ==")
    tuner = Autotuner("spade", "spmm", ft.params, ft.model_cfg, ft.codec,
                      resolution=RES)
    mat = generate_matrix("powerlaw", seed=999)
    choice = tuner.tune(mat, k=5)
    print(f"matrix {mat.name} ({mat.n_rows}x{mat.n_cols}, nnz={mat.nnz}) -> "
          f"{choice}")
    assert m["top1_geomean"] > 0.9, "fine-tuned model should beat ~baseline"


if __name__ == "__main__":
    main()
