"""End-to-end LM training driver example: train a reduced yi-9b-family model
for a few hundred steps on the host mesh with checkpoints + elastic resume.

This is a thin veneer over the production launcher (repro.launch.train); on a
real slice you drop --reduced and point --ckpt-dir at durable storage.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="yi-9b")
    args = ap.parse_args()
    final_loss = train_mod.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "25",
        "--resume",
    ])
    print(f"final loss: {final_loss:.4f}")
    if final_loss > 6.3:
        print("warning: loss did not drop below init (~6.24 for vocab 512)")
        sys.exit(1)


if __name__ == "__main__":
    main()
