"""Few-shot fine-tuning scenario (the paper's deployment story): an emerging
accelerator (SPADE) exists only as a slow simulator; we can afford labels
from FIVE matrices. Compare:

  zero-shot  — CPU-pretrained model applied directly,
  no-transfer — train from scratch on the 5 matrices,
  COGNATE    — CPU-pretrain + unsupervised AE + few-shot fine-tune,

and report speedup + the metered data-collection expense of each.

Run:  PYTHONPATH=src python examples/finetune_spade.py
"""
from repro.core import (CostModelConfig, evaluate, finetune_target,
                        pretrain_source, train_scratch, zero_shot)
from repro.data import CostMeter, collect_dataset, split_suite
from repro.hw import get_platform

RES = 32

def main():
    train, evl = split_suite(20, 10, seed=1)
    cpu, spade = get_platform("cpu"), get_platform("spade")

    meter_cpu, meter_spade = CostMeter(), CostMeter()
    src = collect_dataset(cpu, train, "spmm", 40, seed=1, resolution=RES,
                          meter=meter_cpu)
    tgt = collect_dataset(spade, train[:5], "spmm", 40, seed=2, resolution=RES,
                          meter=meter_spade)
    ev = collect_dataset(spade, evl, "spmm", 0, seed=3, resolution=RES)

    cfg = CostModelConfig(ch_scale=0.25)
    pre = pretrain_source(cfg, src, epochs=8, ae_epochs=60)

    results = {
        "zero-shot": (evaluate(zero_shot(pre, tgt, ae_epochs=60), ev),
                      meter_cpu.units),
        "no-transfer": (evaluate(train_scratch(cfg, tgt, epochs=20,
                                               ae_epochs=60), ev),
                        meter_spade.units),
        "COGNATE": (evaluate(finetune_target(pre, tgt, epochs=20,
                                             ae_epochs=60), ev),
                    meter_cpu.units + meter_spade.units),
    }
    print(f"{'method':12s} {'top1':>6s} {'top5':>6s} {'OPA':>6s} {'DCE':>10s}")
    for name, (m, dce) in results.items():
        print(f"{name:12s} {m['top1_geomean']:6.2f} {m['top5_geomean']:6.2f} "
              f"{m['opa']:6.2f} {dce:10.0f}")
    print(f"{'optimal':12s} {results['COGNATE'][0]['optimal_geomean']:6.2f}")


if __name__ == "__main__":
    main()
