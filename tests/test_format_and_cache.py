"""Equivalence tests for the vectorized BSR fast path + autotune cache.

The vectorized ``bsr_from_coo``/``bsr_from_dense`` must produce bit-identical
``(data, rowids, colids)`` to the seed dense-roundtrip implementation
(reproduced verbatim below as the oracle), including empty block-rows,
duplicate COO entries (last-write-wins), explicit zero values, and shapes
that are not multiples of the block size.  Cached autotune results must match
uncached ones and must not re-featurize on a hit.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _compat import given, settings, st

from repro.core.autotune import (AutotuneCache, KernelAutotuner,
                                 matrix_digest, pattern_digest)
from repro.data import generate_matrix
from repro.data.matrices import SparseMatrix
from repro.kernels.format import (_dense_roundtrip_reference, bsr_from_blocks,
                                  bsr_from_coo, bsr_from_dense, plan_from_coo)


def _assert_matches_oracle(bsr, dense, block_m):
    data, rowids, colids, nbr, nbc = _dense_roundtrip_reference(dense, block_m)
    np.testing.assert_array_equal(np.asarray(bsr.data), data)
    np.testing.assert_array_equal(np.asarray(bsr.rowids), rowids)
    np.testing.assert_array_equal(np.asarray(bsr.colids), colids)
    assert (bsr.n_blockrows, bsr.n_blockcols) == (nbr, nbc)


# ----------------------------------------------------------- equivalence

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16),
       m=st.integers(1, 300), k=st.integers(1, 500),
       block_m=st.sampled_from([8, 16, 32, 64]),
       nnz=st.integers(0, 2000))
def test_coo_equivalence_property(seed, m, k, block_m, nnz):
    """Random COO (duplicates + explicit zeros + ragged shapes) matches the
    dense-roundtrip oracle bit for bit."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, k, nnz)
    values = rng.normal(size=nnz).astype(np.float32)
    values[rng.random(nnz) < 0.15] = 0.0
    dense = np.zeros((m, k), np.float32)
    dense[rows, cols] = values
    a = bsr_from_coo(rows, cols, values, (m, k), block_m=block_m)
    _assert_matches_oracle(a, dense, block_m)
    b = bsr_from_dense(dense, block_m=block_m)
    _assert_matches_oracle(b, dense, block_m)


def test_empty_rows_get_pad_blocks():
    rows = np.array([2, 3])
    cols = np.array([0, 400])
    a = bsr_from_coo(rows, cols, np.ones(2, np.float32), (200, 512),
                     block_m=32)
    # 7 block-rows (200 -> 224 padded), all represented
    assert a.n_blockrows == 7
    assert set(np.asarray(a.rowids).tolist()) == set(range(7))
    dense = np.zeros((200, 512), np.float32)
    dense[rows, cols] = 1.0
    _assert_matches_oracle(a, dense, 32)


def test_duplicates_last_write_wins():
    rows = np.array([5, 5, 5])
    cols = np.array([7, 7, 7])
    vals = np.array([1.0, 2.0, 3.0], np.float32)
    a = bsr_from_coo(rows, cols, vals, (64, 128), block_m=32)
    assert np.asarray(a.data)[0, 5, 7] == 3.0


def test_explicit_zero_values_do_not_create_blocks():
    rows = np.array([0, 40])
    cols = np.array([0, 0])
    vals = np.array([0.0, 1.0], np.float32)
    a = bsr_from_coo(rows, cols, vals, (64, 128), block_m=32)
    dense = np.zeros((64, 128), np.float32)
    dense[rows, cols] = vals
    _assert_matches_oracle(a, dense, 32)   # block-row 0 is a zero pad block
    assert float(np.abs(np.asarray(a.data)[0]).sum()) == 0.0


def test_all_empty_pattern():
    a = bsr_from_coo(np.array([], np.int32), np.array([], np.int32),
                     np.array([], np.float32), (100, 100), block_m=32)
    _assert_matches_oracle(a, np.zeros((100, 100), np.float32), 32)


def test_out_of_range_raises():
    with pytest.raises(ValueError):
        bsr_from_coo(np.array([100]), np.array([0]), np.ones(1),
                     (100, 128), block_m=32)


def test_large_grid_sort_fallback():
    """Huge logical shape forces the sort-based assembly path."""
    rng = np.random.default_rng(0)
    m = k = 300_000
    rows = rng.integers(0, m, 300)
    cols = rng.integers(0, k, 300)
    plan = plan_from_coo(rows, cols, (m, k), block_m=32)
    assert plan.n_blockrows * plan.n_blockcols > 1 << 22
    a = plan.build(np.ones(300, np.float32))
    key = (np.asarray(a.rowids).astype(np.int64) * plan.n_blockcols
           + np.asarray(a.colids))
    assert np.all(np.diff(key) > 0)                      # sorted, unique
    assert set(np.asarray(a.rowids).tolist()) == set(range(plan.n_blockrows))


def test_plan_reuse_and_take_indices():
    """A plan built once serves fresh values; reuse=True overwrites in
    place; last-write-wins maps through ``take``."""
    rows = np.array([0, 5, 5, 40, 0])
    cols = np.array([0, 200, 200, 3, 0])
    plan = plan_from_coo(rows, cols, (64, 256), block_m=32)
    v1 = np.array([1., 2., 3., 4., 5.], np.float32)
    m1 = plan.build(v1)
    d1 = np.asarray(m1.data)
    assert d1[np.asarray(m1.rowids) == 0][0][0, 0] == 5.0    # last dup wins
    m2 = plan.build(2 * v1, reuse=True)
    m3 = plan.build(3 * v1, reuse=True)
    assert np.asarray(m3.data)[np.asarray(m3.rowids) == 0][0][0, 0] == 15.0


def test_bsr_from_blocks_matches_coo():
    """Block-coordinate construction == element-level construction."""
    rng = np.random.default_rng(3)
    bm, E, T = 32, 4, 128
    pairs_t = np.repeat(np.arange(T), 2)
    pairs_e = np.stack([rng.permutation(E)[:2] for _ in range(T)]).reshape(-1)
    x = rng.normal(size=(T, 128)).astype(np.float32)
    # element level
    rows = np.repeat(pairs_t, 128).astype(np.int32)
    cols = (pairs_e[:, None] * 128 + np.arange(128)).reshape(-1)
    vals = x[pairs_t].reshape(-1)
    a = bsr_from_coo(rows, cols, vals, (T, E * 128), block_m=bm)
    # block level
    bkey = (pairs_t // bm) * E + pairs_e
    ub, inv = np.unique(bkey, return_inverse=True)
    blocks = np.zeros((ub.size, bm, 128), np.float32)
    blocks[inv, pairs_t % bm, :] = x[pairs_t]
    b = bsr_from_blocks(ub // E, ub % E, blocks, T // bm, E)
    np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))
    np.testing.assert_array_equal(np.asarray(a.rowids), np.asarray(b.rowids))
    np.testing.assert_array_equal(np.asarray(a.colids), np.asarray(b.colids))


def test_bsr_from_blocks_rejects_duplicates():
    blocks = np.zeros((2, 32, 128), np.float32)
    with pytest.raises(ValueError):
        bsr_from_blocks([0, 0], [1, 1], blocks, 2, 2)


# ------------------------------------------------------ device build path

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       m=st.integers(1, 300), k=st.integers(1, 500),
       block_m=st.sampled_from([8, 16, 32, 64]),
       nnz=st.integers(0, 1500),
       dtype=st.sampled_from(["float32", "float64", "int32"]))
def test_build_device_bit_identical_property(seed, m, k, block_m, nnz,
                                             dtype):
    """The jitted device scatter is bit-identical to the numpy host path
    across duplicate entries (last-write-wins through ``take``), explicit
    zero values, empty block-rows (pad blocks stay zero), and non-float32
    value dtypes."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)          # duplicates likely for dense nnz
    cols = rng.integers(0, k, nnz)
    plan = plan_from_coo(rows, cols, (m, k), block_m=block_m)
    values = rng.normal(size=nnz) * 10
    values[rng.random(nnz) < 0.15] = 0.0    # explicit zeros stay structural
    values = values.astype(dtype)
    host = plan.build(values)
    dev = plan.build_device(jnp.asarray(values))
    np.testing.assert_array_equal(np.asarray(host.data),
                                  np.asarray(dev.data))
    np.testing.assert_array_equal(np.asarray(host.rowids),
                                  np.asarray(dev.rowids))
    np.testing.assert_array_equal(np.asarray(host.colids),
                                  np.asarray(dev.colids))
    # the donated in-place update rebuilds to the same bits as a cold build
    v2 = (values * 2).astype(dtype)
    buf = plan.device_update(dev.data, jnp.asarray(v2))
    np.testing.assert_array_equal(np.asarray(plan.build(v2).data),
                                  np.asarray(buf))


def test_build_device_duplicates_and_empty_rows():
    rows = np.array([5, 5, 5, 130])         # dup entries + empty block-rows
    cols = np.array([7, 7, 7, 0])
    plan = plan_from_coo(rows, cols, (160, 256), block_m=32)
    vals = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    dev = plan.build_device(jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(plan.build(vals).data),
                                  np.asarray(dev.data))
    assert np.asarray(dev.data)[0, 5, 7] == 3.0          # last dup wins
    assert plan.n_blockrows == 5                         # rows 1..3 empty
    assert set(np.asarray(dev.rowids).tolist()) == set(range(5))


def test_build_device_empty_pattern():
    plan = plan_from_coo(np.array([], np.int64), np.array([], np.int64),
                         (100, 100), block_m=32)
    host = plan.build(np.array([], np.float32))
    dev = plan.build_device(jnp.zeros((0,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(host.data),
                                  np.asarray(dev.data))


def test_build_device_rejects_short_values_like_host_path():
    # the device gather would silently clamp out-of-range indices; the
    # host numpy path raises — both must fail on malformed input
    rows = np.array([0, 40]); cols = np.array([0, 130])
    plan = plan_from_coo(rows, cols, (64, 256), block_m=32)
    with pytest.raises(IndexError):
        plan.build(np.ones(1, np.float32))
    with pytest.raises(ValueError, match="values has 1"):
        plan.build_device(jnp.ones(1, jnp.float32))


def test_device_indices_refuses_silent_int64_truncation():
    # x64-disabled JAX would wrap an int64 scatter index to int32 —
    # corruption, not an error.  A plan whose buffer needs int64 must
    # refuse the device path instead.
    from repro.kernels.format import BsrPlan
    n = 140_000                     # nnzb * 128 * 128 > 2**31
    plan = BsrPlan(rowids=np.zeros(n, np.int32),
                   colids=np.zeros(n, np.int32),
                   n_blockrows=n, n_blockcols=1, block_m=128,
                   take=np.array([0], np.int32),
                   slot=np.array([n - 1], np.int32),
                   rloc=np.array([127], np.int16),
                   cloc=np.array([127], np.int16))
    assert plan.flat_index().dtype == np.int64
    with pytest.raises(ValueError, match="int64"):
        plan.device_indices()


def test_flat_index_cached_and_consistent():
    rows = np.array([0, 33, 64]); cols = np.array([0, 130, 255])
    plan = plan_from_coo(rows, cols, (96, 256), block_m=32)
    flat = plan.flat_index()
    assert flat is plan.flat_index()                     # cached
    want = (plan.slot.astype(np.int64) * plan.block_m
            + plan.rloc) * 128 + plan.cloc
    np.testing.assert_array_equal(flat.astype(np.int64), want)


# -------------------------------------------------------- autotune cache

def test_cached_config_matches_uncached():
    for fam in ("banded", "uniform", "blockdiag"):
        mat = generate_matrix(fam, seed=5, n_rows=512, n_cols=512,
                              target_nnz=6000)
        fresh = KernelAutotuner().heuristic(mat)
        cached = KernelAutotuner().get(mat).config
        assert fresh == cached


def test_cache_hit_skips_featurization():
    mat = generate_matrix("powerlaw", seed=9, n_rows=512, n_cols=512,
                          target_nnz=5000)
    kt = KernelAutotuner()
    e1 = kt.get(mat)
    e2 = kt.get(mat)
    assert e1 is e2
    assert kt.featurize_calls == 1
    assert kt.cache.hits == 1 and kt.cache.misses == 1
    # the cached plan produces the same matrix as a fresh conversion
    vals = np.ones(mat.nnz, np.float32)
    a = e2.build(vals)
    b = plan_from_coo(mat.rows, mat.cols, (mat.n_rows, mat.n_cols),
                      block_m=e2.config["block_m"]).build(vals)
    np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))


def test_cache_lru_eviction():
    cache = AutotuneCache(maxsize=2)
    for i in range(3):
        mat = generate_matrix("uniform", seed=i, n_rows=256, n_cols=256,
                              target_nnz=1000)
        cache.put(("spmm", matrix_digest(mat)), object())
    assert len(cache) == 2


def test_pattern_digest_sensitivity():
    r = np.array([0, 1]); c = np.array([2, 3])
    base = pattern_digest(r, c, (10, 10))
    assert pattern_digest(r, c, (10, 11)) != base        # shape matters
    assert pattern_digest(c, r, (10, 10)) != base        # coords matter
    assert pattern_digest(r.astype(np.int32), c, (10, 10)) == base  # dtype no


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       family=st.sampled_from(["uniform", "banded", "blockdiag", "powerlaw"]))
def test_cache_equivalence_property(seed, family):
    """Cache round-trips any generated pattern to the uncached config."""
    mat = generate_matrix(family, seed=seed, n_rows=384, n_cols=384,
                          target_nnz=3000)
    assert KernelAutotuner().get(mat).config == KernelAutotuner.heuristic(mat)
