"""Unit tests for the trip-count-aware HLO cost analyzer — the foundation of
every roofline number in EXPERIMENTS.md."""
import jax
import jax.numpy as jnp
from jax import lax
import numpy as np

from repro.launch.hloparse import HloModule, analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_counts_multiply_flops():
    def scanned(x, ws):
        def body(x, w):
            return x @ w, None
        y, _ = lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    r = analyze_hlo(_compile(scanned, x, ws))
    expect = 7 * 2 * 256 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    r = analyze_hlo(_compile(lambda a, b: a @ b, a, b))
    assert r["flops"] == 2 * 128 * 512 * 64


def test_traffic_counts_results_once():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze_hlo(_compile(lambda a: a @ a, a))
    # one dot result materialized: 64KiB <= traffic <= a few results
    assert 128 * 128 * 4 <= r["traffic_bytes"] <= 10 * 128 * 128 * 4


def test_batched_dot_contraction_dims():
    """dot_general with batch dims: flops = 2 * prod(result) * contract."""
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    r = analyze_hlo(_compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                             a, b))
    assert r["flops"] == 2 * (4 * 32 * 16) * 64


def test_entry_detection_and_no_collectives_on_host():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    hlo = _compile(lambda a: jnp.tanh(a @ a), a)
    mod = HloModule(hlo)
    assert mod.entry is not None
    r = analyze_hlo(hlo)
    assert r["collectives"] == {}
