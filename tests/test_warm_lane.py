"""Differential tests for the fused warm fast path.

The warm lane's correctness story is *differential*: every test here runs
the same request sequence through two engines — one with ``warm_lane=True``
(the fused lane) and one with ``warm_lane=False`` (the staged pipeline,
bit-for-bit the pre-warm-lane engine) — and asserts the responses are
**bit-identical** (outputs, built block data, configs, cache-hit flags)
and the accounting agrees (hit counters, dispatch generations, lease
balance, health successes).  Mix coverage: all-warm repeats, all-cold
fresh traffic, interleaved warm/cold batches, a breaker tripping mid-run
(warm table invalidation), and drift-gated fallthrough.

Property-based via ``hypothesis`` when installed; ``tests/_compat.py``
degrades to a seeded deterministic sampler otherwise, so the suite runs
on the bare container image.

The threaded stress test (producers hammering ``step()`` while a
``FaultPlan`` trips a breaker mid-run) carries the ``slow`` marker like
the other stress tests; everything here also carries ``warm_lane`` so CI
can run exactly this suite as its own step.
"""
import threading

import numpy as np
import pytest

from _compat import given, settings, st
from repro.data import generate_matrix
from repro.serving import (FaultPlan, HealthConfig, HealthRegistry,
                           KernelRequest, SparseKernelEngine, inject_faults)
from repro.serving.health import CLOSED, OPEN

pytestmark = pytest.mark.warm_lane

TAG = ("tpu_interpret", "spmm")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mats(n, seed0=0, n_rows=256, nnz=1200):
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    return [generate_matrix(fams[i % 4], seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_rows, target_nnz=nnz) for i in range(n)]


def _engines(**kw):
    """One warm-lane engine and one staged reference engine."""
    warm = SparseKernelEngine(warm_lane=True, **kw)
    ref = SparseKernelEngine(warm_lane=False, **kw)
    return warm, ref


def _step_both(warm, ref, reqs_a, reqs_b):
    """Serve the same batch on both engines, returning both responses."""
    return warm.step(reqs_a), ref.step(reqs_b)


def _requests(mats, values_seed=0, with_operand=True, n_cols=8):
    rng = np.random.default_rng(values_seed)
    out = []
    for m in mats:
        vals = rng.normal(size=m.nnz).astype(np.float32)
        operand = rng.normal(size=(m.n_cols, n_cols)).astype(np.float32) \
            if with_operand else None
        out.append((m, vals, operand))
    return out


def _build(specs):
    return [KernelRequest(m, v.copy(), "spmm", o) for m, v, o in specs]


def _assert_bit_identical(got, want):
    """Responses from the warm engine vs the staged reference: the entire
    externally visible result must match bit for bit."""
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert a.digest == b.digest
        assert a.config == b.config
        assert a.cache_hit == b.cache_hit
        assert a.platform == b.platform
        assert a.degraded == b.degraded
        assert a.attempts == b.attempts
        assert np.array_equal(np.asarray(a.matrix.data),
                              np.asarray(b.matrix.data))
        assert (a.output is None) == (b.output is None)
        if a.output is not None:
            assert np.array_equal(np.asarray(a.output),
                                  np.asarray(b.output))


def _assert_accounting_agrees(warm, ref, *, warm_steps_expected=None):
    """stats() deltas agree between the lanes: hits/misses, requests,
    dispatch generations, breaker successes, and lease balance."""
    sw, sr = warm.stats(), ref.stats()
    assert sw["requests"] == sr["requests"]
    assert sw["batches"] == sr["batches"]
    assert sw["hits"] == sr["hits"]
    assert sw["misses"] == sr["misses"]
    assert sw["arenas"]["generation"] == sr["arenas"]["generation"]
    assert sw["arenas"]["outstanding_leases"] \
        == sr["arenas"]["outstanding_leases"]
    for tag, br in sr["health"]["breakers"].items():
        bw = sw["health"]["breakers"][tag]
        assert bw["successes"] == br["successes"]
        assert bw["failures"] == br["failures"]
        assert bw["state"] == br["state"]
    if warm_steps_expected is not None:
        assert sw["warm_lane"]["steps"] == warm_steps_expected
    assert sr["warm_lane"]["steps"] == 0


# ------------------------------------------------------------- differential

def test_all_warm_repeat_bit_identical():
    """Steady-state hot traffic: step 1 populates the warm table, steps
    2..4 are all-warm and must reproduce the staged engine bit for bit."""
    warm, ref = _engines()
    specs = _requests(_mats(3, seed0=9_000), values_seed=1)
    for k in range(4):
        rw, rr = _step_both(warm, ref, _build(specs), _build(specs))
        _assert_bit_identical(rw, rr)
    assert warm.stats()["warm_lane"]["steps"] == 3   # steps 2..4
    assert warm.stats()["warm_lane"]["requests"] == 9
    _assert_accounting_agrees(warm, ref, warm_steps_expected=3)
    warm.release_stream()
    ref.release_stream()


def test_all_cold_traffic_never_takes_lane():
    warm, ref = _engines()
    for k in range(3):
        specs = _requests(_mats(2, seed0=9_100 + 10 * k), values_seed=k)
        rw, rr = _step_both(warm, ref, _build(specs), _build(specs))
        _assert_bit_identical(rw, rr)
    assert warm.stats()["warm_lane"]["steps"] == 0
    _assert_accounting_agrees(warm, ref)
    warm.release_stream()
    ref.release_stream()


def test_interleaved_warm_cold_batches_split_once():
    """Mixed batches: repeats take the lane while fresh patterns run the
    staged sub-pipeline in the same step — outputs and accounting must
    still match the staged engine exactly."""
    warm, ref = _engines()
    hot = _requests(_mats(2, seed0=9_200), values_seed=3)
    warm.step(_build(hot))
    ref.step(_build(hot))
    for k in range(3):
        cold = _requests(_mats(2, seed0=9_300 + 10 * k), values_seed=4 + k)
        mixed = [hot[0], cold[0], hot[1], cold[1]]
        rw, rr = _step_both(warm, ref, _build(mixed), _build(mixed))
        _assert_bit_identical(rw, rr)
    s = warm.stats()["warm_lane"]
    assert s["steps"] == 3 and s["requests"] == 6    # 2 warm per mixed step
    _assert_accounting_agrees(warm, ref, warm_steps_expected=3)
    warm.release_stream()
    ref.release_stream()


def test_prepare_only_traffic_warm_bit_identical():
    """Operand-less (prepare-only) repeats take the fused build path; the
    built block data must match the staged engine's bit for bit."""
    warm, ref = _engines()
    specs = _requests(_mats(3, seed0=9_400), values_seed=5,
                      with_operand=False)
    for _ in range(3):
        rw, rr = _step_both(warm, ref, _build(specs), _build(specs))
        _assert_bit_identical(rw, rr)
    assert warm.stats()["warm_lane"]["steps"] == 2
    _assert_accounting_agrees(warm, ref, warm_steps_expected=2)
    warm.release_stream()
    ref.release_stream()


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2**20),
       n_patterns=st.integers(min_value=1, max_value=3),
       mix=st.lists(st.integers(min_value=0, max_value=5),
                    min_size=2, max_size=5),
       with_operand=st.sampled_from([True, False]))
def test_warm_lane_differential_property(seed, n_patterns, mix,
                                         with_operand):
    """Property: for ANY mix of repeated and fresh patterns across steps,
    the warm engine is bit-identical to the staged engine and the
    accounting deltas agree.  ``mix`` draws each step's batch from a
    rotating window over a pattern pool, so consecutive steps overlap in
    arbitrary warm/cold proportions."""
    pool = _requests(_mats(n_patterns + 3, seed0=20_000 + seed % 997),
                     values_seed=seed, with_operand=with_operand)
    warm, ref = _engines()
    for step_i, pick in enumerate(mix):
        lo = pick % len(pool)
        batch = [pool[(lo + j) % len(pool)] for j in range(n_patterns)]
        rw, rr = _step_both(warm, ref, _build(batch), _build(batch))
        _assert_bit_identical(rw, rr)
    _assert_accounting_agrees(warm, ref)
    warm.release_stream()
    ref.release_stream()


# ------------------------------------------------- invalidation / health

def test_breaker_trip_invalidates_warm_entries():
    """A breaker transition mid-stream: the warm table's entries for the
    tripped platform are stamped with a stale health generation, so the
    next probe drops them (warm_invalidation event) and traffic flows
    back through the router's health gate — outputs still bit-identical
    to the staged engine, responses degraded on both."""
    kw = dict(health=HealthRegistry(HealthConfig(consecutive_errors=1,
                                                 backoff_s=60.0),
                                    clock=FakeClock()))
    warm = SparseKernelEngine(warm_lane=True, **kw)
    ref = SparseKernelEngine(
        warm_lane=False,
        health=HealthRegistry(HealthConfig(consecutive_errors=1,
                                           backoff_s=60.0),
                              clock=FakeClock()))
    specs = _requests(_mats(2, seed0=9_500), values_seed=6)
    for e in (warm, ref):
        e.step(_build(specs))
        e.step(_build(specs))               # warm engine: lane serves this
    assert warm.stats()["warm_lane"]["steps"] == 1
    # trip the default backend's breaker on both engines
    fw = inject_faults(warm.backends, *TAG, FaultPlan.fail_calls(0, 2))
    fr = inject_faults(ref.backends, *TAG, FaultPlan.fail_calls(0, 2))
    rw = warm.step(_build(specs))
    rr = ref.step(_build(specs))
    fw.restore()
    fr.restore()
    assert all(r.degraded and r.attempts == 2 for r in rw)
    _assert_bit_identical(rw, rr)
    assert warm.health.state(TAG) == OPEN
    # the tripped step DID take the lane (the probe ran against a still-
    # closed breaker; the failure struck in execute) — the shared retry
    # lane served it degraded, mid-lane, identically to the staged engine
    assert warm.stats()["warm_lane"]["steps"] == 2
    # ...but the NEXT step cannot: the health generation moved, so the
    # probe drops the stale entries and falls through to the health gate
    rw2 = warm.step(_build(specs))
    rr2 = ref.step(_build(specs))
    _assert_bit_identical(rw2, rr2)
    assert warm.stats()["warm_lane"]["steps"] == 2
    # the stale entries were dropped and the event emitted
    assert warm.telemetry.warm_invalidations >= 1
    assert warm.events.events(kind="warm_invalidation")
    # degraded requests always land in the error ring, lane or no lane
    assert len(warm.traces(errors=True)) == len(ref.traces(errors=True)) > 0
    warm.release_stream()
    ref.release_stream()


def test_open_breaker_requests_fall_through_not_warm():
    """While a circuit is open, previously-warm traffic must keep flowing
    through the staged health gate (failover rewrite), never the lane."""
    clk = FakeClock()
    engine = SparseKernelEngine(
        warm_lane=True,
        health=HealthRegistry(HealthConfig(consecutive_errors=1,
                                           backoff_s=60.0), clock=clk))
    specs = _requests(_mats(1, seed0=9_600), values_seed=7)
    engine.step(_build(specs))
    engine.step(_build(specs))
    assert engine.stats()["warm_lane"]["steps"] == 1
    fx = inject_faults(engine.backends, *TAG, FaultPlan.fail_calls(0, 1))
    engine.step(_build(specs))
    fx.restore()
    assert engine.health.state(TAG) == OPEN
    before = engine.stats()["warm_lane"]["steps"]
    r = engine.step(_build(specs))
    assert engine.stats()["warm_lane"]["steps"] == before   # no lane
    assert r[0].platform != TAG[0]          # health gate failed it over
    engine.release_stream()


def test_recovered_breaker_re_warms():
    """After the circuit closes again, repeats re-record and the lane
    resumes — the warm table tracks health generations, not history."""
    clk = FakeClock()
    engine = SparseKernelEngine(
        warm_lane=True,
        health=HealthRegistry(HealthConfig(consecutive_errors=1,
                                           backoff_s=1.0), clock=clk))
    specs = _requests(_mats(1, seed0=9_700), values_seed=8)
    engine.step(_build(specs))
    fx = inject_faults(engine.backends, *TAG, FaultPlan.fail_calls(0, 1))
    engine.step(_build(specs))              # trips the breaker
    fx.restore()
    clk.advance(2.0)                        # past backoff: probe allowed
    engine.step(_build(specs))              # half-open probe succeeds
    assert engine.health.state(TAG) == CLOSED
    engine.step(_build(specs))              # records under the new gen
    before = engine.stats()["warm_lane"]["steps"]
    r = engine.step(_build(specs))
    assert engine.stats()["warm_lane"]["steps"] == before + 1
    assert not r[0].degraded
    engine.release_stream()


def test_drift_gate_falls_through():
    """``warm_drift_ms=0`` makes any measurable calibration drift fail
    the gate: once the drift gauge is non-None the lane must decline."""
    engine = SparseKernelEngine(warm_lane=True, warm_drift_ms=0.0,
                                warm_sample_rate=1.0)
    specs = _requests(_mats(1, seed0=9_800), values_seed=9)
    engine.step(_build(specs))
    for _ in range(4):
        engine.step(_build(specs))
    # sampled warm steps + staged steps feed the drift gauge; once it has
    # two samples it exceeds the 0ms gate and the lane declines
    assert engine.telemetry.calibration.drift(TAG[0], op=TAG[1]) is not None
    before = engine.stats()["warm_lane"]
    engine.step(_build(specs))
    after = engine.stats()["warm_lane"]
    assert after["steps"] == before["steps"]
    assert after["fallthroughs"] > before["fallthroughs"]
    engine.release_stream()


def test_warm_lane_off_is_staged_engine():
    engine = SparseKernelEngine(warm_lane=False)
    specs = _requests(_mats(2, seed0=9_900), values_seed=10)
    engine.step(_build(specs))
    engine.step(_build(specs))
    s = engine.stats()["warm_lane"]
    assert s["steps"] == 0 and s["requests"] == 0 and s["table"] == 0
    engine.release_stream()


def test_warm_telemetry_sampling_is_deterministic():
    """warm_sample_rate=0.25 -> exactly every 4th warm step runs the
    per-request calibration observes (counter sampler, no RNG)."""
    engine = SparseKernelEngine(warm_lane=True, warm_sample_rate=0.25)
    specs = _requests(_mats(1, seed0=10_000), values_seed=11)
    for _ in range(9):                      # 1 cold + 8 warm steps
        engine.step(_build(specs))
    s = engine.stats()["warm_lane"]
    assert s["steps"] == 8
    assert s["sampled_steps"] == 2          # ceil-spaced 2 of 8 at 1/4
    engine.release_stream()


# ------------------------------------------------------- threaded stress

@pytest.mark.slow
def test_threaded_warm_stress_with_breaker_trip():
    """N producers hammer ``step()`` with hot traffic while a fault plan
    hard-fails a window of executor calls, tripping the default backend's
    breaker mid-run.  Invariants: zero lost requests (every step returns
    a full response list), every degraded request retained in the error
    ring, lease balance returns to zero, and the engine stays consistent
    (no double-released slots, no stuck load accounting)."""
    clk = FakeClock()
    engine = SparseKernelEngine(
        warm_lane=True,
        health=HealthRegistry(HealthConfig(consecutive_errors=2,
                                           backoff_s=1e9), clock=clk))
    specs = _requests(_mats(4, seed0=10_100), values_seed=12)
    engine.step(_build(specs))              # populate cache + warm table
    fx = inject_faults(engine.backends, *TAG,
                       FaultPlan.fail_calls(20, 24))
    n_threads, n_steps = 4, 10
    served = [0] * n_threads
    degraded = [0] * n_threads
    errors: list = []

    def worker(t):
        try:
            for k in range(n_steps):
                reqs = _build([specs[(t + k + j) % len(specs)]
                               for j in range(2)])
                resp = engine.step(reqs)
                assert len(resp) == len(reqs)
                served[t] += len(resp)
                degraded[t] += sum(r.degraded for r in resp)
            engine.release_stream()
        except Exception as e:              # pragma: no cover - fail loud
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    fx.restore()
    engine.release_stream()
    assert not errors
    assert sum(served) == n_threads * n_steps * 2    # zero lost requests
    # the window fired at least to the trip threshold — once the breaker
    # opens, the health gate steers traffic off the backend, so later
    # calls in the fault window may legitimately never happen
    assert fx.injected["error"] >= 2
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 0    # lease balance
    for tag, load in s["load"].items():
        assert load["inflight"] == 0                 # no stuck accounting
    # every degraded request was retained in the error ring (ring is large
    # enough here that nothing was evicted)
    assert s["tracing"]["error_recorded"] == sum(degraded) > 0
    # every degraded request is accounted for: moved by the retry lane
    # (executor actually failed) or rewritten by the health gate once the
    # circuit opened — nothing degraded without a recorded cause
    assert s["health"]["failovers"] + s["health"]["circuit_fast_fails"] \
        == sum(degraded)
    assert s["health"]["failovers"] == fx.injected["error"]
