"""Hypothesis import guard for the test suite.

On environments with ``hypothesis`` installed the real library is used
unchanged.  On a clean environment (the container images only guarantee
numpy/jax/pytest) we fall back to a thin deterministic sampler: each
``@given`` test runs a fixed number of pseudo-random examples drawn from the
declared strategies, seeded by the test name — so property tests keep
running (with less adversarial search) instead of failing at collection.
"""
from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import zlib

    import numpy as _np

    _FALLBACK_EXAMPLES = 6

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _St:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

    st = _St()

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            def runner():
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(**{name: s.sample(rng)
                          for name, s in strategies.items()})
            # keep pytest from treating the sampled params as fixtures
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__signature__ = inspect.Signature()
            return runner
        return deco
