"""Tests for the serving subsystem: engine, arena, persistence, telemetry,
batched autotuning, multi-backend dispatch (routing, cache isolation,
namespaced persistence incl. legacy files), and the core/autotune satellites.

Stress tests (thread hammering, long arena rotations) carry the ``slow``
marker and are deselected from tier-1 (``pytest -m slow`` runs them).
"""
import threading
import types

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.autotune import (AutotuneCache, KernelAutotuner, StatsMemo,
                                 _STATS_MEMO, matrix_digest, pattern_digest)
from repro.data import generate_matrix
from repro.kernels import spmm_ref
from repro.kernels.format import plan_from_coo
from repro.serving import (ArenaOverrun, KernelBackend, KernelRequest,
                           PlanArena, SparseKernelEngine, default_registry,
                           load_cache, load_grouped, save_backends,
                           save_cache, warm_start)
from repro.serving.telemetry import LatencyHistogram


def _mats(n, seed0=0, n_rows=256, nnz=1200):
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    return [generate_matrix(fams[i % 4], seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_rows, target_nnz=nnz) for i in range(n)]


# ------------------------------------------------------------ pattern digest

def test_pattern_digest_dtype_insensitive_native_hash():
    r = np.array([3, 70, 200], np.int32)
    c = np.array([5, 9, 100], np.int32)
    base = pattern_digest(r, c, (256, 256))
    assert pattern_digest(r.astype(np.int64), c.astype(np.int64),
                          (256, 256)) == base
    assert pattern_digest(r.astype(np.uint16), c, (256, 256)) == base
    assert pattern_digest(r, c, (256, 512)) != base
    # coordinates beyond int32 hash distinctly (can't collide with int32)
    big = np.array([2**40], np.int64)
    assert pattern_digest(big, np.array([0]), (2**41, 2)) \
        != pattern_digest(np.array([1], np.int64), np.array([0]), (2**41, 2))


# ---------------------------------------------------------------- stats memo

def test_stats_memo_clear_and_maxsize():
    memo = StatsMemo(maxsize=4)
    mats = _mats(6, seed0=100)
    for m in mats:
        memo.get_or_compute(m)
    assert len(memo) == 4              # LRU-bounded
    memo.maxsize = 2
    assert len(memo) == 2              # shrinking trims oldest
    memo.clear()
    assert len(memo) == 0
    s1 = memo.get_or_compute(mats[0])
    s2 = memo.get_or_compute(mats[0])
    assert s1 is s2                    # memoized again after clear


def test_module_global_stats_memo_api():
    _STATS_MEMO.clear()
    assert len(_STATS_MEMO) == 0
    assert _STATS_MEMO.maxsize > 0


# ----------------------------------------------------------------- get_batch

def test_get_batch_matches_sequential_get():
    mats = _mats(6, seed0=200)
    seq = [KernelAutotuner().get(m) for m in mats]
    kt = KernelAutotuner()
    bat = kt.get_batch(mats)
    assert [e.config for e in bat] == [e.config for e in seq]
    assert kt.featurize_calls == len(mats)
    # hits afterwards: no featurization
    kt.get_batch(mats)
    assert kt.featurize_calls == len(mats)


def test_get_batch_dedupes_within_batch():
    m = _mats(1, seed0=300)[0]
    kt = KernelAutotuner()
    entries = kt.get_batch([m, m, m])
    assert kt.featurize_calls == 1
    assert entries[0] is entries[1] is entries[2]


def test_get_batch_mixed_hits_and_misses():
    mats = _mats(4, seed0=400)
    kt = KernelAutotuner()
    kt.get(mats[0])
    kt.get(mats[1])
    entries = kt.get_batch(mats)
    assert kt.featurize_calls == 4          # only the two new ones
    assert entries[0].digest == matrix_digest(mats[0])
    assert [e.digest for e in entries] == [matrix_digest(m) for m in mats]


# --------------------------------------------------------------------- arena

def test_arena_double_buffer_rotation_and_generations():
    m = _mats(1, seed0=500)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    arena = PlanArena(plan, n_slots=2)
    v1 = np.ones(m.nnz, np.float32)
    l1 = arena.build(v1)
    l2 = arena.build(2 * v1)
    # two live leases use distinct buffers; l1's data is intact
    assert np.asarray(l1.matrix.data).max() == 1.0
    assert np.asarray(l2.matrix.data).max() == 2.0
    with pytest.raises(ArenaOverrun):
        arena.build(3 * v1)                # both slots held
    assert arena.overruns == 1
    l1.release()
    l3 = arena.build(3 * v1)               # recycles l1's slot
    assert not l1.valid                    # stale alias is detectable
    assert l2.valid and l3.valid
    assert np.asarray(l3.matrix.data).max() == 3.0
    l2.release()
    l3.release()
    assert arena.free_slots() == 2


def test_arena_matrix_matches_plain_build():
    m = _mats(1, seed0=600)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    vals = np.random.default_rng(0).normal(size=m.nnz).astype(np.float32)
    lease = PlanArena(plan).build(vals)
    ref = plan.build(vals)
    np.testing.assert_array_equal(np.asarray(lease.matrix.data),
                                  np.asarray(ref.data))


def test_stale_release_does_not_free_new_lease():
    m = _mats(1, seed0=650)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    arena = PlanArena(plan, n_slots=1)
    v = np.ones(m.nnz, np.float32)
    l1 = arena.build(v)
    l1.release()
    l2 = arena.build(v)                    # same slot, new generation
    l1.release()                           # stale double-release: no-op
    assert arena.free_slots() == 0
    assert l2.valid


def test_arena_device_build_rotation_and_donation():
    m = _mats(1, seed0=660)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    arena = PlanArena(plan, n_slots=2)
    rng = np.random.default_rng(7)
    v = [jnp.asarray(rng.normal(size=m.nnz).astype(np.float32))
         for _ in range(3)]
    l1 = arena.build_device(v[0])
    l2 = arena.build_device(v[1])
    for lease, vals in ((l1, v[0]), (l2, v[1])):
        np.testing.assert_array_equal(np.asarray(lease.matrix.data),
                                      np.asarray(plan.build(
                                          np.asarray(vals)).data))
    l1.release()
    l3 = arena.build_device(v[2])       # recycles l1's slot via donation
    assert not l1.valid
    assert l1.matrix.data.is_deleted()  # stale alias raises, never corrupts
    np.testing.assert_array_equal(np.asarray(l3.matrix.data),
                                  np.asarray(plan.build(
                                      np.asarray(v[2])).data))
    assert arena.builds == 3 and arena.device_builds == 3
    l2.release()
    l3.release()


def test_arena_mixed_host_and_device_slots():
    m = _mats(1, seed0=670)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    arena = PlanArena(plan, n_slots=2)
    vals = np.random.default_rng(8).normal(size=m.nnz).astype(np.float32)
    lh = arena.build(vals)                       # host path
    ld = arena.build_device(jnp.asarray(vals))   # device path, other slot
    np.testing.assert_array_equal(np.asarray(lh.matrix.data),
                                  np.asarray(ld.matrix.data))
    assert arena.builds == 2 and arena.device_builds == 1
    lh.release()
    ld.release()


# -------------------------------------------------------------------- engine

def test_engine_outputs_match_reference():
    mats = _mats(3, seed0=700)
    rng = np.random.default_rng(1)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    engine = SparseKernelEngine()
    for _ in range(2):                      # second step = pure cache hits
        reqs = [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                              "spmm", rhs) for m in mats]
        for resp, req in zip(engine.step(reqs), reqs):
            want = np.asarray(spmm_ref(resp.matrix, rhs))
            got = np.asarray(resp.output)[:, :64]
            np.testing.assert_allclose(got, want[:, :64], atol=1e-4)
    s = engine.stats()
    assert s["misses"] == 3 and s["hits"] == 3
    assert s["featurize_calls"] == 3
    assert s["stages"]["step"]["n"] == 2


def test_engine_double_buffers_across_steps():
    m = _mats(1, seed0=800)[0]
    engine = SparseKernelEngine()
    r1 = engine.step([KernelRequest(m, np.ones(m.nnz, np.float32))])[0]
    d1 = np.asarray(r1.matrix.data)
    r2 = engine.step([KernelRequest(m, 2 * np.ones(m.nnz, np.float32))])[0]
    # step 1's matrix is still intact while step 2 is outstanding
    assert np.asarray(r1.matrix.data).max() == 1.0
    assert np.asarray(r2.matrix.data).max() == 2.0
    assert d1 is not np.asarray(r2.matrix.data)
    engine.flush()


def test_engine_arena_overflow_falls_back():
    m = _mats(1, seed0=900)[0]
    engine = SparseKernelEngine(arena_slots=2)
    # 3 same-pattern requests in one batch: 2 arena slots + 1 fallback
    reqs = [KernelRequest(m, (i + 1) * np.ones(m.nnz, np.float32))
            for i in range(3)]
    resps = engine.step(reqs)
    assert [r.arena_slot for r in resps] == [True, True, False]
    assert engine.stats()["arena_fallbacks"] == 1
    # every response still carries its own values
    for i, r in enumerate(resps):
        assert np.asarray(r.matrix.data).max() == i + 1.0


def test_engine_telemetry_hit_accounting():
    mats = _mats(2, seed0=1000)
    engine = SparseKernelEngine()
    resps = engine.step([KernelRequest(mats[0]), KernelRequest(mats[1]),
                         KernelRequest(mats[0])])
    assert [r.cache_hit for r in resps] == [False, False, False]
    assert engine.featurize_calls == 2      # within-batch dup scored once
    resps = engine.step([KernelRequest(mats[0])])
    assert resps[0].cache_hit
    s = engine.stats()
    assert s["requests"] == 4 and s["batches"] == 2
    assert 0 < s["hit_rate"] < 1


# ------------------------------------------------- device builds + drain

def test_engine_device_build_auto_routes_by_residency():
    m = _mats(1, seed0=2900)[0]
    vals = np.random.default_rng(9).normal(size=m.nnz).astype(np.float32)
    engine = SparseKernelEngine()
    r_dev = engine.step([KernelRequest(m, jnp.asarray(vals))])[0]
    r_host = engine.step([KernelRequest(m, vals)])[0]
    assert r_dev.device_built and not r_host.device_built
    np.testing.assert_array_equal(np.asarray(r_dev.matrix.data),
                                  np.asarray(r_host.matrix.data))
    bp = engine.stats()["build_paths"]
    assert bp["device"] == 1 and bp["host"] == 1
    # the second step's build overlapped the first step's in-flight batch
    assert bp["overlapped"] == 1 and bp["overlap_ratio"] == 0.5
    engine.drain()


def test_engine_device_build_always_and_never():
    m = _mats(1, seed0=2950)[0]
    vals = np.ones(m.nnz, np.float32)
    always = SparseKernelEngine(device_build="always")
    assert always.step([KernelRequest(m, vals)])[0].device_built
    always.drain()
    never = SparseKernelEngine(device_build="never")
    assert not never.step([KernelRequest(m, jnp.asarray(vals))])[0] \
        .device_built
    never.drain()
    with pytest.raises(ValueError, match="device_build"):
        SparseKernelEngine(device_build="sometimes")


def test_engine_drain_releases_every_generation():
    mats = _mats(2, seed0=2960)
    rhs = np.ones((256, 32), np.float32)
    engine = SparseKernelEngine()
    gens = []
    for i in range(3):      # three async generations on one stream
        resp = engine.step([KernelRequest(mats[i % 2],
                                          np.ones(mats[i % 2].nnz,
                                                  np.float32),
                                          "spmm", rhs)])[0]
        gens.append(resp.generation)
    assert gens == sorted(gens) and len(set(gens)) == 3
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 1   # only the last gen
    assert s["arenas"]["generation"] == gens[-1]
    engine.drain()
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 0
    assert all(v["inflight"] == 0 for v in s["load"].values())
    assert s["build_paths"]["drain_waits"] == 1
    engine.drain()          # idempotent: nothing outstanding, no new wait
    assert engine.stats()["build_paths"]["drain_waits"] == 1


def test_engine_drain_device_path_end_to_end():
    m = _mats(1, seed0=2970)[0]
    rhs = np.random.default_rng(10).normal(size=(256, 32)) \
        .astype(np.float32)
    vals = np.random.default_rng(11).normal(size=m.nnz).astype(np.float32)
    engine = SparseKernelEngine()
    outs = []
    for scale in (1.0, 2.0):
        resp = engine.step([KernelRequest(m, jnp.asarray(scale * vals),
                                          "spmm", rhs)])[0]
        assert resp.device_built
        # consume the async output BEFORE the slot can rotate
        outs.append(np.asarray(resp.output))
    engine.drain()
    np.testing.assert_allclose(outs[1], 2.0 * outs[0], rtol=1e-4)


# --------------------------------------------------------------- persistence

def test_persist_roundtrip_zero_featurize(tmp_path):
    path = tmp_path / "cache.npz"
    mats = _mats(4, seed0=1100)
    kt = KernelAutotuner()
    entries = kt.get_batch(mats)
    save_cache(kt.cache, path)

    kt2 = KernelAutotuner()
    assert warm_start(kt2, path) == 4
    warm = kt2.get_batch(mats)
    assert kt2.featurize_calls == 0         # zero featurizations on warm start
    assert [e.config for e in warm] == [e.config for e in entries]
    # restored plans produce identical BSR data
    vals = np.ones(mats[0].nnz, np.float32)
    np.testing.assert_array_equal(
        np.asarray(warm[0].build(vals).data),
        np.asarray(entries[0].build(vals).data))


def test_persist_corrupted_file_falls_back_cold(tmp_path):
    path = tmp_path / "cache.npz"
    mats = _mats(2, seed0=1200)
    kt = KernelAutotuner()
    kt.get_batch(mats)
    save_cache(kt.cache, path)
    # torn write: truncate the committed file mid-way
    raw = path.read_bytes()
    path.write_bytes(raw[:len(raw) // 2])
    with pytest.warns(UserWarning, match="starting cold"):
        assert load_cache(path) is None
    # engine constructor survives and counts the failure
    with pytest.warns(UserWarning):
        engine = SparseKernelEngine(persist_path=path)
    assert engine.stats()["persist_load_failures"] == 1
    resp = engine.step([KernelRequest(mats[0])])[0]     # serves cold
    assert not resp.cache_hit and engine.featurize_calls == 1


def test_persist_garbage_and_missing(tmp_path):
    bad = tmp_path / "bad.npz"
    bad.write_bytes(b"not an npz at all")
    with pytest.warns(UserWarning):
        assert load_cache(bad) is None
    assert load_cache(tmp_path / "never_written.npz") is None
    assert warm_start(KernelAutotuner(), tmp_path / "never_written.npz") == 0


def test_engine_save_and_warm_start(tmp_path):
    path = tmp_path / "cache.npz"
    mats = _mats(3, seed0=1300)
    engine = SparseKernelEngine(persist_path=path)
    engine.step([KernelRequest(m) for m in mats])
    engine.save()
    engine2 = SparseKernelEngine(persist_path=path)
    resps = engine2.step([KernelRequest(m) for m in mats])
    assert all(r.cache_hit for r in resps)
    s = engine2.stats()
    assert s["warm_start_entries"] == 3
    assert s["featurize_calls"] == 0


# ------------------------------------------------- per-thread lease lifecycle

def test_release_stream_idempotent_and_flush_alias():
    m = _mats(1, seed0=1700)[0]
    engine = SparseKernelEngine()
    engine.step([KernelRequest(m, np.ones(m.nnz, np.float32))])
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 1
    assert s["load"][f"{engine.default_platform}/spmm"]["inflight"] == 1
    engine.release_stream()
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 0
    assert s["load"][f"{engine.default_platform}/spmm"]["inflight"] == 0
    engine.release_stream()         # second call: no-op, never negative
    s = engine.stats()
    assert s["arenas"]["outstanding_leases"] == 0
    assert s["load"][f"{engine.default_platform}/spmm"]["inflight"] == 0
    engine.step([KernelRequest(m, np.ones(m.nnz, np.float32))])
    engine.flush()                  # historical alias still releases
    assert engine.stats()["arenas"]["outstanding_leases"] == 0


def test_interleaved_steps_never_release_other_streams_leases():
    m = _mats(1, seed0=1800)[0]
    engine = SparseKernelEngine(arena_slots=2)
    ones = np.ones(m.nnz, np.float32)
    out, b_done, b_go, errors = {}, threading.Event(), threading.Event(), []

    def stream_b():
        try:
            out["b1"] = engine.step([KernelRequest(m, 2 * ones)])[0]
            b_done.set()
            b_go.wait(timeout=30)
            out["b2"] = engine.step([KernelRequest(m, 5 * ones)])[0]
        except Exception as e:      # pragma: no cover
            errors.append(e)
            b_done.set()

    a1 = engine.step([KernelRequest(m, 1 * ones)])[0]       # slot 1 (A)
    t = threading.Thread(target=stream_b)
    t.start()
    b_done.wait(timeout=30)
    assert not errors
    assert a1.arena_slot and out["b1"].arena_slot           # both slots held
    # stream A steps again: both slots belong to live streams, so A gets the
    # counted un-aliased fallback — it can NOT steal B's slot...
    a2 = engine.step([KernelRequest(m, 3 * ones)])[0]
    assert not a2.arena_slot
    assert engine.stats()["arena_fallbacks"] == 1
    # ...and releasing A's batch-1 lease left B's buffer untouched
    assert np.asarray(out["b1"].matrix.data).max() == 2.0
    assert np.asarray(a2.matrix.data).max() == 3.0
    # B's next step recycles the slot A's hand-off freed, not B's own
    b_go.set()
    t.join(timeout=30)
    assert not errors
    assert out["b2"].arena_slot
    assert np.asarray(out["b2"].matrix.data).max() == 5.0
    engine.release_stream()         # A's stream (main thread)
    # B's thread exited with its step-2 lease outstanding; only the lease
    # count reflects it — A's release never touched it
    assert engine.stats()["arenas"]["outstanding_leases"] == 1


def test_step_failure_rolls_back_leases_and_load():
    reg = default_registry()

    def boom(config, matrix, operand):
        raise RuntimeError("kaboom")

    reg.register(KernelBackend("bad_accel", "spmm",
                               KernelAutotuner(None, cache_size=8), boom))
    # max_retries=0 disables the failover lane: the raise must propagate
    # AND leave the engine consistent (with the default max_retries=1 the
    # request would instead be re-served — covered in test_faults.py)
    engine = SparseKernelEngine(backends=reg, max_retries=0)
    m = _mats(1, seed0=1900)[0]
    operand = np.ones((m.n_cols, 8), np.float32)
    with pytest.raises(RuntimeError, match="kaboom"):
        engine.step([KernelRequest(m, None, "spmm", operand,
                                   platform="bad_accel")])
    s = engine.stats()      # the failed step left nothing leaked behind
    assert s["load"]["bad_accel/spmm"]["inflight"] == 0
    assert s["arenas"]["outstanding_leases"] == 0
    # the engine keeps serving: same pattern, healthy backend, arena slot
    resp = engine.step([KernelRequest(m, None, "spmm", operand,
                                      platform="cpu_ref")])[0]
    assert resp.arena_slot
    engine.release_stream()


# ------------------------------------------------------------- multi-backend

PLATFORMS = ("tpu_interpret", "tpu_pallas", "cpu_ref")


def test_mixed_platform_batch_partitions_and_executes():
    mats = _mats(3, seed0=2000)
    rng = np.random.default_rng(2)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    engine = SparseKernelEngine()
    reqs = [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                          "spmm", rhs, platform=p)
            for m, p in zip(mats, PLATFORMS)]
    resps = engine.step(reqs)
    assert [r.platform for r in resps] == list(PLATFORMS)
    for resp in resps:      # every backend's output matches the oracle
        want = np.asarray(spmm_ref(resp.matrix, rhs))[:, :64]
        np.testing.assert_allclose(np.asarray(resp.output)[:, :64], want,
                                   atol=1e-4)
    s = engine.stats()
    assert set(s["backends"]) == {f"{p}/spmm" for p in PLATFORMS}
    for b in s["backends"].values():
        assert b["requests"] == 1 and b["misses"] == 1 and b["hits"] == 0
        assert b["serve"]["n"] == 1
        assert {"p50_ms", "p99_ms"} <= set(b["serve"])
    engine.flush()


def test_backend_caches_do_not_cross_contaminate():
    m = _mats(1, seed0=2100)[0]
    d = matrix_digest(m)
    engine = SparseKernelEngine()
    reqs = [KernelRequest(m, platform="tpu_interpret"),
            KernelRequest(m, platform="cpu_ref")]
    engine.step(reqs)
    cache_i = engine.backends.get("tpu_interpret", "spmm").tuner.cache
    cache_r = engine.backends.get("cpu_ref", "spmm").tuner.cache
    # same pattern digest, different backend -> independent entries
    assert ("spmm", d) in cache_i and ("spmm", d) in cache_r
    assert cache_i.get(("spmm", d)) is not cache_r.get(("spmm", d))
    n_feat = engine.featurize_calls
    assert n_feat == 2                  # one per backend, none shared
    resps = engine.step(reqs)           # repeats hit per-backend caches
    assert all(r.cache_hit for r in resps)
    assert engine.featurize_calls == n_feat
    s = engine.stats()
    assert s["backends"]["tpu_interpret/spmm"]["hit_rate"] == 0.5
    assert s["backends"]["cpu_ref/spmm"]["hit_rate"] == 0.5
    # per-platform cache occupancy is reported for every backend, not just
    # the default one ("cache" stays the default backend for compat)
    assert s["caches"]["tpu_interpret"]["size"] == 1
    assert s["caches"]["cpu_ref"]["size"] == 1
    assert s["caches"]["tpu_pallas"]["size"] == 0
    assert s["cache"]["size"] == s["caches"]["tpu_interpret"]["size"]
    engine.flush()


def test_unknown_platform_tag_raises_before_serving():
    m = _mats(1, seed0=2200)[0]
    engine = SparseKernelEngine()
    with pytest.raises(KeyError, match="no backend registered"):
        engine.step([KernelRequest(m, platform="gpu_sparse")])
    assert engine.stats()["requests"] == 0      # failed before any work


def test_custom_backend_registration():
    reg = default_registry()
    calls = []

    def run(config, matrix, operand):
        calls.append(config)
        return np.full((1,), 42.0)

    reg.register(KernelBackend("my_accel", "spmm",
                               KernelAutotuner(None, cache_size=8), run))
    engine = SparseKernelEngine(backends=reg)
    m = _mats(1, seed0=2250)[0]
    resp = engine.step([KernelRequest(m, None, "spmm",
                                      np.ones((256, 8), np.float32),
                                      platform="my_accel")])[0]
    assert resp.platform == "my_accel"
    assert calls and np.asarray(resp.output)[0] == 42.0
    engine.flush()


# ------------------------------------------- multi-backend persistence

def test_multi_backend_persist_roundtrip(tmp_path):
    path = tmp_path / "cache.npz"
    mats = _mats(2, seed0=2300)
    engine = SparseKernelEngine(persist_path=path)
    reqs = [KernelRequest(mats[0], platform="tpu_interpret"),
            KernelRequest(mats[0], platform="cpu_ref"),
            KernelRequest(mats[1], platform="tpu_pallas")]
    engine.step(reqs)
    engine.flush()
    engine.save()

    engine2 = SparseKernelEngine(persist_path=path)
    s = engine2.stats()
    assert s["warm_start_entries"] == 3 and s["warm_start_skipped"] == 0
    resps = engine2.step(reqs)
    assert all(r.cache_hit for r in resps)
    assert engine2.featurize_calls == 0     # every backend restored
    # each backend's entries landed in its own cache
    d0, d1 = matrix_digest(mats[0]), matrix_digest(mats[1])
    assert ("spmm", d0) in engine2.backends.get("tpu_interpret",
                                                "spmm").tuner.cache
    assert ("spmm", d0) in engine2.backends.get("cpu_ref",
                                                "spmm").tuner.cache
    assert ("spmm", d1) in engine2.backends.get("tpu_pallas",
                                                "spmm").tuner.cache
    assert ("spmm", d1) not in engine2.backends.get("tpu_interpret",
                                                    "spmm").tuner.cache
    engine2.flush()


def test_legacy_v1_file_warm_starts_default_backend(tmp_path):
    path = tmp_path / "cache.npz"
    mats = _mats(2, seed0=2400)
    kt = KernelAutotuner()
    kt.get_batch(mats)
    save_cache(kt.cache, path, version=1)   # the pre-tag on-disk format

    engine = SparseKernelEngine(persist_path=path)
    assert engine.stats()["warm_start_entries"] == 2
    resps = engine.step([KernelRequest(m) for m in mats])
    assert all(r.cache_hit for r in resps)
    assert engine.featurize_calls == 0
    engine.flush()
    # standalone loaders see v1 entries in the default namespace too
    assert len(load_cache(path)) == 2
    kt2 = KernelAutotuner()
    assert warm_start(kt2, path) == 2


def test_unknown_tag_entries_fall_back_cold(tmp_path):
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=2500)[0]
    kt = KernelAutotuner()
    kt.get(m)
    save_backends({"fpga_exotic": kt.cache}, path)   # orphaned platform tag

    engine = SparseKernelEngine(persist_path=path)
    s = engine.stats()
    assert s["warm_start_entries"] == 0 and s["warm_start_skipped"] == 1
    resp = engine.step([KernelRequest(m)])[0]
    assert not resp.cache_hit           # default backend serves it cold
    engine.flush()


def test_tagless_save_cache_warm_starts_any_default_platform(tmp_path):
    # the compat single-cache API writes unnamespaced entries, so the
    # restoring engine's *own* default backend gets them — including an
    # interpret=False engine whose default is tpu_pallas
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=2700)[0]
    kt = KernelAutotuner()
    kt.get(m)
    save_cache(kt.cache, path)
    engine = SparseKernelEngine(persist_path=path, interpret=False)
    assert engine.default_platform == "tpu_pallas"
    assert engine.stats()["warm_start_entries"] == 1
    resp = engine.step([KernelRequest(m)])[0]
    assert resp.cache_hit and engine.featurize_calls == 0
    engine.flush()


def test_explicit_backend_load_excludes_unnamespaced_entries(tmp_path):
    # unnamespaced (legacy / tag-less) entries make no claim about which
    # backend tuned them, so asking for a specific backend must not
    # cross-contaminate its cache with them
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=2800)[0]
    kt = KernelAutotuner()
    kt.get(m)
    save_cache(kt.cache, path, version=1)
    assert load_cache(path, backend="cpu_ref") == []
    assert len(load_cache(path)) == 1
    kt2 = KernelAutotuner()
    assert warm_start(kt2, path, backend="cpu_ref") == 0
    save_cache(kt.cache, path, backend="cpu_ref")
    assert len(load_cache(path, backend="cpu_ref")) == 1


def test_load_grouped_namespaces_and_counts(tmp_path):
    path = tmp_path / "cache.npz"
    ma, mb = _mats(2, seed0=2600)
    kt_a, kt_b = KernelAutotuner(), KernelAutotuner()
    kt_a.get(ma)
    kt_b.get(mb)
    save_backends({"a": kt_a.cache, "b": kt_b.cache}, path)
    g = load_grouped(path)
    assert set(g.entries) == {"a", "b"} and g.skipped == 0
    assert len(g) == 2
    (key_a, entry_a), = g.entries["a"]
    assert key_a == ("spmm", matrix_digest(ma))
    assert entry_a.config["block_m"] == entry_a.plan.block_m


def test_persist_v3_carries_device_index(tmp_path):
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=3000)[0]
    kt = KernelAutotuner()
    entry = kt.get(m)
    save_cache(kt.cache, path, backend="tpu_interpret")
    (_, restored), = load_grouped(path).entries["tpu_interpret"]
    # the device-scatter index came off disk (no recompute on first device
    # build) and matches the original plan's
    assert restored.plan._flat is not None
    np.testing.assert_array_equal(restored.plan._flat,
                                  entry.plan.flat_index())
    vals = np.random.default_rng(12).normal(size=m.nnz).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(restored.plan.build_device(jnp.asarray(vals)).data),
        np.asarray(entry.build(vals).data))


def test_persist_v2_file_still_restores(tmp_path):
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=3100)[0]
    kt = KernelAutotuner()
    kt.get(m)
    save_cache(kt.cache, path, backend="tpu_interpret", version=2)
    g = load_grouped(path)
    (_, restored), = g.entries["tpu_interpret"]
    assert g.skipped == 0
    assert restored.plan._flat is None      # v2: computed lazily instead
    vals = np.ones(m.nnz, np.float32)
    np.testing.assert_array_equal(
        np.asarray(restored.plan.build_device(jnp.asarray(vals)).data),
        np.asarray(restored.plan.build(vals).data))


def test_persist_tampered_device_index_skipped(tmp_path):
    # an in-range but WRONG device index would silently mis-scatter on the
    # device path only — load validates it against the plan arrays it is
    # derived from and skips the entry
    path = tmp_path / "cache.npz"
    m = _mats(1, seed0=3150)[0]
    kt = KernelAutotuner()
    kt.get(m)
    # version 3: exercises the dindex consistency check itself (in a v4
    # file the per-entry CRC catches the tampering first)
    save_backends({"tpu_interpret": kt.cache}, path, version=3)
    with np.load(path) as data:
        arrays = dict(data.items())
    arrays["e0_dindex"] = np.roll(arrays["e0_dindex"], 1)   # still in range
    np.savez(path, **arrays)
    with pytest.warns(UserWarning, match="inconsistent"):
        g = load_grouped(path)
    assert g.skipped == 1 and len(g) == 0


def test_persist_dtype_mismatch_entry_skipped(tmp_path):
    # a v2/v3 entry whose scatter arrays carry the wrong dtype used to
    # restore fine and then blow up at its first scatter; now it is
    # validated at load and skipped like any other bad entry
    path = tmp_path / "cache.npz"
    mats = _mats(2, seed0=3200)
    kt = KernelAutotuner()
    kt.get_batch(mats)
    # version 3 again: v4's CRC would flag the tamper before the dtype check
    save_backends({"tpu_interpret": kt.cache}, path, version=3)
    with np.load(path) as data:
        arrays = dict(data.items())
    arrays["e0_slot"] = arrays["e0_slot"].astype(np.float32)   # tampered
    np.savez(path, **arrays)
    with pytest.warns(UserWarning, match="dtype"):
        g = load_grouped(path)
    assert g.skipped == 1 and len(g) == 1
    engine = SparseKernelEngine(persist_path=path)
    s = engine.stats()
    assert s["warm_start_entries"] == 1 and s["warm_start_skipped"] == 1


# ----------------------------------------------------------------- telemetry

def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 100):
        h.record(ms / 1e3)
    assert h.n == 10
    assert 0.8e-3 <= h.quantile(0.5) <= 1.6e-3        # bucketed ~1ms
    assert h.quantile(0.99) >= 90e-3
    snap = h.snapshot()
    assert snap["n"] == 10 and snap["max_ms"] == pytest.approx(100.0)


def test_latency_histogram_empty_and_overflow():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    h.record(1e4)                   # beyond the last edge: overflow bucket
    assert h.quantile(0.99) == pytest.approx(1e4)


# ------------------------------------------------------------- slow / stress

@pytest.mark.slow
def test_cache_thread_safety_stress():
    cache = AutotuneCache(maxsize=16)
    mats = _mats(8, seed0=1400, n_rows=128, nnz=400)
    keys = [("spmm", matrix_digest(m)) for m in mats]
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(2000):
                k = keys[rng.integers(len(keys))]
                if cache.get(k) is None:
                    cache.put(k, types.SimpleNamespace(hits=0))
        except Exception as e:      # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(cache) <= 16
    assert cache.hits + cache.misses == 8 * 2000


@pytest.mark.slow
def test_engine_threaded_steps_stress():
    mats = _mats(6, seed0=1500, n_rows=128, nnz=400)
    engine = SparseKernelEngine(arena_slots=4)
    errors = []

    def serve(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(50):
                picks = rng.choice(len(mats), size=2, replace=False)
                engine.step([KernelRequest(mats[i]) for i in picks])
        except Exception as e:      # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=serve, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = engine.stats()
    assert s["requests"] == 4 * 50 * 2
    assert s["featurize_calls"] <= len(mats) * 4   # bounded re-featurization


@pytest.mark.slow
def test_arena_long_rotation_stress():
    m = _mats(1, seed0=1600, n_rows=128, nnz=400)[0]
    plan = plan_from_coo(m.rows, m.cols, (m.n_rows, m.n_cols), block_m=32,
                         assume_unique=True)
    arena = PlanArena(plan, n_slots=2)
    prev = None
    for i in range(500):
        lease = arena.build(float(i + 1) * np.ones(m.nnz, np.float32))
        if prev is not None:
            # previous build intact until released (double-buffer invariant)
            assert np.asarray(prev.matrix.data).max() == float(i)
            prev.release()
        prev = lease
    prev.release()
    assert arena.builds == 500 and arena.overruns == 0
