"""Observability tests: span-tree tracing (``repro.serving.trace``), the
flight recorder's head sampling + tail retention, the structured event
log, the exporters (``repro.serving.export`` — Prometheus text, Chrome
trace, windowed stats deltas), the calibration drift gauge, and the
histogram bucket-export/merge/threading contracts in
``repro.serving.telemetry``.

Engine-level tests reuse the deterministic fault idioms from
``test_faults.py`` (call-indexed ``FaultPlan``, fake clocks, zero/huge
breaker backoffs) so every trace and event assertion replays identically
on any machine.
"""
import json
import threading

import numpy as np
import pytest

from _compat import given, settings, st
from repro.data import generate_matrix
from repro.serving import (DEFAULT_PLATFORM, CostModelRouter, EventLog,
                           FaultPlan, FlightRecorder, HealthConfig,
                           HealthRegistry, KernelRequest, LatencyHistogram,
                           LoadAwareRouter, RouteCalibration,
                           SparseKernelEngine, StaticRouter, chrome_trace,
                           default_registry, inject_faults, load_grouped,
                           parse_prometheus_text, prom_get, prometheus_text,
                           save_backends, stats_delta, truncate_file)
from repro.serving.telemetry import EngineTelemetry
from repro.serving.trace import Span, Trace

TAG = ("tpu_interpret", "spmm")
#: upper-edge quantile error bound: the histogram's bucket edge ratio
BUCKET_RATIO = 10 ** (8 / 71)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mats(n, seed0=0, n_rows=256, nnz=1200):
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    return [generate_matrix(fams[i % 4], seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_rows, target_nnz=nnz) for i in range(n)]


def _requests(mats, rhs=None, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                          "spmm", rhs, **kw) for m in mats]


# ------------------------------------------------------- flight recorder

def _trace(i, status="ok"):
    return Trace(f"t-{i}", 1000.0 + i, status, "spmm", "cpu_ref", "d", i,
                 Span("request", 0.0, 0.001))


def test_recorder_deterministic_head_sampling():
    for rate, n, expect in ((0.0, 50, 0), (1.0, 50, 50), (0.25, 100, 25),
                            (0.1, 95, 9)):
        rec = FlightRecorder(rate)
        took = sum(rec.sample() for _ in range(n))
        # counter-based: exactly floor(n * rate), no RNG, no drift
        assert took == expect == int(n * rate)
        assert rec.snapshot()["sampled_steps"] == expect


def test_recorder_rings_bounded_and_ordered():
    rec = FlightRecorder(1.0, capacity=4, error_capacity=2)
    for i in range(10):
        rec.record(_trace(i), sampled=True, error=i % 3 == 0)
    assert [t.trace_id for t in rec.traces()] == [f"t-{i}" for i in (6, 7, 8, 9)]
    # 0,3,6,9 hit the error ring; capacity 2 keeps the most recent two
    assert [t.trace_id for t in rec.traces(errors=True)] == ["t-6", "t-9"]
    assert [t.trace_id for t in rec.traces(errors=True, n=1)] == ["t-9"]
    s = rec.snapshot()
    assert s["recorded"] == 10 and s["dropped"] == 6 and s["buffered"] == 4
    assert s["error_recorded"] == 4 and s["error_dropped"] == 2


def test_recorder_error_retention_independent_of_sampling():
    rec = FlightRecorder(0.0)           # head sampling fully off
    assert not rec.sample()
    rec.record(_trace(0, "degraded"), error=True)
    assert not rec.traces() and len(rec.traces(errors=True)) == 1


def test_event_log_ring_kinds_and_jsonl(tmp_path):
    clk = FakeClock()
    log = EventLog(capacity=3, clock=clk)
    for i in range(5):
        clk.advance(1.0)
        log.emit("failover" if i % 2 else "drain", n=i)
    assert [e["kind"] for e in log.events()] == ["drain", "failover", "drain"]
    assert [e["n"] for e in log.events(kind="drain")] == [2, 4]
    assert log.snapshot() == {"emitted": 5, "buffered": 3,
                              "by_kind": {"drain": 3, "failover": 2}}
    lines = log.to_jsonl().splitlines()
    assert len(lines) == 3
    for line, ev in zip(lines, log.events()):
        assert json.loads(line) == ev
    path = tmp_path / "events.jsonl"
    log.write(path)
    assert path.read_text() == log.to_jsonl()


# ------------------------------------------------ engine tracing end-to-end

def test_engine_stamps_trace_ids_and_records_span_tree():
    engine = SparseKernelEngine(trace_sample_rate=1.0)
    mats = _mats(3, seed0=20_000)
    resps = engine.step(_requests(mats))
    engine.release_stream()
    assert all(r.trace_id for r in resps)
    assert len(set(r.trace_id for r in resps)) == 3
    traces = {t.trace_id: t for t in engine.traces()}
    for r in resps:
        t = traces[r.trace_id]
        assert t.status == "ok" and t.platform == r.platform
        assert t.digest == r.digest and t.generation == r.generation
        # six pipeline stages + accounting, in execution order, no retry
        assert t.span_names() == ["route", "partition", "score", "build",
                                  "execute", "account"]
        assert t.root.attrs["op"] == "spmm"
        assert t.root.dur >= sum(c.dur for c in t.root.children) * 0.5
        for c in t.root.children:
            assert c.dur >= 0.0 and c.t0 >= 0.0
        d = t.to_dict()
        assert d["trace_id"] == r.trace_id
        assert [c["name"] for c in d["root"]["children"]] == t.span_names()


def test_engine_honors_caller_trace_id():
    engine = SparseKernelEngine(trace_sample_rate=1.0)
    reqs = _requests(_mats(2, seed0=20_100))
    reqs[0].trace_id = "caller-chose-this"
    resps = engine.step(reqs)
    engine.release_stream()
    assert resps[0].trace_id == "caller-chose-this"
    assert resps[1].trace_id != "caller-chose-this"
    assert "caller-chose-this" in {t.trace_id for t in engine.traces()}


def test_engine_rate_zero_records_nothing_healthy():
    engine = SparseKernelEngine()       # trace_sample_rate defaults to 0.0
    resps = engine.step(_requests(_mats(2, seed0=20_200)))
    engine.release_stream()
    assert all(r.trace_id is None for r in resps)
    assert not engine.traces() and not engine.traces(errors=True)
    assert engine.stats()["tracing"]["sampled_steps"] == 0


def test_error_ring_retains_degraded_with_full_span_tree():
    # head sampling OFF + a hard-failing default backend: tail retention
    # must still capture every failed-over request end to end
    reg = default_registry()
    inject_faults(reg, DEFAULT_PLATFORM, "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    mats = _mats(3, seed0=20_300)
    rng = np.random.default_rng(1)
    rhs = rng.normal(size=(256, 32)).astype(np.float32)
    resps = engine.step(_requests(mats, rhs))
    engine.drain()
    assert all(r.degraded and r.trace_id for r in resps)
    ring = {t.trace_id: t for t in engine.traces(errors=True)}
    assert not engine.traces()          # main ring untouched at rate 0
    for r in resps:
        t = ring[r.trace_id]
        assert t.status == "degraded" and t.degraded
        assert t.span_names() == ["route", "partition", "score", "build",
                                  "execute", "retry", "account"]
        retry = t.root.find("retry")
        assert [c.name for c in retry.children] == [
            "retry.partition", "retry.score", "retry.build",
            "retry.execute"]
        assert retry.attrs == {"failed_over_from": DEFAULT_PLATFORM,
                               "attempts": 2}
        assert t.root.attrs["degraded"] is True
        assert t.root.attrs["platform"] == "cpu_ref"


def test_breaker_transitions_land_in_event_log():
    reg = default_registry()
    inject_faults(reg, DEFAULT_PLATFORM, "spmm",
                  FaultPlan.fail_calls(0, 3 + 3))   # kill step + 1 failed probe
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(HealthConfig(consecutive_errors=3,
                                           backoff_s=0.0)))
    mats = _mats(3, seed0=20_400)
    rhs = np.ones((256, 16), np.float32)    # dense operand: really execute
    engine.step(_requests(mats, rhs))   # trips: closed -> open
    engine.step(_requests(mats, rhs))   # failed probe: open->half_open->open
    engine.step(_requests(mats, rhs))   # probe succeeds: -> closed
    engine.drain()
    trans = engine.events.events(kind="breaker_transition")
    tag = f"{DEFAULT_PLATFORM}/spmm"
    assert [(e["from"], e["to"]) for e in trans if e["tag"] == tag] == [
        ("closed", "open"), ("open", "half_open"), ("half_open", "open"),
        ("open", "half_open"), ("half_open", "closed")]
    assert all(e["ts"] > 0 and "failure_rate" in e for e in trans)
    fo = engine.events.events(kind="failover")
    assert fo and fo[0]["moves"] == [f"{DEFAULT_PLATFORM}->cpu_ref"]


def test_persist_quarantine_events(tmp_path):
    from repro.core.autotune import KernelAutotuner
    kt = KernelAutotuner()
    kt.get_batch(_mats(1, seed0=20_500))
    path = tmp_path / "cache.npz"
    save_backends({DEFAULT_PLATFORM: kt.cache}, path)
    truncate_file(path, 0.5)
    events = []
    with pytest.warns(UserWarning):
        assert load_grouped(path, quarantine=True,
                            on_event=lambda k, **f: events.append((k, f))) \
            is None
    kinds = [k for k, _ in events]
    assert kinds == ["persist_load_failure", "persist_quarantined"]
    assert all(f["path"] == str(path) for _, f in events)
    assert events[1][1]["wholesale"] is True

    # and through the engine: warm-start failure lands in engine.events
    save_backends({DEFAULT_PLATFORM: kt.cache}, path)
    truncate_file(path, 0.5)
    with pytest.warns(UserWarning):
        engine = SparseKernelEngine(persist_path=path)
    by_kind = engine.events.snapshot()["by_kind"]
    assert by_kind.get("persist_load_failure") == 1
    assert by_kind.get("persist_quarantined") == 1


def test_warm_start_and_save_events(tmp_path):
    from repro.core.autotune import KernelAutotuner
    kt = KernelAutotuner()
    kt.get_batch(_mats(2, seed0=20_600))
    path = tmp_path / "cache.npz"
    save_backends({DEFAULT_PLATFORM: kt.cache}, path)
    engine = SparseKernelEngine(persist_path=path)
    ws, = engine.events.events(kind="warm_start")
    assert ws["entries"] == 2 and ws["skipped"] == 0
    engine.save()
    sv, = engine.events.events(kind="persist_save")
    assert sv["path"] == str(path)


def test_router_spill_and_sticky_invalidation_events():
    # open circuit -> LoadAwareRouter spills immediately -> router_spill
    engine = SparseKernelEngine(
        router=LoadAwareRouter(StaticRouter(), max_inflight=100),
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    for _ in range(3):
        engine.health.record_failure(TAG)
    engine.step(_requests(_mats(2, seed0=20_700)))
    engine.release_stream()
    spills = engine.events.events(kind="router_spill")
    assert len(spills) == 2
    assert all(e["to"] == "cpu_ref" and e["circuit_open"] for e in spills)

    # health transition invalidates a sticky memo -> sticky_invalidation
    engine2 = SparseKernelEngine(
        router=CostModelRouter(),
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    mats = _mats(2, seed0=20_800)
    engine2.step(_requests(mats))
    engine2.step(_requests(mats))       # memoized: sticky
    for _ in range(3):
        engine2.health.record_failure(TAG)
    engine2.step(_requests(mats))       # memo invalidated, re-decided
    engine2.release_stream()
    inv = engine2.events.events(kind="sticky_invalidation")
    assert len(inv) == 2
    assert all(e["platform"] == DEFAULT_PLATFORM and e["digest"]
               for e in inv)


# ------------------------------------------------------------- exporters

def test_prometheus_text_round_trips_and_matches_stats():
    engine = SparseKernelEngine(trace_sample_rate=1.0)
    for s0 in (21_000, 21_000, 21_100):     # repeats -> hits; new -> misses
        engine.step(_requests(_mats(2, seed0=s0)))
    engine.drain()
    txt = prometheus_text(engine)
    samples = parse_prometheus_text(txt)
    s = engine.stats()
    assert prom_get(samples, "repro_serving_requests_total") == s["requests"]
    assert prom_get(samples, "repro_serving_hits_total") == s["hits"]
    assert prom_get(samples, "repro_serving_hit_rate") \
        == pytest.approx(s["hit_rate"])
    assert prom_get(samples, "repro_serving_routed_requests_total",
                    platform=DEFAULT_PLATFORM) == s["requests"]
    assert prom_get(samples, "repro_serving_breaker_state",
                    tag=f"{DEFAULT_PLATFORM}/spmm", state="closed") == 1
    assert prom_get(samples, "repro_serving_trace_sampled_steps_total") == 3


def test_prometheus_histogram_buckets_match_export_path():
    engine = SparseKernelEngine()
    engine.step(_requests(_mats(2, seed0=21_200)))
    engine.release_stream()
    samples = parse_prometheus_text(prometheus_text(engine))
    for stage in ("route", "execute", "step"):
        hist = engine.telemetry.stage_histograms()[stage]
        buckets = hist.buckets()
        # cumulative, monotone, ending at the sample count...
        assert buckets[-1] == (float("inf"), hist.n)
        assert all(b1[1] >= b0[1] for b0, b1 in zip(buckets, buckets[1:]))
        # ...and every bucket line in the exposition matches exactly
        prom = [(lab["le"], v) for name, lab, v in samples
                if name == "repro_serving_stage_duration_seconds_bucket"
                and lab["stage"] == stage]
        assert len(prom) == len(buckets)
        for (le, v), (edge, cum) in zip(prom, buckets):
            assert v == cum
            if le != "+Inf":
                assert float(le) == pytest.approx(edge)
        assert prom_get(samples, "repro_serving_stage_duration_seconds_count",
                        stage=stage) == hist.n
        assert prom_get(samples, "repro_serving_stage_duration_seconds_sum",
                        stage=stage) == pytest.approx(hist.total)


def test_prometheus_labels_merged_into_every_series():
    """prometheus_text(labels=...) stamps the dict on every sample (the
    shard-label hook) and round-trips: values match the unlabeled render,
    per-series labels still win on clash."""
    engine = SparseKernelEngine()
    engine.step(_requests(_mats(2, seed0=21_300)))
    engine.release_stream()
    plain = parse_prometheus_text(prometheus_text(engine))
    labeled = parse_prometheus_text(
        prometheus_text(engine, labels={"shard": "r7"}))
    assert len(labeled) == len(plain)
    assert all(lab.get("shard") == "r7" for _n, lab, _v in labeled)
    # stripping the injected label recovers the plain exposition for the
    # time-independent series (counters; gauges like ts/latency EMAs move)
    stripped = [(n, {k: v for k, v in lab.items() if k != "shard"}, val)
                for n, lab, val in labeled]
    for (n0, l0, v0), (n1, l1, v1) in zip(plain, stripped):
        assert (n0, l0) == (n1, l1)
        if n0.endswith("_total") or n0.endswith("_bucket"):
            assert v0 == v1
    assert prom_get(labeled, "repro_serving_requests_total", shard="r7") \
        == engine.stats()["requests"]
    # per-series labels win on key clash with the injected base
    from repro.serving.export import _Writer
    w = _Writer("ns", {"shard": "base"})
    w.scalar("x", "gauge", "clash", 1.0, {"shard": "series"})
    assert parse_prometheus_text(w.text()) \
        == [("ns_x", {"shard": "series"}, 1.0)]


def test_prometheus_parser_rejects_malformed():
    for bad in ("no_value_here\n", "name{unclosed 1.0\n",
                'name{k="v" 1.0\n', "name not-a-number\n"):
        with pytest.raises(ValueError):
            parse_prometheus_text(bad)
    assert parse_prometheus_text("# HELP x y\n\nx_total 3\n") \
        == [("x_total", {}, 3.0)]


def test_chrome_trace_schema():
    engine = SparseKernelEngine(trace_sample_rate=1.0)
    engine.step(_requests(_mats(2, seed0=21_300)))
    engine.step(_requests(_mats(2, seed0=21_300)))
    engine.drain()
    doc = json.loads(json.dumps(chrome_trace(engine.traces(),
                                             engine.generation_log())))
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    complete = [e for e in events if e["ph"] == "X"]
    meta = [e for e in events if e["ph"] == "M"]
    assert complete and meta
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["pid"] == 1 and isinstance(e["tid"], int)
        assert e["cat"] == "serving"
    roots = [e for e in complete if e["name"] == "request"]
    assert len(roots) == 4 and all("trace_id" in e["args"] for e in roots)
    gens = [e for e in complete if "in-flight" in e["name"]]
    assert {e["tid"] for e in gens} == {1, 2}   # one row per generation
    assert chrome_trace([]) == {
        "traceEvents": [{"name": "process_name", "ph": "M", "pid": 1,
                         "args": {"name": "repro.serving"}}],
        "displayTimeUnit": "ms"}


def test_stats_delta_hand_computed():
    prev = {"ts": 100.0, "requests": 50, "batches": 5, "hits": 20,
            "misses": 10, "health": {"failovers": 1, "execute_failures": 1},
            "backends": {"a/spmm": {"requests": 30, "hits": 15, "misses": 5}}}
    cur = {"ts": 110.0, "requests": 150, "batches": 15, "hits": 60,
           "misses": 30, "health": {"failovers": 5, "execute_failures": 2},
           "backends": {"a/spmm": {"requests": 90, "hits": 45, "misses": 15}}}
    d = stats_delta(prev, cur)
    assert d["interval_s"] == pytest.approx(10.0)
    assert d["requests"] == 100 and d["requests_per_s"] == pytest.approx(10.0)
    assert d["batches_per_s"] == pytest.approx(1.0)
    # windowed: (60-20) hits over (60-20)+(30-10) served
    assert d["hit_rate"] == pytest.approx(40 / 60)
    assert d["failovers"] == 4 and d["failovers_per_s"] == pytest.approx(0.4)
    assert d["execute_failures"] == 1
    b = d["backends"]["a/spmm"]
    assert b["requests_per_s"] == pytest.approx(6.0)
    assert b["hit_rate"] == pytest.approx(30 / 40)
    # restart (counters went backwards) rebaselines to zero — the window
    # reports the new process's lifetime-so-far, never a negative rate
    d2 = stats_delta(cur, {**prev, "ts": 120.0})
    assert d2["requests"] == 50 and d2["requests_per_s"] == pytest.approx(5.0)
    assert d2["hit_rate"] == pytest.approx(20 / 30)


def test_stats_delta_restart_rebaselines_hit_rate():
    """Regression: a warm-start-restored engine restarts with small
    lifetime counters but a high hit share (the restored cache serves
    repeats as hits).  Per-counter clamping used to zero the hits delta
    while letting misses clear the old baseline, collapsing the windowed
    hit rate; the restart rebaseline reports the restored engine's true
    window, and ratios stay inside [0, 1] for any snapshot pair."""
    prev = {"ts": 100.0, "requests": 500, "batches": 50, "hits": 400,
            "misses": 100,
            "health": {"failovers": 3, "execute_failures": 1},
            "backends": {"a/spmm": {"requests": 500, "hits": 400,
                                    "misses": 100}}}
    cur = {"ts": 110.0, "requests": 45, "batches": 5, "hits": 40,
           "misses": 5, "health": {"failovers": 0, "execute_failures": 0},
           "backends": {"a/spmm": {"requests": 45, "hits": 40,
                                   "misses": 5}}}
    d = stats_delta(prev, cur)
    assert d["requests"] == 45          # rebaselined, not clamped to zero
    assert d["hit_rate"] == pytest.approx(40 / 45)
    assert 0.0 <= d["hit_rate"] <= 1.0
    # restart must not fabricate failover/failure deltas either
    assert d["failovers"] == 0 and d["execute_failures"] == 0
    b = d["backends"]["a/spmm"]
    assert b["requests"] == 45
    assert b["hit_rate"] == pytest.approx(40 / 45)
    assert 0.0 <= b["hit_rate"] <= 1.0


def test_engine_stats_delta_windows():
    engine = SparseKernelEngine()
    engine.step(_requests(_mats(2, seed0=21_400)))
    d1 = engine.stats_delta()           # window: construction -> now
    assert d1["requests"] == 2 and d1["requests_per_s"] > 0
    d2 = engine.stats_delta()           # empty window since d1
    assert d2["requests"] == 0
    engine.step(_requests(_mats(2, seed0=21_400)))   # cache hits now
    d3 = engine.stats_delta()
    assert d3["requests"] == 2 and d3["hit_rate"] == 1.0
    engine.release_stream()


# --------------------------------------------------- calibration drift gauge

def test_calibration_drift_gauge_tracks_regime_shift():
    cal = RouteCalibration(alpha=0.2)
    for _ in range(20):                 # stable regime: 5ms observed
        cal.observe("tpu", 0.005, predicted=1.0, op="spmm")
    stable = cal.drift("tpu")
    assert stable is not None and stable < 0.5
    for _ in range(3):                  # regime shift: latency 4x
        cal.observe("tpu", 0.020, predicted=1.0, op="spmm")
    spiked = cal.drift("tpu")
    assert spiked > stable + 5.0        # gauge spikes with the shift
    assert cal.drift("tpu", op="spmm") > stable + 5.0
    for _ in range(60):                 # calibration re-converges at 20ms
        cal.observe("tpu", 0.020, predicted=1.0, op="spmm")
    settled = cal.drift("tpu")
    assert settled < spiked             # ...and the gauge settles back
    assert cal.drift("tpu", op="never-seen") == cal.drift("tpu")  # fallback
    assert cal.drift("never-seen") is None
    snap = cal.snapshot()["tpu"]
    assert snap["drift_ms"] == pytest.approx(settled)
    assert snap["by_op"]["spmm"]["drift_ms"] \
        == pytest.approx(cal.drift("tpu", op="spmm"))


def test_calibration_drift_surfaces_in_prometheus():
    engine = SparseKernelEngine(router=CostModelRouter())
    mats = _mats(2, seed0=21_500)
    engine.step(_requests(mats))
    engine.step(_requests(mats))
    engine.release_stream()
    samples = parse_prometheus_text(prometheus_text(engine))
    drift = prom_get(samples, "repro_serving_calibration_drift_ms",
                     platform=DEFAULT_PLATFORM, op="")
    assert drift is not None and drift >= 0.0


# ----------------------------------------- histogram properties + threading

@settings(max_examples=40, deadline=None)
@given(data=st.lists(st.floats(min_value=1e-6, max_value=50.0),
                     min_size=1, max_size=60),
       q=st.floats(min_value=0.0, max_value=1.0))
def test_histogram_quantile_tracks_percentile(data, q):
    h = LatencyHistogram()
    for x in data:
        h.record(x)
    true = float(np.percentile(data, q * 100, method="higher"))
    got = h.quantile(q)
    # reported quantile is the containing bucket's upper edge:
    # conservative (>= true) and within one bucket ratio (~29.6%)
    assert got >= true * (1 - 1e-9)
    assert got <= true * BUCKET_RATIO * (1 + 1e-9)


@settings(max_examples=20, deadline=None)
@given(a=st.lists(st.floats(min_value=1e-6, max_value=50.0),
                  min_size=0, max_size=30),
       b=st.lists(st.floats(min_value=1e-6, max_value=50.0),
                  min_size=0, max_size=30),
       c=st.lists(st.floats(min_value=1e-6, max_value=50.0),
                  min_size=1, max_size=30))
def test_histogram_merge_associative_commutative(a, b, c):
    def hist(xs):
        h = LatencyHistogram()
        for x in xs:
            h.record(x)
        return h

    left = hist(a).merge(hist(b)).merge(hist(c))        # (a+b)+c
    right = hist(c).merge(hist(b).merge(hist(a)))       # c+(b+a)
    whole = hist(a + b + c)                             # no sharding at all
    for other in (right, whole):
        assert np.array_equal(left.counts, other.counts)
        assert left.n == other.n
        assert left.total == pytest.approx(other.total)
        assert left.max == other.max
        assert left.buckets() == other.buckets()
        for q in (0.0, 0.5, 0.99, 1.0):
            assert left.quantile(q) == other.quantile(q)


def test_histogram_merge_rejects_mismatched_edges():
    with pytest.raises(ValueError):
        LatencyHistogram().merge(LatencyHistogram(n_buckets=16))


def test_histogram_buckets_cumulative_le_semantics():
    h = LatencyHistogram()
    data = [1e-6, 2e-6, 1e-3, 1e-3, 0.5, 200.0]     # incl. edge + overflow
    for x in data:
        h.record(x)
    for edge, cum in h.buckets():
        assert cum == sum(1 for x in data if x <= edge)
    assert h.buckets()[-1] == (float("inf"), len(data))


def test_histogram_copy_is_independent():
    h = LatencyHistogram()
    h.record(0.01)
    c = h.copy()
    c.record(0.02)
    assert h.n == 1 and c.n == 2
    assert h.edges is c.edges           # immutable edges shared


def test_telemetry_snapshot_under_threaded_mutation():
    tel = EngineTelemetry()
    stop = threading.Event()
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            tel.record_stage("execute", float(rng.uniform(1e-5, 1e-2)))
            tel.record_backend("tpu/spmm", requests=1, hits=1,
                               seconds=float(rng.uniform(1e-5, 1e-2)))
            tel.count(requests=1, hits=1)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(50):             # concurrent polls must never tear
            s = tel.snapshot()
            assert s["requests"] == s["hits"]   # counted atomically together
            assert s["stages"]["execute"]["n"] >= 0
            b = s["backends"].get("tpu/spmm")
            if b:
                assert b["requests"] == b["hits"] >= b["serve"]["n"]
    except Exception as e:              # pragma: no cover - diagnostic
        errors.append(e)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    final = tel.snapshot()
    assert final["requests"] == tel.requests
    assert final["stages"]["execute"]["n"] == tel.stages["execute"].n


def test_snapshot_renders_from_copies_outside_lock():
    tel = EngineTelemetry()
    tel.record_stage("step", 0.01)
    copies = tel.stage_histograms()
    tel.record_stage("step", 0.02)      # mutate after the copy
    assert copies["step"].n == 1        # the copy is a frozen point in time
    assert tel.stages["step"].n == 2
