"""Tests for cost-model-guided backend routing: the staged pipeline's route
stage, the Router policies (Static / CostModel / LoadAware), multi-space
batched scoring (``Autotuner.scores_multi``), online latency calibration,
and the per-backend load counters that drive spilling.
"""
import functools

import numpy as np
import pytest

import jax

from repro.core.autotune import Autotuner, KernelAutotuner
from repro.core.cognate import CostModelConfig, init_cost_model
from repro.core.latent import zero_codec
from repro.data import generate_matrix
from repro.kernels import spmm_ref
from repro.serving import (CostModelRouter, KernelRequest, LoadAwareRouter,
                           RouteCalibration, SparseKernelEngine,
                           StaticRouter)


def _mats(n, seed0=0, n_rows=256, n_cols=256, nnz=1200):
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    return [generate_matrix(fams[i % 4], seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_cols, target_nnz=nnz) for i in range(n)]


@functools.lru_cache(maxsize=1)
def _learned_tuner() -> Autotuner:
    """One small randomly-initialized learned tuner shared by the module —
    routing exercises dispatch structure, not prediction quality."""
    cfg = CostModelConfig(ch_scale=0.125)
    params = init_cost_model(jax.random.PRNGKey(0), cfg)
    return Autotuner("tpu_pallas", "spmm", params, cfg, zero_codec(),
                     resolution=8)


def _engine(router, **kw):
    return SparseKernelEngine(KernelAutotuner(_learned_tuner()),
                              router=router, **kw)


# ------------------------------------------------------ multi-space scoring

def test_scores_multi_matches_scores_batch():
    tuner = _learned_tuner()
    mats = _mats(5, seed0=3000)
    batch = tuner.scores_batch(mats)
    multi = tuner.scores_multi(mats, [tuner.space, tuner.space])
    assert len(multi) == 2
    for scores in multi:
        assert scores.shape == batch.shape
        np.testing.assert_allclose(scores, batch, atol=1e-4)


def test_scores_multi_single_dispatch_and_foreign_space():
    from repro.hw.configspace import spade_space
    tuner = _learned_tuner()
    mats = _mats(4, seed0=3100)
    foreign = spade_space()
    before = tuner.score_dispatches
    own, other = tuner.scores_multi(mats, [tuner.space, foreign])
    assert tuner.score_dispatches == before + 1     # ONE fused dispatch
    assert own.shape == (4, tuner.space.n_configs)
    assert other.shape == (4, foreign.n_configs)
    assert np.isfinite(own).all() and np.isfinite(other).all()


def test_scores_multi_varying_n_cols():
    tuner = _learned_tuner()
    mats = _mats(2, seed0=3200) + _mats(2, seed0=3300, n_cols=512)
    before = tuner.score_dispatches
    (scores,) = tuner.scores_multi(mats, [tuner.space])
    assert tuner.score_dispatches == before + 1
    assert scores.shape == (4, tuner.space.n_configs)
    # per-matrix scores agree with the single-shape batched path
    np.testing.assert_allclose(scores[:2], tuner.scores_batch(mats[:2]),
                               atol=1e-4)


# ----------------------------------------------------------- static routing

def test_static_router_reasons_and_default():
    engine = SparseKernelEngine()
    mats = _mats(2, seed0=3400)
    resps = engine.step([KernelRequest(mats[0]),
                         KernelRequest(mats[1], platform="cpu_ref")])
    assert resps[0].platform == engine.default_platform
    assert resps[0].route_reason == "default"
    assert resps[1].platform == "cpu_ref"
    assert resps[1].route_reason == "explicit"
    routing = engine.stats()["routing"]
    assert routing["decisions"] == {"default": 1, "explicit": 1}
    assert routing["by_platform"][engine.default_platform] == 1
    engine.release_stream()


def test_unknown_platform_fails_at_route_time_naming_backends():
    engine = SparseKernelEngine()
    mats = _mats(2, seed0=3500)
    with pytest.raises(KeyError, match="no backend registered") as ei:
        engine.step([KernelRequest(mats[0]),
                     KernelRequest(mats[1], platform="fpga_exotic")])
    msg = str(ei.value)
    assert "fpga_exotic" in msg
    assert "registered platforms" in msg and "cpu_ref" in msg
    s = engine.stats()      # the mixed batch was rejected before ANY work
    assert s["requests"] == 0
    assert s["stages"]["partition"]["n"] == 0
    assert engine.featurize_calls == 0
    assert all(v["inflight"] == 0 for v in s["load"].values())


# ------------------------------------------------------- cost-model routing

def test_cost_model_router_single_dispatch_and_install():
    router = CostModelRouter()
    engine = _engine(router)
    tuner = _learned_tuner()
    mats = _mats(6, seed0=3600)
    before = tuner.score_dispatches
    resps = engine.step([KernelRequest(m) for m in mats])
    # every untagged miss was scored against ALL candidate backends in ONE
    # batched dispatch — and the winning config was installed from it, so
    # the step cost exactly one cost-model round-trip total
    assert router.dispatches == 1
    assert tuner.score_dispatches == before + 1
    assert router.scored_patterns == len(mats)
    assert all(r.route_reason == "cost_model" for r in resps)
    s = engine.stats()
    assert s["routing"]["decisions"] == {"cost_model": len(mats)}
    assert s["routing"]["config_installs"] == len(mats)
    assert s["featurize_calls"] == 0    # no second scoring in the engine
    # routed platform's calibration now holds observed-vs-predicted EMAs
    plat = resps[0].platform
    cal = s["routing"]["calibration"][plat]
    assert cal["n"] == len(mats)
    assert np.isfinite(cal["offset"])
    engine.release_stream()


def test_cost_model_router_sticky_repeat_no_redispatch():
    router = CostModelRouter()
    # warm_lane=False: this test asserts the *router's* sticky memo serves
    # the repeat step; the warm lane would replay it before routing runs
    engine = _engine(router, warm_lane=False)
    mats = _mats(3, seed0=3700)
    first = engine.step([KernelRequest(m) for m in mats])
    second = engine.step([KernelRequest(m) for m in mats])
    assert router.dispatches == 1                   # memoized routing
    assert [r.platform for r in second] == [r.platform for r in first]
    assert all(r.route_reason == "sticky" for r in second)
    assert all(r.cache_hit for r in second)
    engine.release_stream()


def test_cost_model_router_follows_calibrated_latency():
    router = CostModelRouter()
    engine = _engine(router)
    cal = engine.telemetry.calibration
    # observe cpu_ref as dramatically faster than both pallas platforms
    for _ in range(30):
        cal.observe("cpu_ref", 1e-6)
        cal.observe("tpu_interpret", 0.5)
        cal.observe("tpu_pallas", 0.5)
    resps = engine.step([KernelRequest(m) for m in _mats(4, seed0=3800)])
    assert all(r.platform == "cpu_ref" for r in resps)
    assert all(r.route_reason == "cost_model" for r in resps)
    engine.release_stream()


def test_cost_model_router_priors_and_unscored_default():
    # cold (no calibration): knob-free cpu_ref has neither a model score nor
    # an observation, so it stays out of rotation by default...
    engine = _engine(CostModelRouter())
    resps = engine.step([KernelRequest(m) for m in _mats(2, seed0=3900)])
    assert all(r.platform in ("tpu_interpret", "tpu_pallas") for r in resps)
    engine.release_stream()
    # ...but an explicit prior can pull it in cold
    engine2 = _engine(CostModelRouter(priors={"cpu_ref": -1e6}))
    resps2 = engine2.step([KernelRequest(m) for m in _mats(2, seed0=3900)])
    assert all(r.platform == "cpu_ref" for r in resps2)
    engine2.release_stream()


def test_cost_model_router_mixed_explicit_passthrough():
    router = CostModelRouter()
    engine = _engine(router)
    mats = _mats(2, seed0=4000)
    resps = engine.step([KernelRequest(mats[0], platform="cpu_ref"),
                         KernelRequest(mats[1])])
    assert resps[0].platform == "cpu_ref"
    assert resps[0].route_reason == "explicit"
    assert resps[1].route_reason == "cost_model"
    engine.release_stream()


def test_cost_model_router_explore_probes_least_observed():
    router = CostModelRouter(explore_every=2)
    engine = _engine(router)
    resps = engine.step([KernelRequest(m) for m in _mats(6, seed0=4100)])
    reasons = [r.route_reason for r in resps]
    assert reasons.count("explore") == 3            # every 2nd decision
    # probes reach backends the argmin would starve (e.g. cold cpu_ref)
    assert any(r.platform == "cpu_ref" for r in resps
               if r.route_reason == "explore")
    engine.release_stream()


def test_cost_model_routed_outputs_match_reference():
    rng = np.random.default_rng(5)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    engine = _engine(CostModelRouter())
    reqs = [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                          "spmm", rhs) for m in _mats(3, seed0=4200)]
    for resp in engine.step(reqs):
        want = np.asarray(spmm_ref(resp.matrix, rhs))[:, :64]
        np.testing.assert_allclose(np.asarray(resp.output)[:, :64], want,
                                   atol=1e-4)
    engine.release_stream()


# ------------------------------------------------------- load-aware routing

def test_load_aware_router_spills_within_batch():
    # spill_after=1: immediate spill (no hysteresis), the sharpest assertion
    router = LoadAwareRouter(StaticRouter(), max_inflight=4, spill_after=1)
    engine = SparseKernelEngine(router=router)
    mats = _mats(10, seed0=4300)
    resps = engine.step([KernelRequest(m) for m in mats])
    platforms = [r.platform for r in resps]
    assert platforms[:4] == [engine.default_platform] * 4
    assert platforms[4:] == ["cpu_ref"] * 6         # overflow spilled
    assert [r.route_reason for r in resps[4:]] == ["spill"] * 6
    s = engine.stats()
    assert s["routing"]["spills"] == 6 and router.spills == 6
    assert s["load"][f"{engine.default_platform}/spmm"]["inflight"] == 4
    assert s["load"]["cpu_ref/spmm"]["inflight"] == 6
    engine.release_stream()
    assert all(v["inflight"] == 0
               for v in engine.stats()["load"].values())


def test_load_aware_router_spills_across_steps_until_leases_release():
    # synthetic saturation: step N's leases are outstanding during step N+1
    # (double-buffer hand-off), so a saturated backend spills the next batch
    router = LoadAwareRouter(StaticRouter(), max_inflight=2, spill_after=1)
    engine = SparseKernelEngine(router=router)
    mats = _mats(4, seed0=4400)
    first = engine.step([KernelRequest(m) for m in mats[:2]])
    assert [r.platform for r in first] == [engine.default_platform] * 2
    second = engine.step([KernelRequest(m) for m in mats[2:]])
    assert [r.platform for r in second] == ["cpu_ref"] * 2
    assert engine.stats()["routing"]["spills"] == 2
    # draining the stream frees the depth; traffic returns to the default
    engine.release_stream()
    third = engine.step([KernelRequest(m) for m in mats[:2]])
    assert [r.platform for r in third] == [engine.default_platform] * 2
    engine.release_stream()


def test_load_aware_spilled_outputs_match_reference():
    rng = np.random.default_rng(6)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    engine = SparseKernelEngine(
        router=LoadAwareRouter(StaticRouter(), max_inflight=1,
                               spill_after=1))
    reqs = [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                          "spmm", rhs) for m in _mats(3, seed0=4500)]
    resps = engine.step(reqs)
    assert [r.platform for r in resps] == \
        [engine.default_platform, "cpu_ref", "cpu_ref"]
    for resp in resps:
        want = np.asarray(spmm_ref(resp.matrix, rhs))[:, :64]
        np.testing.assert_allclose(np.asarray(resp.output)[:, :64], want,
                                   atol=1e-4)
    engine.release_stream()


def test_load_aware_wraps_cost_model_router():
    inner = CostModelRouter(priors={"tpu_interpret": -1e6})
    router = LoadAwareRouter(inner, max_inflight=3, spill_after=1)
    engine = _engine(router)
    resps = engine.step([KernelRequest(m) for m in _mats(5, seed0=4600)])
    platforms = [r.platform for r in resps]
    assert platforms[:3] == ["tpu_interpret"] * 3   # inner's pick
    assert platforms[3:] == ["cpu_ref"] * 2         # then load shed
    reasons = [r.route_reason for r in resps]
    assert reasons[:3] == ["cost_model"] * 3
    assert reasons[3:] == ["spill"] * 2
    engine.release_stream()


def test_load_aware_hysteresis_suppresses_transient_burst():
    # default spill_after=2: the FIRST saturated decision keeps its
    # assignment (counted), the second consecutive one spills
    router = LoadAwareRouter(StaticRouter(), max_inflight=2)
    engine = SparseKernelEngine(router=router)
    mats = _mats(4, seed0=4800)
    resps = engine.step([KernelRequest(m) for m in mats])
    platforms = [r.platform for r in resps]
    assert platforms == [engine.default_platform] * 3 + ["cpu_ref"]
    assert router.spill_hysteresis == 1 and router.spills == 1
    s = engine.stats()
    assert s["routing"]["spill_hysteresis"] == 1
    assert s["routing"]["spills"] == 1
    engine.release_stream()


def test_load_aware_hysteresis_streak_resets_below_threshold():
    router = LoadAwareRouter(StaticRouter(), max_inflight=2)
    engine = SparseKernelEngine(router=router)
    mats = _mats(6, seed0=4900)
    # burst 1: exactly one saturated decision -> suppressed, no spill
    engine.step([KernelRequest(m) for m in mats[:3]])
    assert router.spills == 0 and router.spill_hysteresis == 1
    # back below threshold: the streak resets...
    engine.release_stream()
    engine.step([KernelRequest(m) for m in mats[3:5]])
    engine.release_stream()
    # ...so the next single-decision burst is again suppressed, not spilled
    resps = engine.step([KernelRequest(m) for m in mats[:3]])
    assert [r.platform for r in resps] == [engine.default_platform] * 3
    assert router.spills == 0 and router.spill_hysteresis == 2
    engine.release_stream()


# ----------------------------------------------------------------- plumbing

def test_route_calibration_offsets():
    cal = RouteCalibration(alpha=0.5)
    assert cal.offset("x") is None
    cal.observe("x", 0.010, predicted=2.0)          # 10 ms
    assert cal.n_observed("x") == 1
    assert cal.offset("x") == pytest.approx(10.0 - 2.0)
    cal.observe("x", 0.020, predicted=4.0)
    snap = cal.snapshot()["x"]
    assert snap["n"] == 2
    assert snap["observed_ms"] == pytest.approx(15.0)   # EMA, alpha .5
    assert snap["predicted"] == pytest.approx(3.0)
    # latency-only observations (spills, sticky routes) still calibrate
    cal.observe("y", 0.001)
    assert cal.offset("y") == pytest.approx(1.0)


def test_route_calibration_per_op_ledger():
    cal = RouteCalibration(alpha=0.5)
    cal.observe("x", 0.010, op="spmm")
    cal.observe("x", 0.030, op="sddmm")
    # per-(platform, op) offsets diverge; the aggregate EMAs both samples
    assert cal.offset("x", "spmm") == pytest.approx(10.0)
    assert cal.offset("x", "sddmm") == pytest.approx(30.0)
    assert cal.offset("x") == pytest.approx(20.0)       # EMA .5: 10 -> 20
    assert cal.n_observed("x") == 2
    assert cal.n_observed("x", "spmm") == 1
    # an op never observed on a measured platform falls back to aggregate
    assert cal.offset("x", "conv") == pytest.approx(20.0)
    assert cal.offset("z", "spmm") is None
    # snapshot keeps the aggregate per-platform shape, nesting op detail
    snap = cal.snapshot()["x"]
    assert snap["n"] == 2
    assert snap["by_op"]["spmm"]["observed_ms"] == pytest.approx(10.0)
    assert snap["by_op"]["sddmm"]["observed_ms"] == pytest.approx(30.0)


def test_engine_feeds_per_op_calibration():
    engine = SparseKernelEngine()
    mats = _mats(2, seed0=5000)
    engine.step([KernelRequest(mats[0], op="spmm"),
                 KernelRequest(mats[1], op="sddmm")])
    cal = engine.stats()["routing"]["calibration"][engine.default_platform]
    assert set(cal["by_op"]) == {"spmm", "sddmm"}
    assert cal["n"] == 2
    engine.release_stream()


def test_route_stage_histogram_records():
    engine = SparseKernelEngine()
    engine.step([KernelRequest(m) for m in _mats(2, seed0=4700)])
    stages = engine.stats()["stages"]
    for name in ("route", "partition", "score", "build", "execute", "step"):
        assert stages[name]["n"] == 1
    engine.release_stream()
