"""Distributed-runtime tests: sharding rules, train/serve steps, checkpoint
manager (atomic, rolling, elastic), gradient compression, pipeline parallel,
and SSM consistency — all on the host mesh (1 CPU device here, but the code
paths are the production ones)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_by_name, settings
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     param_shardings, spec_for_leaf)
from repro.train.steps import TrainStepConfig, init_optimizer, make_train_step


def test_spec_rules():
    mesh = make_host_mesh()
    # embed: vocab on tp, d_model on fsdp — degenerate mesh sizes still valid
    s = spec_for_leaf(mesh, "embed/table", (512, 128))
    assert isinstance(s, P)
    # norms replicated
    s = spec_for_leaf(mesh, "layers/ln1/scale", (4, 128))
    assert all(x is None for x in s)


def test_host_mesh_model_axis_validation():
    """model_axis outside [1, n_devices] must raise a ValueError naming
    both values — not build a zero-extent mesh or divide by zero."""
    n = len(jax.devices())
    for bad in (0, -1, n + 1):
        with pytest.raises(ValueError) as exc:
            make_host_mesh(model_axis=bad)
        msg = str(exc.value)
        assert f"model_axis={bad}" in msg
        assert str(n) in msg
    # the full valid range still builds
    mesh = make_host_mesh(model_axis=n)
    assert mesh.shape["model"] == n


def test_replica_devices_covers_data_axis():
    """replica_devices gives one distinct placement slot per data slice."""
    from repro.parallel.sharding import replica_devices
    mesh = make_host_mesh()
    devs = replica_devices(mesh)
    assert len(devs) == mesh.shape["data"]
    assert len(set(devs)) == len(devs)


def test_spec_rules_production_mesh_shapes():
    """Verify divisibility-driven drops on a production-like abstract mesh."""
    import jax.sharding as shd
    devs = np.array(jax.devices() * 256).reshape(16, 16)[:1, :1]
    # build a fake mesh via Mesh of repeated device is invalid; instead use
    # the single-device mesh and check the resolver's divisibility logic via
    # _resolve directly.
    from repro.hw import configspace  # noqa - unrelated, keep imports clean
    from repro.parallel import sharding as sh
    mesh = make_host_mesh()
    # dim not divisible by axis size 1 never drops (1 divides everything)
    s = sh.spec_for_leaf(mesh, "mlp/wi", (48, 4096, 11008))
    assert len(s) == 3


def test_train_step_runs_and_checkpoints(tmp_path):
    from repro.checkpoint import CheckpointManager
    arch, model = build_by_name("yi-9b", reduced=True)
    shape = ShapeConfig("t", 64, 4, "train")
    cfg = TrainStepConfig(remat=False, total_steps=10, warmup_steps=1)
    step = make_train_step(model, cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_optimizer(params, cfg)
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "targets": jnp.ones((4, 64), jnp.int32)}
    jstep = jax.jit(step)
    p1, o1, m1 = jstep(params, opt, batch)
    p2, o2, m2 = jstep(p1, o1, batch)
    assert float(m2["loss"]) < float(m1["loss"])
    assert int(o2["step"]) == 2

    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"params": p2, "opt": o2})
    assert mgr.all_steps() == [2, 3]                   # rolling retention
    restored = mgr.restore({"params": p2, "opt": o2})
    r, o = restored["params"], restored["opt"]
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(r)[0]),
        np.asarray(jax.tree_util.tree_leaves(p2)[0]))
    assert int(o["step"]) == 2


def test_checkpoint_atomicity(tmp_path):
    """A stray .tmp dir (simulated crash) must be invisible to restore."""
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((4,))}
    mgr.save(5, state)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert mgr.latest_step() == 5
    out = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))


def test_grad_accumulation_matches_full_batch():
    arch, model = build_by_name("yi-9b", reduced=True)
    batch = {"tokens": jnp.arange(4 * 32, dtype=jnp.int32).reshape(4, 32) % 100,
             "targets": jnp.ones((4, 32), jnp.int32)}
    cfg1 = TrainStepConfig(remat=False, accum_steps=1, total_steps=10,
                           warmup_steps=1)
    cfg2 = TrainStepConfig(remat=False, accum_steps=2, total_steps=10,
                           warmup_steps=1)
    params = model.init(jax.random.PRNGKey(0))
    p1, _, m1 = jax.jit(make_train_step(model, cfg1))(
        params, init_optimizer(params, cfg1), batch)
    p2, _, m2 = jax.jit(make_train_step(model, cfg2))(
        params, init_optimizer(params, cfg2), batch)
    # loss identical; updated params near-identical (fp tolerance)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(l1, l2))
    assert worst < 0.05


def test_gradient_compression_error_feedback():
    from repro.optim.compression import compress, decompress
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    q, s, resid = compress(g)
    deq = decompress(q, s)
    rel = float(jnp.abs(deq["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
    assert rel < 0.02                         # int8 quantization error bound
    # error feedback: residual carries the rounding error
    q2, s2, resid2 = compress(g, resid)
    deq2 = decompress(q2, s2)
    # two-step average closer to g than one-step (variance reduction)
    err1 = float(jnp.abs(deq["w"] - g["w"]).mean())
    err2 = float(jnp.abs((deq["w"] + deq2["w"]) / 2 - g["w"]).mean())
    assert err2 < err1


def test_batch_and_cache_shardings():
    arch, model = build_by_name("yi-9b", reduced=True)
    mesh = make_host_mesh()
    specs = model.input_specs(ShapeConfig("t", 64, 4, "train"))
    bs = batch_shardings(mesh, specs)
    assert set(bs) == set(specs)
    cache = jax.eval_shape(lambda: model.init_cache(4, 128))
    cs = cache_shardings(mesh, cache, 4)
    assert jax.tree_util.tree_structure(cs) == jax.tree_util.tree_structure(cache)


def test_serve_prefill_consistency_dense():
    """Cached decode must reproduce the parallel forward logits (yi-9b)."""
    arch, model = build_by_name("yi-9b", reduced=True)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 0, arch.vocab)
    logits_par = model.prefill_step(params, {"tokens": toks})
    cache = model.init_cache(2, 8)
    for t in range(6):
        logits_seq, cache = model.serve_step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=0.08, atol=0.08)
