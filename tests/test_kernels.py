"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles in repro.kernels.ref (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _compat import given, settings, st

from repro.kernels import ops


def _rand_sparse(rng, m, k, density):
    return ((rng.random((m, k)) < density) *
            rng.normal(size=(m, k))).astype(np.float32)


# ------------------------------------------------------------ shape sweeps

@pytest.mark.parametrize("block_m", [8, 32, 64])
@pytest.mark.parametrize("n_major", [True, False])
def test_spmm_block_sweep(block_m, n_major):
    rng = np.random.default_rng(block_m)
    dense = _rand_sparse(rng, 128, 256, 0.07)
    a = ops.bsr_from_dense(dense, block_m=block_m)
    b = rng.normal(size=(256, 128)).astype(np.float32)
    got = np.asarray(ops.spmm(a, jnp.asarray(b), n_major=n_major))
    want = np.asarray(ops.spmm_ref(a, jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", [(32, 128, 128), (96, 384, 256),
                                   (160, 128, 512)])
def test_spmm_shape_sweep(shape):
    m, k, n = shape
    rng = np.random.default_rng(m + k)
    a = ops.bsr_from_dense(_rand_sparse(rng, m, k, 0.05), block_m=32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(ops.spmm(a, jnp.asarray(b)))
    want = np.asarray(ops.spmm_ref(a, jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    rng = np.random.default_rng(7)
    a = ops.bsr_from_dense(_rand_sparse(rng, 64, 256, 0.08), block_m=32,
                           dtype=dtype)
    b = jnp.asarray(rng.normal(size=(256, 128)), dtype)
    got = np.asarray(ops.spmm(a, b), np.float32)
    want = np.asarray(ops.spmm_ref(a, b), np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_spmm_vs_dense_matmul():
    """BSR path must agree with a plain dense matmul on the padded operand."""
    rng = np.random.default_rng(3)
    m, k, n = 100, 200, 96          # deliberately unaligned
    dense = _rand_sparse(rng, m, k, 0.1)
    a = ops.bsr_from_dense(dense, block_m=32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(ops.spmm(a, jnp.asarray(b), block_n=32))
    padded = np.zeros(a.shape, np.float32)
    padded[:m, :k] = dense
    want = padded @ np.pad(b, ((0, a.shape[1] - k), (0, 0)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_spmm_empty_rows():
    """Block-rows with no nonzeros must produce exact zeros (pad blocks)."""
    rng = np.random.default_rng(9)
    dense = np.zeros((96, 256), np.float32)
    dense[:32] = _rand_sparse(rng, 32, 256, 0.2)   # only first block-row
    a = ops.bsr_from_dense(dense, block_m=32)
    b = rng.normal(size=(256, 128)).astype(np.float32)
    out = np.asarray(ops.spmm(a, jnp.asarray(b)))
    assert np.abs(out[32:]).max() == 0.0
    np.testing.assert_allclose(out[:32], dense[:32] @ b, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_k", [64, 128])
@pytest.mark.parametrize("block_m", [16, 32])
def test_sddmm_sweep(block_k, block_m):
    rng = np.random.default_rng(block_k + block_m)
    m, kd, n = 64, 256, 256
    mask = (rng.random((m, n)) < 0.1).astype(np.float32)
    mk = ops.bsr_from_dense(mask, block_m=block_m)
    b = rng.normal(size=(m, kd)).astype(np.float32)
    c = rng.normal(size=(kd, n)).astype(np.float32)
    got = np.asarray(ops.sddmm(mk, jnp.asarray(b), jnp.asarray(c),
                               block_k=block_k))
    want = np.asarray(ops.sddmm_ref(mk, jnp.asarray(b), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_sddmm_respects_mask():
    rng = np.random.default_rng(11)
    mask = (rng.random((64, 128)) < 0.05).astype(np.float32)
    mk = ops.bsr_from_dense(mask, block_m=32)
    b = rng.normal(size=(64, 128)).astype(np.float32)
    c = rng.normal(size=(128, 128)).astype(np.float32)
    out = np.asarray(ops.sddmm(mk, jnp.asarray(b), jnp.asarray(c)))
    md = np.asarray(mk.data)
    assert np.all(out[md == 0] == 0.0)


# --------------------------------------------------------------- property

@settings(max_examples=10, deadline=None)
@given(density=st.floats(0.01, 0.3),
       seed=st.integers(0, 2**16),
       block_m=st.sampled_from([8, 32]))
def test_spmm_property(density, seed, block_m):
    """For random patterns/densities the kernel equals the oracle."""
    rng = np.random.default_rng(seed)
    dense = _rand_sparse(rng, 64, 128, density)
    a = ops.bsr_from_dense(dense, block_m=block_m)
    b = rng.normal(size=(128, 128)).astype(np.float32)
    got = np.asarray(ops.spmm(a, jnp.asarray(b)))
    want = np.asarray(ops.spmm_ref(a, jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_spmm_linearity_property(seed):
    """spmm(a, b1 + b2) == spmm(a, b1) + spmm(a, b2) (linearity invariant)."""
    rng = np.random.default_rng(seed)
    a = ops.bsr_from_dense(_rand_sparse(rng, 64, 128, 0.1), block_m=32)
    b1 = rng.normal(size=(128, 128)).astype(np.float32)
    b2 = rng.normal(size=(128, 128)).astype(np.float32)
    s = np.asarray(ops.spmm(a, jnp.asarray(b1 + b2)))
    s1 = np.asarray(ops.spmm(a, jnp.asarray(b1)))
    s2 = np.asarray(ops.spmm(a, jnp.asarray(b2)))
    np.testing.assert_allclose(s, s1 + s2, rtol=1e-4, atol=1e-3)
