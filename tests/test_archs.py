"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.models import ARCH_IDS, build_by_name


def _batch_for(model, shape, key):
    specs = model.input_specs(shape)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jnp.ones(v.shape, jnp.int32)
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_arch_forward_and_decode(name):
    arch, model = build_by_name(name, reduced=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    shape = SHAPES["train_4k"].reduced(seq=64, batch=2)
    batch = _batch_for(model, shape, key)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))

    cache = model.init_cache(2, 128)
    logits, cache2 = jax.jit(model.serve_step)(
        params, cache, jnp.ones((2,), jnp.int32))
    assert logits.shape == (2, arch.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache2["pos"][0]) == 1
    # second step advances
    logits3, cache3 = jax.jit(model.serve_step)(
        params, cache2, jnp.ones((2,), jnp.int32))
    assert int(cache3["pos"][0]) == 2


@pytest.mark.parametrize("name", ["yi-9b", "granite-moe-3b-a800m", "xlstm-350m"])
def test_arch_train_step_reduces_loss(name):
    """A few SGD steps on a fixed batch must reduce the loss (gradients flow
    through every block type: dense attn, MoE dispatch, recurrence)."""
    arch, model = build_by_name(name, reduced=True)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    shape = SHAPES["train_4k"].reduced(seq=32, batch=2)
    batch = _batch_for(model, shape, key)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(model.train_loss, has_aux=True)(p, batch)
        p = jax.tree_util.tree_map(
            lambda w, gr: (w - 0.3 * gr.astype(jnp.float32)).astype(w.dtype)
            if jnp.issubdtype(w.dtype, jnp.floating) else w, p, g)
        return p, l

    losses = []
    for _ in range(5):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


def test_prefill_matches_decode_xlstm():
    """Recurrent decode must agree with the parallel (chunked) prefill path —
    the chunked GLA and the step recurrence are the same operator."""
    arch, model = build_by_name("xlstm-350m", reduced=True)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 8), 0, arch.vocab)
    # prefill logits at final position
    logits_par = model.prefill_step(params, {"tokens": toks})
    # sequential decode over the same tokens
    cache = model.init_cache(1, 16)
    for t in range(8):
        logits_seq, cache = model.serve_step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=0.1, atol=0.15)
