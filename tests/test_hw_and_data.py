"""Substrate tests: matrix generation, featurization, config spaces, mapping
functions, and the analytical platform models (+ hypothesis invariants)."""
import numpy as np
import pytest
from _compat import given, settings, st

from repro.data import generate_matrix, density_pyramid, matrix_stats, FAMILIES
from repro.data.features import STAT_NAMES
from repro.hw import get_platform, PLATFORMS
from repro.hw import mapping
from repro.hw.mapping import UNIFIED_DIM, encode_unified


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_matrix_generation(family):
    m = generate_matrix(family, seed=3, n_rows=512, n_cols=512,
                        target_nnz=4000)
    assert m.nnz > 0
    assert m.rows.max() < m.n_rows and m.cols.max() < m.n_cols
    # sorted + deduplicated
    key = m.rows.astype(np.int64) * m.n_cols + m.cols
    assert np.all(np.diff(key) > 0)


def test_pyramid_shape_and_range():
    m = generate_matrix("powerlaw", seed=5)
    p = density_pyramid(m, 32)
    assert p.shape == (4, 32, 32)
    assert np.isfinite(p).all()
    assert (p >= 0).all()
    assert p[1].max() <= 1.0   # presence channel is binary


def test_stats_vector():
    m = generate_matrix("banded", seed=7, n_rows=1024, n_cols=1024)
    s = matrix_stats(m)
    assert s.shape == (len(STAT_NAMES),)
    d = dict(zip(STAT_NAMES, s))
    assert d["bandwidth"] < 0.2          # banded => near-diagonal
    assert np.isfinite(s).all()


def test_spade_space_is_paper_exact():
    sp = get_platform("spade").space
    assert sp.n_configs == 256           # paper §4.1
    assert sorted(set(sp.params["row_panels"])) == [4, 32, 256, 2048]
    assert sorted(set(sp.params["col_panels"])) == [-1, 1024, 16384, 65536]
    assert sorted(set(sp.params["split"])) == [32, 256]


def test_unified_encoding_dims():
    for name in PLATFORMS:
        sp = get_platform(name).space
        h = sp.homogeneous(4096)
        assert h.shape == (sp.n_configs, UNIFIED_DIM)   # 53, Table 6
        # each of the 7 loop slots is a valid one-hot
        slots = h[:, 3:52].reshape(-1, 7, 7)
        np.testing.assert_allclose(slots.sum(-1), 1.0)


def test_phi_spade_appendix_e_example():
    """App. E: (row=4, col=1024, split(idx)->32, b=0) ->
    i,j,k = 4,1024,32 and order [k2,k3,i2,j2,i1,j1,k1]."""
    I, J, K, order = mapping.phi_spade(
        np.array([4]), np.array([1024]), np.array([32]), np.array([0]), 65536)
    assert (I[0], J[0], K[0]) == (4, 1024, 32)
    names = [mapping.LOOP_NAMES[i] for i in order[0]]
    assert names == ["k2", "k3", "i2", "j2", "i1", "j1", "k1"]
    # barrier flips i2/j2 (paper §3.2)
    _, _, _, order_b = mapping.phi_spade(
        np.array([4]), np.array([1024]), np.array([32]), np.array([1]), 65536)
    names_b = [mapping.LOOP_NAMES[i] for i in order_b[0]]
    assert names_b == ["k2", "k3", "j2", "i2", "i1", "j1", "k1"]


def test_pi_a1_inserts_k3_after_k2():
    out = mapping.pi_a1([0, 2, 4, 1, 3, 5])
    assert out.index(mapping.K3) == out.index(mapping.K2) + 1
    assert len(out) == 7


@pytest.mark.parametrize("platform", PLATFORMS)
@pytest.mark.parametrize("op", ["spmm", "sddmm"])
def test_platform_runtimes(platform, op):
    p = get_platform(platform)
    m = generate_matrix("rmat", seed=11, n_rows=2048, n_cols=2048,
                        target_nnz=30000)
    rt = p.runtime(matrix_stats(m), op, n_cols=m.n_cols)
    assert rt.shape == (p.space.n_configs,)
    assert np.isfinite(rt).all() and (rt > 0).all()
    # configuration matters: nontrivial spread
    assert rt.max() / rt.min() > 1.05


def test_platform_determinism_and_noise():
    p = get_platform("spade")
    m = generate_matrix("uniform", seed=13)
    s = matrix_stats(m)
    a = p.runtime(s, "spmm", matrix_key=5, n_cols=m.n_cols)
    b = p.runtime(s, "spmm", matrix_key=5, n_cols=m.n_cols)
    np.testing.assert_array_equal(a, b)                 # deterministic
    c = p.runtime(s, "spmm", matrix_key=5, n_cols=m.n_cols, noise=False)
    assert np.abs(np.log(a / c)).mean() < 0.2           # noise is mild


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       family=st.sampled_from(sorted(FAMILIES)))
def test_runtime_positive_property(seed, family):
    """Platform models must stay positive/finite over the input family mix."""
    m = generate_matrix(family, seed=seed, n_rows=512, n_cols=512,
                        target_nnz=5000)
    rt = get_platform("spade").runtime(matrix_stats(m), "spmm",
                                       n_cols=m.n_cols, noise=False)
    assert np.isfinite(rt).all() and (rt > 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_more_work_costs_more_property(seed):
    """2x the nnz (same structure family/size) should not be cheaper at the
    per-matrix optimum — a monotonicity invariant of the cost models."""
    m1 = generate_matrix("uniform", seed=seed, n_rows=1024, n_cols=1024,
                         target_nnz=8000)
    m2 = generate_matrix("uniform", seed=seed, n_rows=1024, n_cols=1024,
                         target_nnz=32000)
    p = get_platform("spade")
    r1 = p.runtime(matrix_stats(m1), "spmm", n_cols=1024, noise=False).min()
    r2 = p.runtime(matrix_stats(m2), "spmm", n_cols=1024, noise=False).min()
    assert r2 >= r1 * 0.9
