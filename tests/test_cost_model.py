"""COGNATE cost-model tests: components, losses, metrics, transfer pipeline,
search, autotune — at tiny scale (seconds, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelConfig, apply_cost_model, evaluate,
                        finetune_target, geomean, init_cost_model,
                        kendall_tau, make_codec, ordered_pair_accuracy,
                        pairwise_ranking_loss, pretrain_source, topk_speedup)
from repro.core.search import hamming_neighbors, simulated_annealing, topk_exhaustive
from repro.data import collect_dataset, split_suite
from repro.hw import get_platform

CFG = CostModelConfig(ch_scale=0.25)


def _tiny_datasets():
    train, evl = split_suite(6, 4, seed=0, size_range=(256, 2048))
    cpu, spade = get_platform("cpu"), get_platform("spade")
    src = collect_dataset(cpu, train, "spmm", 16, seed=1, resolution=16)
    tgt = collect_dataset(spade, train[:3], "spmm", 16, seed=2, resolution=16)
    ev = collect_dataset(spade, evl, "spmm", 0, seed=3, resolution=16)
    return src, tgt, ev


def test_model_forward_shapes():
    key = jax.random.PRNGKey(0)
    for pred in ("mlp", "lstm", "gru", "tf"):
        cfg = dataclasses.replace(CFG, predictor=pred)
        p = init_cost_model(key, cfg)
        pyr = jnp.zeros((2, 4, 16, 16))
        hom = jnp.zeros((2, 5, 53))
        z = jnp.zeros((2, 5, cfg.latent_dim))
        scores = apply_cost_model(p, cfg, pyr, hom, z)
        assert scores.shape == (2, 5)


def test_ranking_loss_behaviour():
    # perfectly ordered scores (higher=slower) give zero hinge beyond margin
    t = jnp.asarray([[1.0, 2.0, 3.0]])
    good = jnp.asarray([[-10.0, 0.0, 10.0]])
    bad = -good
    assert float(pairwise_ranking_loss(good, t)) == 0.0
    assert float(pairwise_ranking_loss(bad, t)) > 1.0


def test_metrics():
    t = np.asarray([[1.0, 2.0, 3.0, 4.0]])
    s = np.asarray([[0.1, 0.2, 0.3, 0.4]])
    assert ordered_pair_accuracy(s, t) == 1.0
    assert kendall_tau(s, t) == 1.0
    sp, ape = topk_speedup(s, t, default_index=3, k=1)
    assert sp[0] == 4.0 and ape[0] == 0.0
    assert abs(geomean([2.0, 8.0]) - 4.0) < 1e-9


def test_codecs():
    het = np.random.default_rng(0).random((40, 13)).astype(np.float32)
    for kind in ("ae", "vae", "pca", "fa", "none"):
        codec = make_codec(kind, het, epochs=20, fa_platform="spade")
        z = codec.encode(het)
        assert z.shape == (40, codec.latent_dim)
        assert np.isfinite(z).all()
    # AE learns to reconstruct (loss decreases)
    codec = make_codec("ae", het, epochs=60)
    losses = codec.history["loss"]
    assert losses[-1] < losses[0]


def test_transfer_pipeline_end_to_end():
    src, tgt, ev = _tiny_datasets()
    pre = pretrain_source(CFG, src, epochs=3, ae_epochs=20)
    assert pre.history["loss"][-1] <= pre.history["loss"][0] * 1.2
    ft = finetune_target(pre, tgt, epochs=3, ae_epochs=20)
    m = evaluate(ft, ev)
    for k in ("top1_geomean", "top5_geomean", "optimal_geomean", "opa"):
        assert np.isfinite(m[k])
    # top-5 can't be worse than top-1; oracle bounds both
    assert m["top5_geomean"] >= m["top1_geomean"] - 1e-9
    assert m["optimal_geomean"] >= m["top5_geomean"] - 1e-6


def test_freeze_prefixes_keep_params_fixed():
    from repro.core.trainer import TrainConfig, train_cost_model
    src, tgt, _ = _tiny_datasets()
    codec = make_codec("ae", tgt.het, epochs=10)
    p0 = init_cost_model(jax.random.PRNGKey(0), CFG)
    cfg = TrainConfig(epochs=2, freeze_prefixes=("featurizer/blocks/0",),
                      batch_matrices=3)
    p1, _ = train_cost_model(CFG, tgt, codec, cfg, init_params=p0)
    frozen0 = jax.tree_util.tree_leaves(p0["featurizer"]["blocks"][0])
    frozen1 = jax.tree_util.tree_leaves(p1["featurizer"]["blocks"][0])
    for a, b in zip(frozen0, frozen1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-frozen parts moved
    moved0 = jax.tree_util.tree_leaves(p0["predictor"])
    moved1 = jax.tree_util.tree_leaves(p1["predictor"])
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(moved0, moved1))


def test_search():
    scores = np.asarray([5.0, 1.0, 3.0, 0.5, 2.0])
    assert list(topk_exhaustive(scores, 2)) == [3, 1]
    space = get_platform("spade").space
    nbrs = hamming_neighbors(space, 0)
    assert len(nbrs) == (3 + 3 + 1 + 1 + 1 + 1)   # sum over param fan-outs
    # SA converges toward the optimum of a smooth objective
    target = np.arange(256, dtype=np.float64)
    best, best_s, trace = simulated_annealing(
        lambda idx: target[idx], 256, steps=300, seed=0)
    assert best_s <= 10


def test_autotuner_api():
    from repro.core.autotune import Autotuner, KernelAutotuner
    from repro.data import generate_matrix
    src, tgt, _ = _tiny_datasets()
    pre = pretrain_source(CFG, src, epochs=2, ae_epochs=10)
    ft = finetune_target(pre, tgt, epochs=2, ae_epochs=10)
    tuner = Autotuner("spade", "spmm", ft.params, ft.model_cfg, ft.codec,
                      resolution=16)
    mat = generate_matrix("banded", seed=42, n_rows=512, n_cols=512)
    cands = tuner.best_configs(mat, k=3)
    assert len(cands) == 3 and "row_panels" in cands[0]
    picked = tuner.tune(mat, k=3)
    assert picked["runtime_ms"] > 0
    kt = KernelAutotuner()
    cfg = kt.select(mat)
    assert cfg["block_m"] in (8, 16, 32, 64, 128)
    # batched scoring is one jitted dispatch and matches per-matrix calls
    mats = [mat, generate_matrix("uniform", seed=7, n_rows=256, n_cols=256),
            generate_matrix("powerlaw", seed=8, n_rows=512, n_cols=384)]
    batched = tuner.scores_batch(mats)
    assert batched.shape == (3, tuner.space.n_configs)
    for i, m in enumerate(mats):
        np.testing.assert_allclose(batched[i], tuner.scores(m),
                                   rtol=1e-5, atol=1e-5)
    cands_b = tuner.best_configs_batch(mats, k=3)
    assert len(cands_b) == 3
    assert cands_b[0] == tuner.best_configs(mats[0], k=3)
