"""Sharded serving: consistent-hash ring stability properties, routing and
bounded-load overflow through ``ShardedEngine``, warm-start merge, live
rebalance (replica add/remove with warm cache-row migration), aggregated
stats, and shard-labeled Prometheus exposition.

The differential anchor everywhere: a sharded fleet must serve the exact
responses a single unsharded engine serves — bit for bit — because every
replica runs the identical deterministic pipeline on the identical cached
plans, just partitioned by digest ownership.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.matrices import generate_matrix
from repro.serving import (HashRing, KernelRequest, ShardedEngine,
                           SparseKernelEngine, parse_prometheus_text,
                           prom_get)


def _mats(n, seed0=0, n_rows=64, nnz=300):
    return [generate_matrix("uniform", seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _requests(mats, rhs=None):
    return [KernelRequest(m, operand=rhs) for m in mats]


def _rhs(n_rows=64, cols=8, seed=0):
    return np.asarray(
        np.random.default_rng(seed).standard_normal((n_rows, cols)),
        np.float32)


# ------------------------------------------------------------------ ring

def test_ring_deterministic_and_roughly_balanced():
    keys = [f"digest-{i}" for i in range(4000)]
    ring = HashRing(["r0", "r1", "r2", "r3"], vnodes=64)
    assert ring.assignment(keys) == HashRing(
        ["r3", "r1", "r0", "r2"], vnodes=64).assignment(keys)
    shares = {n: 0 for n in ring.nodes()}
    for owner in ring.assignment(keys).values():
        shares[owner] += 1
    for n, c in shares.items():
        # vnodes keep shares near 1/4; generous bounds, no flakes
        assert 0.10 < c / len(keys) < 0.45, (n, shares)


def test_ring_remove_rehomes_only_the_removed_nodes_keys():
    """The consistent-hashing property itself: losing 1 of N nodes moves
    ~1/N of the key space, and every moved key was owned by the loser."""
    keys = [f"digest-{i}" for i in range(4000)]
    ring = HashRing([f"r{i}" for i in range(5)], vnodes=64)
    before = ring.assignment(keys)
    ring.remove("r2")
    after = ring.assignment(keys)
    moved = [k for k in keys if before[k] != after[k]]
    assert all(before[k] == "r2" for k in moved)
    assert "r2" not in after.values()
    # exactly the removed node's share moved (~1/5, loose bounds)
    assert 0.05 < len(moved) / len(keys) < 0.40


def test_ring_readd_restores_assignment_bit_for_bit():
    keys = [f"digest-{i}" for i in range(4000)]
    ring = HashRing([f"r{i}" for i in range(5)], vnodes=64)
    before = ring.assignment(keys)
    ring.remove("r2")
    ring.add("r2")
    assert ring.assignment(keys) == before


def test_ring_membership_errors_and_successor():
    ring = HashRing(["r0"], vnodes=32)
    with pytest.raises(ValueError):
        ring.add("r0")                       # duplicate
    with pytest.raises(KeyError):
        ring.remove("r9")                    # unknown
    assert ring.successor("k") is None       # single node: no overflow target
    assert ring.owner("k") == "r0"
    ring.add("r1")
    for k in ("a", "b", "c", "d"):
        assert ring.successor(k) != ring.owner(k)
    ring.remove("r0")
    ring.remove("r1")
    with pytest.raises(KeyError):
        ring.owner("k")                      # empty ring
    with pytest.raises(ValueError):
        HashRing(vnodes=0)


# --------------------------------------------------------------- serving

def test_sharded_matches_unsharded_bit_for_bit():
    mats = _mats(10, seed0=30_000)
    rhs = _rhs(seed=1)
    ref = SparseKernelEngine(cache_size=64)
    want = ref.step(_requests(mats, rhs))
    ref.drain()
    with ShardedEngine(n_replicas=3, cache_size=64) as se:
        got = se.step(_requests(mats, rhs))
        se.drain()
        assert len(got) == len(mats)
        for w, g in zip(want, got):
            assert g is not None
            assert g.digest == w.digest
            assert g.config == w.config
            assert np.array_equal(np.asarray(w.output), np.asarray(g.output))
        s = se.stats()
        # the batch really was partitioned across replicas
        assert sum(s["routing"]["by_shard"].values()) == len(mats)
        assert len(s["routing"]["by_shard"]) >= 2


def test_sharded_ownership_is_sticky_and_second_pass_hits():
    mats = _mats(8, seed0=30_100)
    with ShardedEngine(n_replicas=3, cache_size=64) as se:
        r1 = se.step(_requests(mats))
        se.drain()
        owners = {r.digest: se.owner_of(r.digest) for r in r1}
        r2 = se.step(_requests(mats))
        se.drain()
        assert {r.digest: se.owner_of(r.digest) for r in r2} == owners
        s = se.stats()
        assert s["aggregate"]["misses"] == len(mats)
        assert s["aggregate"]["hits"] == len(mats)
        # each digest's hit landed on the replica that owns it
        for rid, per in s["by_shard"].items():
            assert per["hits"] == s["routing"]["by_shard"][rid] - per["misses"]


def test_bounded_load_overflow_spills_to_successor_and_never_drops():
    mat = _mats(1, seed0=30_200)[0]
    with ShardedEngine(n_replicas=2, cache_size=16, max_inflight=2,
                       parallel=False) as se:
        out = se.step(_requests([mat] * 8))
        se.drain()
        assert all(r is not None for r in out)
        s = se.stats()
        # one digest, one owner: slots 0-1 at the owner, 2-3 overflow to
        # the successor, 4+ fall back to the owner (never dropped)
        assert s["routing"]["overflows"] == 2
        assert sorted(s["routing"]["by_shard"].values()) == [2, 6]


def test_add_replica_migrates_only_moved_digests_warm():
    mats = _mats(12, seed0=30_300)
    with ShardedEngine(n_replicas=2, cache_size=64) as se:
        se.step(_requests(mats))
        se.drain()
        cold = se.featurize_calls
        before = {se._digest(m): se.owner_of(se._digest(m)) for m in mats}
        rid = se.add_replica()
        after = {dg: se.owner_of(dg) for dg in before}
        moved = [dg for dg in before if before[dg] != after[dg]]
        assert all(after[dg] == rid for dg in moved)
        s = se.stats()
        assert s["routing"]["rebalances"] == 1
        assert s["routing"]["migrated_entries"] == len(moved)
        # migrations are observable through the persistence counters
        assert sum(per["persist_saved_entries"]
                   for per in s["by_shard"].values()) > 0
        # moved digests serve warm on the new owner: all hits, zero
        # featurizations, and the source rows were popped (no doubles)
        out = se.step(_requests(mats))
        se.drain()
        assert se.featurize_calls == cold
        s2 = se.stats()
        assert s2["aggregate"]["hits"] == len(mats)
        assert s2["aggregate"]["cache_size"] == len(mats)
        assert all(r is not None for r in out)


def test_remove_replica_quiesces_migrates_and_survivors_serve_warm():
    mats = _mats(12, seed0=30_400)
    rhs = _rhs(seed=2)
    ref = SparseKernelEngine(cache_size=64)
    want = ref.step(_requests(mats, rhs))
    ref.drain()
    with ShardedEngine(n_replicas=3, cache_size=64) as se:
        se.step(_requests(mats, rhs))
        se.drain()
        victim = se.replica_ids[0]
        owned = [se._digest(m) for m in mats
                 if se.owner_of(se._digest(m)) == victim]
        moved = se.remove_replica(victim)
        assert moved == len(owned)
        assert victim not in se.replica_ids
        # post-remove assignment == a fresh ring of the survivors
        survivors = HashRing(se.replica_ids, vnodes=se._ring.vnodes)
        for m in mats:
            assert se.owner_of(se._digest(m)) == survivors.owner(
                se._digest(m))
        # featurize_calls sums over *live* replicas — the victim took its
        # count with it, so baseline after the removal
        base = se.featurize_calls
        out = se.step(_requests(mats, rhs))
        se.drain()
        assert se.featurize_calls == base
        for w, g in zip(want, out):
            assert np.array_equal(np.asarray(w.output), np.asarray(g.output))
    with ShardedEngine(n_replicas=1, cache_size=8) as solo:
        with pytest.raises(ValueError):
            solo.remove_replica(solo.replica_ids[0])
        with pytest.raises(KeyError):
            solo.remove_replica("r99")


def test_warm_start_merge_restores_any_layout(tmp_path):
    """One cache file warm-starts any replica count: a single engine's
    save() restores into 3 shards; the shard's merged save() restores into
    2 — both serve the traffic with zero featurizations."""
    mats = _mats(9, seed0=30_500)
    path = tmp_path / "cache.npz"
    eng = SparseKernelEngine(cache_size=64, persist_path=path)
    eng.step(_requests(mats))
    eng.drain()
    eng.save()
    assert eng.stats()["persist_saved_entries"] == len(mats)

    with ShardedEngine(n_replicas=3, cache_size=64,
                       persist_path=path) as se:
        s = se.stats()
        assert s["routing"]["warm_start_entries"] == len(mats)
        assert s["aggregate"]["warm_start_entries"] == len(mats)
        se.step(_requests(mats))
        se.drain()
        assert se.featurize_calls == 0
        assert se.stats()["aggregate"]["hits"] == len(mats)
        merged = tmp_path / "merged.npz"
        se.save(merged)
        assert se.stats()["routing"]["merged_saved_entries"] == len(mats)

    with ShardedEngine(n_replicas=2, cache_size=64,
                       persist_path=merged) as se2:
        se2.step(_requests(mats))
        se2.drain()
        assert se2.featurize_calls == 0


def test_sharded_engine_constructor_validation():
    with pytest.raises(ValueError):
        ShardedEngine(n_replicas=0)
    with pytest.raises(ValueError):
        # engine_kwargs only make sense with the default factory
        ShardedEngine(n_replicas=2, cache_size=8,
                      engine_factory=lambda rid, dev: SparseKernelEngine())


def test_engine_save_counts_persist_saved_entries(tmp_path):
    """Satellite: every save counts its written entries, and the counter
    rides the Prometheus exposition."""
    from repro.serving import prometheus_text
    eng = SparseKernelEngine(cache_size=32)
    eng.step(_requests(_mats(5, seed0=30_600)))
    eng.release_stream()
    eng.save(tmp_path / "c.npz")
    eng.save(tmp_path / "c.npz")
    s = eng.stats()
    assert s["persist_saves"] == 2
    assert s["persist_saved_entries"] == 10
    samples = parse_prometheus_text(prometheus_text(eng))
    assert prom_get(samples,
                    "repro_serving_persist_saved_entries_total") == 10
    ev = eng.events.events(kind="persist_save")
    assert ev and ev[-1]["entries"] == 5


def test_sharded_prometheus_every_series_carries_the_shard_label():
    with ShardedEngine(n_replicas=2, cache_size=32) as se:
        se.step(_requests(_mats(6, seed0=30_700)))
        se.drain()
        text = se.prometheus_text()
        samples = parse_prometheus_text(text)
        assert samples
        s = se.stats()
        fleet_prefix = "repro_serving_shard_"
        for name, labels, _v in samples:
            if not name.startswith(fleet_prefix):
                assert labels.get("shard") in s["by_shard"], (name, labels)
        for rid, per in s["by_shard"].items():
            assert prom_get(samples, "repro_serving_requests_total",
                            shard=rid) == per["requests"]
            assert prom_get(samples, "repro_serving_shard_routed_requests_total",
                            shard=rid) == s["routing"]["by_shard"][rid]
        assert prom_get(samples, "repro_serving_shard_replicas") == 2
        assert prom_get(samples, "repro_serving_shard_migrated_entries_total") \
            == 0


def test_sharded_stats_aggregate_consistency():
    mats = _mats(7, seed0=30_800)
    single_cap = sum(c["maxsize"] for c in
                     SparseKernelEngine(cache_size=16).stats()
                     ["caches"].values())
    with ShardedEngine(n_replicas=3, cache_size=16) as se:
        se.step(_requests(mats))
        se.drain()
        s = se.stats()
        assert s["replicas"] == 3
        assert s["aggregate"]["requests"] == len(mats)
        assert s["aggregate"]["requests"] == \
            sum(per["requests"] for per in s["by_shard"].values())
        assert s["aggregate"]["cache_capacity"] == 3 * single_cap
        assert set(s["ring"]["nodes"]) == set(s["by_shard"])
        assert set(s["load"]) == set(s["by_shard"])
        assert all(load["inflight"] == 0 for load in s["load"].values())


@pytest.mark.slow
def test_rebalance_under_load_loses_nothing_and_stays_bit_identical():
    """A driver thread serves continuously while a replica is added and
    then removed: every step returns a full response set (zero lost
    requests), nothing raises, and a final synchronized pass is still
    bit-identical to the unsharded reference."""
    mats = _mats(16, seed0=30_900)
    rhs = _rhs(seed=3)
    ref = SparseKernelEngine(cache_size=64)
    want = [np.asarray(r.output) for r in ref.step(_requests(mats, rhs))]
    ref.drain()
    se = ShardedEngine(n_replicas=2, cache_size=64)
    try:
        stop = threading.Event()
        counts: list[int] = []
        errors: list[BaseException] = []

        def drive():
            try:
                while not stop.is_set():
                    rs = se.step(_requests(mats, rhs))
                    counts.append(sum(r is not None for r in rs))
            except BaseException as e:      # noqa: BLE001 — reported below
                errors.append(e)

        t = threading.Thread(target=drive)
        t.start()
        time.sleep(0.3)
        rid = se.add_replica()
        time.sleep(0.3)
        se.remove_replica(rid)
        time.sleep(0.3)
        stop.set()
        t.join(timeout=60)
        assert not t.is_alive()
        assert not errors, errors
        assert len(counts) >= 3
        assert all(c == len(mats) for c in counts)
        out = se.step(_requests(mats, rhs))
        se.drain()
        for w, g in zip(want, out):
            assert np.array_equal(w, np.asarray(g.output))
        s = se.stats()
        assert s["routing"]["rebalances"] == 2
        assert s["routing"]["migrated_entries"] > 0
        assert s["replicas"] == 2
    finally:
        se.close()
