"""End-to-end system behaviour tests.

1. The full paper pipeline (label collection -> pretrain -> AE -> few-shot
   fine-tune -> top-k selection) must beat the zero-shot baseline and land
   between baseline and oracle — the paper's central claim, at tiny scale.
2. The production training driver must run steps, checkpoint, and resume
   bit-exactly (fault-tolerance contract).
3. The dry-run builder must lower every kind of step on a host mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelConfig, evaluate, finetune_target,
                        pretrain_source, zero_shot)
from repro.data import collect_dataset, split_suite
from repro.hw import get_platform


@pytest.fixture(scope="module")
def pipeline_results():
    train, evl = split_suite(8, 6, seed=2, size_range=(256, 2048))
    cpu, spade = get_platform("cpu"), get_platform("spade")
    src = collect_dataset(cpu, train, "spmm", 24, seed=1, resolution=16)
    tgt = collect_dataset(spade, train[:3], "spmm", 24, seed=2, resolution=16)
    ev = collect_dataset(spade, evl, "spmm", 0, seed=3, resolution=16)
    cfg = CostModelConfig(ch_scale=0.25)
    pre = pretrain_source(cfg, src, epochs=6, ae_epochs=40)
    zs = evaluate(zero_shot(pre, tgt, ae_epochs=40), ev)
    ft = evaluate(finetune_target(pre, tgt, epochs=10, ae_epochs=40), ev)
    return zs, ft


def test_transfer_beats_zero_shot(pipeline_results):
    zs, ft = pipeline_results
    assert ft["top5_geomean"] > zs["top5_geomean"]


def test_finetuned_between_baseline_and_oracle(pipeline_results):
    _, ft = pipeline_results
    assert ft["top5_geomean"] > 1.0              # beats platform default
    assert ft["top5_geomean"] <= ft["optimal_geomean"] + 1e-6
    assert 0.5 <= ft["opa"] <= 1.0


def test_train_driver_resume(tmp_path):
    """Driver trains, checkpoints, and an elastic restart resumes cleanly."""
    from repro.launch import train as train_mod
    common = ["--arch", "yi-9b", "--reduced", "--batch", "2", "--seq", "32",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"]
    loss_a = train_mod.main(common + ["--steps", "6"])
    # resume from step 6's checkpoint and continue to 8
    loss_b = train_mod.main(common + ["--steps", "8", "--resume"])
    assert np.isfinite(loss_a) and np.isfinite(loss_b)


def test_dryrun_builder_all_kinds():
    """build_step produces lowerable artifacts for train/prefill/decode."""
    from repro.launch.dryrun import build_step
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        make, meta = build_step("xlstm-350m", shape)
        assert meta["kind"] in ("train", "prefill", "decode")
