"""Pipeline-parallel and compressed-psum tests on a 4-device host platform.

jax locks the device count at first init, so these run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pod",))
    S, M, mb, D = 4, 6, 2, 8
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(M, mb, D)), jnp.float32)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    out = pipeline_apply(stage_fn, ws, xs, mesh, axis="pod")

    # sequential oracle
    want = xs
    for s in range(S):
        want = jnp.tanh(want @ ws[s])
    err = float(jnp.abs(out - want).max())
    assert err < 1e-5, f"pipeline mismatch {err}"
    print("PIPELINE_OK", err)

    # compressed psum across the pod axis
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import make_compressed_psum
    g = {"w": jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)}
    resid = {"w": jnp.zeros((4, 16), jnp.float32)}
    cp = make_compressed_psum("pod")
    fn = shard_map(cp, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P("pod"), P("pod")), check_rep=False)
    mean, new_resid = fn(g, resid)
    want_mean = jnp.broadcast_to(g["w"].mean(0, keepdims=True), (4, 16))
    err2 = float(jnp.abs(mean["w"] - want_mean).max() /
                 jnp.abs(want_mean).max())
    assert err2 < 0.05, f"compressed psum err {err2}"
    print("PSUM_OK", err2)
""")


def test_pipeline_and_compression_multidev():
    env = dict(os.environ, PYTHONPATH=str(SRC))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=480)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
    assert "PSUM_OK" in out.stdout, out.stdout + out.stderr
