"""Fault-tolerance tests: circuit breakers (``repro.serving.health``), the
deterministic fault-injection harness (``repro.serving.faults``), the
engine's retry-with-failover lane and output guards, health-aware routing
(sticky invalidation, open-circuit spill, EMA-smoothed depth), and the
persistence CRC/quarantine hardening (format v4).

Every breaker test drives time through an injected fake clock and every
executor failure through a ``FaultPlan`` keyed on call index, so the whole
file is deterministic — no sleeps, no wall-clock races.
"""
import threading
import time

import numpy as np
import pytest

from repro.data import generate_matrix
from repro.kernels import spmm_ref
from repro.serving import (CostModelRouter, FaultPlan, FaultWindow,
                           FaultyExecutor, HealthConfig, HealthRegistry,
                           InjectedFault, KernelRequest, LoadAwareRouter,
                           SparseKernelEngine, StaticRouter, default_registry,
                           flip_byte, inject_faults, load_grouped,
                           save_backends, truncate_file)
from repro.serving.health import CLOSED, HALF_OPEN, OPEN


def _mats(n, seed0=0, n_rows=256, nnz=1200):
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    return [generate_matrix(fams[i % 4], seed=seed0 + i, n_rows=n_rows,
                            n_cols=n_rows, target_nnz=nnz) for i in range(n)]


class FakeClock:
    """Injectable monotonic source — breaker timing becomes deterministic."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _requests(mats, rhs, seed=0):
    rng = np.random.default_rng(seed)
    return [KernelRequest(m, rng.normal(size=m.nnz).astype(np.float32),
                          "spmm", rhs) for m in mats]


TAG = ("tpu_interpret", "spmm")


# -------------------------------------------------------- breaker unit tests

def test_breaker_trips_on_consecutive_errors():
    clk = FakeClock()
    hr = HealthRegistry(HealthConfig(consecutive_errors=3, backoff_s=2.0),
                        clock=clk)
    hr.record_failure(TAG)
    hr.record_failure(TAG)
    assert hr.state(TAG) == CLOSED and hr.allow(TAG)
    hr.record_failure(TAG)                  # third back-to-back: trip
    assert hr.state(TAG) == OPEN
    assert not hr.allow(TAG) and not hr.routable(TAG)
    clk.advance(2.0)                        # backoff elapsed: probe due
    assert hr.routable(TAG)
    assert hr.allow(TAG)                    # this admission IS the probe
    assert hr.state(TAG) == HALF_OPEN
    assert not hr.allow(TAG)                # one probe at a time
    hr.record_success(TAG, 0.001)
    assert hr.state(TAG) == CLOSED
    snap = hr.snapshot()["tpu_interpret/spmm"]
    assert snap["probe_successes"] == 1 and snap["opens"] == 1
    assert snap["failure_rate"] == 0.0      # window cleared on recovery


def test_breaker_trips_on_windowed_failure_rate():
    # consecutive_errors out of reach: only the rolling rate can trip it
    hr = HealthRegistry(HealthConfig(window=8, failure_threshold=0.5,
                                     min_samples=4, consecutive_errors=100),
                        clock=FakeClock())
    hr.record_failure(TAG)
    hr.record_success(TAG)
    hr.record_failure(TAG)
    hr.record_success(TAG)
    assert hr.state(TAG) == CLOSED          # rate 0.5 but checked on failure
    hr.record_failure(TAG)                  # 3/5 = 0.6 >= 0.5, n >= 4: trip
    assert hr.state(TAG) == OPEN
    assert hr.failure_rate(TAG) == pytest.approx(0.6)


def test_breaker_backoff_escalates_on_failed_probes():
    clk = FakeClock()
    hr = HealthRegistry(HealthConfig(consecutive_errors=1, backoff_s=1.0,
                                     backoff_factor=2.0, max_backoff_s=4.0),
                        clock=clk)
    hr.record_failure(TAG)                  # trip (backoff 1s)
    clk.advance(1.0)
    assert hr.allow(TAG)                    # probe #1
    hr.record_failure(TAG)                  # fails: reopen, backoff -> 2s
    assert hr.state(TAG) == OPEN
    clk.advance(1.0)
    assert not hr.allow(TAG)                # 1s < escalated 2s
    clk.advance(1.0)
    assert hr.allow(TAG)                    # probe #2
    hr.record_failure(TAG)                  # backoff -> 4s (the cap)
    clk.advance(4.0)
    assert hr.allow(TAG)                    # probe #3
    hr.record_failure(TAG)                  # capped: stays 4s
    snap = hr.snapshot()["tpu_interpret/spmm"]
    assert snap["probe_failures"] == 3 and snap["backoff_s"] == 4.0
    clk.advance(4.0)
    assert hr.allow(TAG)
    hr.record_success(TAG)                  # recovery resets the escalation
    assert hr.snapshot()["tpu_interpret/spmm"]["backoff_s"] == 1.0
    assert hr.state(TAG) == CLOSED


def test_breaker_probe_cancel_returns_grant():
    clk = FakeClock()
    hr = HealthRegistry(HealthConfig(consecutive_errors=1, backoff_s=1.0),
                        clock=clk)
    hr.record_failure(TAG)
    clk.advance(1.0)
    assert hr.allow(TAG)                    # probe granted...
    hr.cancel_probe(TAG)                    # ...but nothing executed
    assert hr.snapshot()["tpu_interpret/spmm"]["probes"] == 0
    assert hr.allow(TAG)                    # grant is immediately reclaimable


def test_health_generation_counts_transitions_per_platform():
    clk = FakeClock()
    hr = HealthRegistry(HealthConfig(consecutive_errors=1, backoff_s=1.0),
                        clock=clk)
    assert hr.generation("tpu_interpret") == 0
    hr.record_failure(TAG)                  # closed -> open
    assert hr.generation("tpu_interpret") == 1
    clk.advance(1.0)
    hr.allow(TAG)                           # open -> half_open
    hr.record_success(TAG)                  # half_open -> closed
    assert hr.generation("tpu_interpret") == 3
    assert hr.generation("cpu_ref") == 0    # other platforms unaffected


# ------------------------------------------------------ fault plan / harness

def test_fault_plan_windows_and_determinism():
    plan = FaultPlan.fail_calls(2, 5)
    assert [bool(plan.active(i)) for i in range(7)] \
        == [False, False, True, True, True, False, False]
    stride = FaultPlan((FaultWindow("error", 0, 10, every=3),))
    assert [i for i in range(10) if stride.active(i)] == [0, 3, 6, 9]
    # probabilistic faults replay identically for the same seed — the draw
    # is keyed on (seed, call index), not evaluation order
    a = FaultPlan((FaultWindow("error", 0, 200, prob=0.5),), seed=7)
    b = FaultPlan((FaultWindow("error", 0, 200, prob=0.5),), seed=7)
    seq = [bool(a.active(i)) for i in range(200)]
    assert seq == [bool(b.active(i)) for i in range(200)]
    assert any(seq) and not all(seq)        # actually Bernoulli, not const
    c = FaultPlan((FaultWindow("error", 0, 200, prob=0.5),), seed=8)
    assert seq != [bool(c.active(i)) for i in range(200)]


def test_faulty_executor_counts_inject_and_restore():
    fx = FaultyExecutor(lambda c, m, o: 42, FaultPlan.fail_calls(1, 2))
    assert fx(None, None, None) == 42
    with pytest.raises(InjectedFault):
        fx(None, None, None)
    assert fx(None, None, None) == 42
    assert fx.calls == 3 and fx.injected["error"] == 1
    # inject_faults swaps KernelBackend.run in place; restore undoes it
    reg = default_registry()
    be = reg.get("cpu_ref", "spmm")
    orig = be.run
    wrapped = inject_faults(reg, "cpu_ref", "spmm", FaultPlan())
    assert be.run is wrapped and wrapped.inner is orig
    wrapped.restore()
    assert be.run is orig


# ------------------------------------------------- engine failover / retries

def test_executor_failure_fails_over_and_matches_reference():
    reg = default_registry()
    fx = inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    rng = np.random.default_rng(3)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    resps = engine.step(_requests(_mats(3, seed0=9000), rhs, seed=3))
    for r in resps:
        # failed over to the healthiest survivor: cpu_ref (lowest failure
        # rate, alphabetical tiebreak), output bit-identical to the oracle
        assert r.platform == "cpu_ref" and r.route_reason == "failover"
        assert r.attempts == 2 and r.degraded
        assert r.failed_over_from == "tpu_interpret"
        np.testing.assert_array_equal(
            np.asarray(r.output)[:, :64],
            np.asarray(spmm_ref(r.matrix, rhs))[:, :64])
    assert fx.injected["error"] == 3
    h = engine.stats()["health"]
    assert h["execute_failures"] == 3 and h["failovers"] == 3
    assert h["retry_failures"] == 0
    br = h["breakers"]["tpu_interpret/spmm"]
    assert br["failures"] == 3 and br["state"] == OPEN   # 3 back-to-back
    engine.drain()
    s = engine.stats()
    assert all(v["inflight"] == 0 for v in s["load"].values())
    assert s["arenas"]["outstanding_leases"] == 0


def test_open_circuit_fast_fails_without_touching_executor():
    reg = default_registry()
    fx = inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    rhs = np.ones((256, 64), np.float32)
    engine.step(_requests(_mats(3, seed0=9100), rhs))     # trips the breaker
    calls_before = fx.calls
    resps = engine.step(_requests(_mats(2, seed0=9200), rhs))
    # the dead backend cost a dict lookup: rerouted at the health gate,
    # served in ONE attempt, and its executor was never called again
    assert fx.calls == calls_before
    for r in resps:
        assert r.platform == "cpu_ref" and r.route_reason == "failover"
        assert r.attempts == 1 and r.degraded
        assert r.failed_over_from == "tpu_interpret"
    assert engine.stats()["health"]["circuit_fast_fails"] == 2
    engine.drain()


def test_breaker_recovers_via_half_open_probe():
    reg = default_registry()
    clk = FakeClock()
    # calls 0..2 fail (the kill batch); everything after succeeds
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0, 3))
    engine = SparseKernelEngine(
        backends=reg, health=HealthRegistry(HealthConfig(backoff_s=5.0),
                                            clock=clk))
    rhs = np.ones((256, 64), np.float32)
    engine.step(_requests(_mats(3, seed0=9300), rhs))     # kill batch: open
    assert engine.health.state(TAG) == OPEN
    engine.step(_requests(_mats(1, seed0=9400), rhs))     # still open
    assert engine.stats()["health"]["circuit_fast_fails"] == 1
    clk.advance(5.0)                                      # backoff elapsed
    resps = engine.step(_requests(_mats(2, seed0=9500), rhs))
    # the admission was the half-open probe; the (now healthy) executor
    # served it, so the breaker closed and traffic is back, undegraded
    for r in resps:
        assert r.platform == "tpu_interpret" and not r.degraded
        assert r.attempts == 1 and r.failed_over_from is None
    snap = engine.health.snapshot()["tpu_interpret/spmm"]
    assert snap["state"] == CLOSED
    assert snap["probes"] == 1 and snap["probe_successes"] == 1
    engine.drain()


def test_failed_probe_reopens_with_escalated_backoff():
    reg = default_registry()
    clk = FakeClock()
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(
            HealthConfig(consecutive_errors=1, backoff_s=5.0,
                         backoff_factor=2.0), clock=clk))
    rhs = np.ones((256, 64), np.float32)
    engine.step(_requests(_mats(1, seed0=9600), rhs))     # trip
    clk.advance(5.0)
    resp, = engine.step(_requests(_mats(1, seed0=9700), rhs))  # probe fails
    assert resp.degraded and resp.platform == "cpu_ref"   # still served
    snap = engine.health.snapshot()["tpu_interpret/spmm"]
    assert snap["state"] == OPEN and snap["probe_failures"] == 1
    assert snap["backoff_s"] == 10.0                      # escalated 2x
    clk.advance(5.0)                                      # old backoff: no
    assert not engine.health.routable(TAG)
    engine.drain()


def test_prepare_only_probe_is_cancelled_not_leaked():
    reg = default_registry()
    clk = FakeClock()
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0, 1))
    engine = SparseKernelEngine(
        backends=reg,
        health=HealthRegistry(HealthConfig(consecutive_errors=1,
                                           backoff_s=1.0), clock=clk))
    rhs = np.ones((256, 64), np.float32)
    engine.step(_requests(_mats(1, seed0=9800), rhs))     # trip
    clk.advance(1.0)
    engine.step([KernelRequest(m) for m in _mats(1, seed0=9900)])
    # the prepare-only batch consumed the probe grant but executed nothing:
    # the grant must be returned, or recovery would deadlock
    assert engine.health.state(TAG) == HALF_OPEN
    assert engine.health.snapshot()["tpu_interpret/spmm"]["probes"] == 0
    resp, = engine.step(_requests(_mats(1, seed0=10000), rhs))  # real probe
    assert resp.platform == "tpu_interpret" and not resp.degraded
    assert engine.health.state(TAG) == CLOSED
    engine.drain()


def test_output_guard_catches_nan_and_fails_over():
    reg = default_registry()
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.nan_calls(0))
    engine = SparseKernelEngine(backends=reg, validate_outputs=True)
    rng = np.random.default_rng(4)
    rhs = rng.normal(size=(256, 64)).astype(np.float32)
    resps = engine.step(_requests(_mats(2, seed0=10100), rhs, seed=4))
    for r in resps:
        assert r.platform == "cpu_ref" and r.degraded and r.attempts == 2
        assert np.isfinite(np.asarray(r.output)).all()
        np.testing.assert_array_equal(
            np.asarray(r.output)[:, :64],
            np.asarray(spmm_ref(r.matrix, rhs))[:, :64])
    h = engine.stats()["health"]
    assert h["output_guard_failures"] == 2 and h["failovers"] == 2
    engine.drain()


def test_output_guard_off_passes_nan_through():
    # guards are opt-in (they force the async dispatch to completion):
    # without them a poisoned output flows to the caller un-degraded
    reg = default_registry()
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.nan_calls(0))
    engine = SparseKernelEngine(backends=reg)
    rhs = np.ones((256, 64), np.float32)
    resp, = engine.step(_requests(_mats(1, seed0=10200), rhs))
    assert resp.platform == "tpu_interpret" and not resp.degraded
    assert np.isnan(np.asarray(resp.output)).all()
    assert engine.stats()["health"]["output_guard_failures"] == 0
    engine.drain()


def test_midbatch_backend_failure_rolls_back_all_leases():
    # three explicit partitions, the SECOND one's executor raises, retries
    # off: the error propagates but no partition leaks a lease or a load
    # count — including the two partitions that executed fine
    reg = default_registry()
    inject_faults(reg, "tpu_pallas", "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(backends=reg, max_retries=0)
    rhs = np.ones((256, 64), np.float32)
    mats = _mats(3, seed0=10300)
    reqs = [KernelRequest(m, np.ones(m.nnz, np.float32), "spmm", rhs, p)
            for m, p in zip(mats,
                            ("tpu_interpret", "tpu_pallas", "cpu_ref"))]
    with pytest.raises(InjectedFault):
        engine.step(reqs)
    s = engine.stats()
    assert all(v["inflight"] == 0 for v in s["load"].values())
    assert s["arenas"]["outstanding_leases"] == 0
    assert s["health"]["execute_failures"] == 1


def test_double_failure_raises_but_releases_resources():
    # primary AND failover target both dead: the retry failure surfaces,
    # and the step's unwind still returns every lease and load count
    reg = default_registry()
    inject_faults(reg, "tpu_interpret", "spmm", FaultPlan.fail_calls(0))
    inject_faults(reg, "cpu_ref", "spmm", FaultPlan.fail_calls(0))
    inject_faults(reg, "tpu_pallas", "spmm", FaultPlan.fail_calls(0))
    engine = SparseKernelEngine(backends=reg)
    rhs = np.ones((256, 64), np.float32)
    with pytest.raises(InjectedFault):
        engine.step(_requests(_mats(1, seed0=10400), rhs))
    s = engine.stats()
    assert s["health"]["retry_failures"] == 1
    assert all(v["inflight"] == 0 for v in s["load"].values())
    assert s["arenas"]["outstanding_leases"] == 0


def test_drain_under_failure_threaded_no_hang():
    # a failure held in flight on another thread: once it lands, the step
    # fails over and a subsequent drain completes — no hang, no leaked
    # lease, no double release
    reg = default_registry()
    fx = inject_faults(reg, "tpu_interpret", "spmm",
                       FaultPlan.fail_calls(0, 1))
    fx.block_event = threading.Event()
    engine = SparseKernelEngine(backends=reg)
    rhs = np.ones((256, 64), np.float32)
    box = {}

    def worker():
        try:
            box["resps"] = engine.step(_requests(_mats(1, seed0=10500), rhs))
            engine.drain()
        except BaseException as e:          # pragma: no cover - test guard
            box["err"] = e

    t = threading.Thread(target=worker)
    t.start()
    deadline = time.monotonic() + 30.0
    while fx.injected["error"] < 1:         # wait for the fault to be held
        assert time.monotonic() < deadline, "executor never reached fault"
        time.sleep(0.01)
    assert t.is_alive()                     # step is blocked on the fault
    fx.block_event.set()
    t.join(timeout=60.0)
    assert not t.is_alive() and "err" not in box
    resp, = box["resps"]
    assert resp.degraded and resp.platform == "cpu_ref"
    s = engine.stats()
    assert all(v["inflight"] == 0 for v in s["load"].values())
    assert s["arenas"]["outstanding_leases"] == 0


# ------------------------------------------------------ health-aware routing

def test_cost_model_sticky_invalidates_on_health_transition():
    router = CostModelRouter()
    # warm_lane=False: this test asserts the *router's* sticky memo and its
    # health-transition invalidation; the warm lane would replay repeats
    # before routing runs (its own invalidation is covered in
    # tests/test_warm_lane.py)
    engine = SparseKernelEngine(
        router=router, warm_lane=False,
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    mats = _mats(2, seed0=10600)
    first = engine.step([KernelRequest(m) for m in mats])
    assert all(r.platform == "tpu_interpret" for r in first)
    second = engine.step([KernelRequest(m) for m in mats])
    assert all(r.route_reason == "sticky" for r in second)
    for _ in range(3):                      # trip the memoized platform
        engine.health.record_failure(TAG)
    third = engine.step([KernelRequest(m) for m in mats])
    # the memo carried the health generation it was decided under: the
    # breaker transition invalidated it and routing re-decided off the
    # open-circuit platform
    assert router.sticky_invalidations == len(mats)
    for r in third:
        assert r.platform == "cpu_ref" and r.route_reason == "cost_model"
    # the re-decision is memoized against the NEW platform's health: it
    # sticks (no flap back while the old platform is still suspect)
    fourth = engine.step([KernelRequest(m) for m in mats])
    assert all(r.platform == "cpu_ref" and r.route_reason == "sticky"
               for r in fourth)
    engine.release_stream()


def test_load_aware_open_circuit_spills_immediately():
    # an open circuit is saturation: spill bypasses both the depth
    # threshold (far from reached) and the hysteresis streak
    router = LoadAwareRouter(StaticRouter(), max_inflight=100, spill_after=5)
    engine = SparseKernelEngine(
        router=router,
        health=HealthRegistry(HealthConfig(backoff_s=60.0),
                              clock=FakeClock()))
    for _ in range(3):
        engine.health.record_failure(TAG)
    resps = engine.step([KernelRequest(m) for m in _mats(2, seed0=10700)])
    assert [r.platform for r in resps] == ["cpu_ref"] * 2
    assert [r.route_reason for r in resps] == ["spill"] * 2
    assert router.spills == 2 and router.spill_hysteresis == 0
    engine.release_stream()


def test_load_aware_ema_damps_transient_depth():
    # raw depth hits max_inflight at the 5th decision of the batch; the
    # EMA-smoothed signal (alpha=0.5) crosses only at the 6th — one fewer
    # spill than the instantaneous router on identical traffic
    smoothed = LoadAwareRouter(StaticRouter(), max_inflight=4,
                               spill_after=1, depth_alpha=0.5)
    engine = SparseKernelEngine(router=smoothed)
    resps = engine.step([KernelRequest(m) for m in _mats(6, seed0=10800)])
    assert [r.platform for r in resps] \
        == [engine.default_platform] * 5 + ["cpu_ref"]
    assert smoothed.spills == 1
    s = engine.stats()
    assert s["load"]["tpu_interpret/spmm"]["smoothed"] \
        == pytest.approx(4.03125)
    engine.release_stream()

    raw = LoadAwareRouter(StaticRouter(), max_inflight=4, spill_after=1)
    engine2 = SparseKernelEngine(router=raw)
    resps2 = engine2.step([KernelRequest(m) for m in _mats(6, seed0=10800)])
    assert [r.platform for r in resps2] \
        == [engine2.default_platform] * 4 + ["cpu_ref"] * 2
    assert raw.spills == 2
    engine2.release_stream()


# -------------------------------------------------- persistence v4 hardening

def _populated_cache(n=2, seed0=11000):
    from repro.core.autotune import KernelAutotuner
    kt = KernelAutotuner()
    mats = _mats(n, seed0=seed0)
    kt.get_batch(mats)
    return kt, mats


def test_persist_v4_crc_catches_semantic_tamper(tmp_path):
    # permuting `take` keeps every structural invariant (dtype, shape,
    # range) — on a v3 file it restores fine and would mis-scatter
    # silently; the v4 per-entry CRC is what catches it
    kt, _ = _populated_cache(1)

    def tamper(path):
        with np.load(path) as data:
            arrays = dict(data.items())
        rolled = np.roll(arrays["e0_take"], 1)
        assert not np.array_equal(rolled, arrays["e0_take"])
        arrays["e0_take"] = rolled
        np.savez(path, **arrays)

    v3 = tmp_path / "v3.npz"
    save_backends({"tpu_interpret": kt.cache}, v3, version=3)
    tamper(v3)
    g3 = load_grouped(v3)
    assert g3.skipped == 0 and len(g3) == 1     # v3: silently wrong

    v4 = tmp_path / "v4.npz"
    save_backends({"tpu_interpret": kt.cache}, v4)
    tamper(v4)
    with pytest.warns(UserWarning, match="CRC mismatch"):
        g4 = load_grouped(v4)
    assert g4.skipped == 1 and len(g4) == 0     # v4: caught and dropped


def test_persist_truncated_file_quarantined(tmp_path):
    kt, _ = _populated_cache(2)
    path = tmp_path / "cache.npz"
    corrupt = tmp_path / "cache.npz.corrupt"
    for keep in (10, 0.1, 0.5, 0.9):
        save_backends({"tpu_interpret": kt.cache}, path)
        truncate_file(path, keep)
        with pytest.warns(UserWarning):
            assert load_grouped(path, quarantine=True) is None
        # wholesale-unreadable: renamed out of the way, evidence preserved
        assert not path.exists() and corrupt.exists()
        corrupt.unlink()


def test_persist_bitflips_never_silently_wrong(tmp_path):
    kt, mats = _populated_cache(2)
    path = tmp_path / "cache.npz"
    save_backends({"tpu_interpret": kt.cache}, path)
    pristine = path.read_bytes()
    originals = {key: entry for key, entry in kt.cache.items()}
    size = len(pristine)
    for offset in (64, size // 3, size // 2, -200):
        path.write_bytes(pristine)
        flip_byte(path, offset)
        with pytest.warns(UserWarning):
            g = load_grouped(path)
        if g is None:
            continue                        # wholesale-unreadable: fine
        assert g.skipped >= 1               # the hit entry was dropped...
        for tag_entries in g.entries.values():
            for key, entry in tag_entries:  # ...survivors are bit-exact
                orig = originals[key]
                assert entry.config == orig.config
                for name in ("rowids", "colids", "take", "slot",
                             "rloc", "cloc"):
                    np.testing.assert_array_equal(getattr(entry.plan, name),
                                                  getattr(orig.plan, name))


def test_engine_warm_start_quarantines_corrupt_entries(tmp_path):
    # partial corruption: good entries keep serving, the file is COPIED to
    # .corrupt (not renamed), and the engine counts the quarantine
    kt, _ = _populated_cache(2)
    path = tmp_path / "cache.npz"
    save_backends({"tpu_interpret": kt.cache}, path)
    with np.load(path) as data:
        arrays = dict(data.items())
    arrays["e0_take"] = np.roll(arrays["e0_take"], 1)   # CRC mismatch
    np.savez(path, **arrays)
    with pytest.warns(UserWarning):
        engine = SparseKernelEngine(persist_path=path)
    s = engine.stats()
    assert s["warm_start_entries"] == 1 and s["warm_start_skipped"] == 1
    assert s["persist_quarantined"] == 1
    assert path.exists()                    # original still serving
    assert (tmp_path / "cache.npz.corrupt").exists()


def test_engine_warm_start_quarantines_truncated_file(tmp_path):
    kt, _ = _populated_cache(1)
    path = tmp_path / "cache.npz"
    save_backends({"tpu_interpret": kt.cache}, path)
    truncate_file(path, 0.5)
    with pytest.warns(UserWarning):
        engine = SparseKernelEngine(persist_path=path)
    s = engine.stats()
    assert s["persist_load_failures"] == 1 and s["persist_quarantined"] == 1
    assert not path.exists()                # renamed to .corrupt
    assert (tmp_path / "cache.npz.corrupt").exists()
    # and the engine came up cold but serving
    resp, = engine.step([KernelRequest(m) for m in _mats(1, seed0=11100)])
    assert resp.digest
    engine.release_stream()
