"""Admission-control and replica-supervision tests: deadline edge cases
through the pipeline (``repro.serving.engine`` gates), the bounded
``AdmissionQueue`` (shedding order, queued expiry, overload resolution),
the ``ReplicaSupervisor`` watchdog (hang -> quarantine -> probation ->
re-admission), graceful shutdown, and the queue's Prometheus exposition
round-trip.

Deadline timing runs on injected fake clocks (the engine and the queue
share one), hangs and crashes come from call-indexed ``FaultPlan``
windows, and every early-exit path asserts leases and loads released —
the invariants the overload benchmark gates at scale.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.matrices import generate_matrix
from repro.serving import (AdmissionQueue, DeadlineExceededError, FaultPlan,
                           FaultyExecutor, KernelRequest, QueueClosed,
                           ReplicaCrash, ShardedEngine, ShedError,
                           SparseKernelEngine, admission_prometheus_text,
                           inject_faults, parse_prometheus_text, prom_get)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(seed, n_rows=64, nnz=400):
    m = generate_matrix("uniform", seed, n_rows=n_rows, n_cols=n_rows,
                        target_nnz=nnz)
    return KernelRequest(m, None, "spmm",
                         np.ones((m.n_cols, 8), np.float32))


def _assert_released(engine):
    assert engine.stats()["arenas"]["outstanding_leases"] == 0
    for tag, load in engine.backends.loads_by_tag().items():
        assert load.inflight == 0, (tag, load.inflight)


# ----------------------------------------------------- engine deadline gates

def test_deadline_zero_budget_expires_at_step_entry():
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk)
    r = _req(0)
    r.deadline_ts = 0.0                 # already past at t=0? no: now == ts
    clk.advance(0.1)
    live = _req(1)
    out = eng.step([r, live])
    assert out[0].deadline_exceeded and out[0].output is None
    assert out[0].route_reason == "deadline"
    assert not out[1].deadline_exceeded and out[1].output is not None
    assert eng.stats()["deadlines"]["expired"] == 1
    eng.drain()
    _assert_released(eng)


def test_deadline_expires_mid_pipeline_between_score_and_execute():
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk)
    doomed, live = _req(0), _req(1)
    doomed.deadline_ts = 5.0
    live.deadline_ts = 10_000.0
    orig = eng._build_stage

    def late_build(st):
        clk.advance(6.0)            # budget blows after score, before build
        return orig(st)

    eng._build_stage = late_build
    out = eng.step([doomed, live])
    assert out[0].deadline_exceeded and out[0].output is None
    assert not out[1].deadline_exceeded and out[1].output is not None
    assert eng.stats()["deadlines"]["expired"] == 1
    eng.drain()
    _assert_released(eng)


def test_retry_lane_respects_remaining_budget():
    from repro.serving import InjectedFault
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk, warm_lane=False)
    be = eng.backends.get(eng.default_platform, "spmm")
    orig_run = be.run
    calls = {"n": 0}

    def failing_and_slow(config, matrix, operand):
        calls["n"] += 1
        if calls["n"] == 1:
            # the failing call burns the whole budget: by the time the
            # retry lane looks at this request, its deadline has passed
            clk.advance(60.0)
            raise InjectedFault("boom")
        return orig_run(config, matrix, operand)

    be.run = failing_and_slow
    try:
        doomed = _req(0)
        doomed.deadline_ts = 5.0
        out = eng.step([doomed])
        assert out[0].deadline_exceeded
        assert eng.stats()["deadlines"]["retry_exhausted"] == 1
        # the failure never became a served response or a failover
        assert eng.stats()["health"]["failovers"] == 0
    finally:
        be.run = orig_run
    eng.drain()
    _assert_released(eng)


# --------------------------------------------------------- queue unit tests

def test_zero_and_negative_budget_resolve_at_submit():
    eng = SparseKernelEngine()
    q = AdmissionQueue(eng, capacity=4, start=False)
    for budget in (0, -10):
        t = q.submit(_req(0), deadline_ms=budget)
        assert t.outcome == "deadline_exceeded" and t.done()
        with pytest.raises(DeadlineExceededError):
            t.result()
    assert q.snapshot()["depth"] == 0
    assert q.snapshot()["deadline_exceeded"] == 2
    q.close()
    _assert_released(eng)


def test_shed_vs_overflow_ordering_under_full_queue():
    eng = SparseKernelEngine()
    q = AdmissionQueue(eng, capacity=4, high_watermark=4, start=False)
    low = [q.submit(_req(i), priority=0) for i in range(4)]
    # same priority as the floor: the incoming (youngest) request sheds
    same = q.submit(_req(10), priority=0)
    assert same.outcome == "shed"
    with pytest.raises(ShedError):
        same.result()
    assert all(t.outcome is None for t in low)
    # higher priority: evicts the YOUNGEST lowest-priority pending ticket,
    # never an older one — admitted work keeps its FIFO place
    high = q.submit(_req(11), priority=3)
    assert high.outcome is None
    assert low[3].outcome == "shed"
    assert all(t.outcome is None for t in low[:3])
    # a second high submit now evicts the next-youngest low ticket
    high2 = q.submit(_req(12), priority=3)
    assert low[2].outcome == "shed" and high2.outcome is None
    assert q.snapshot()["depth"] == 4
    q.close()           # start=False close drains synchronously
    assert high.outcome == "served" and high2.outcome == "served"
    assert low[0].outcome == "served" and low[1].outcome == "served"
    s = q.snapshot()
    assert s["submitted"] == s["served"] + s["shed"] + s["failed"] \
        + s["deadline_exceeded"]
    _assert_released(eng)


def test_queued_expiry_swept_before_dispatch():
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk)
    q = AdmissionQueue(eng, capacity=8, start=False, clock=clk)
    doomed = q.submit(_req(0), deadline_ms=50)
    live = q.submit(_req(1), deadline_ms=50_000)
    clk.advance(1.0)
    q.pump(force=True)
    # the expired ticket resolved without touching the pipeline
    assert doomed.outcome == "deadline_exceeded" and doomed.response is None
    assert live.outcome == "served" and live.response.output is not None
    q.close()
    _assert_released(eng)


def test_pipeline_expiry_resolves_ticket_with_response():
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk)
    q = AdmissionQueue(eng, capacity=8, start=False, clock=clk)
    doomed = q.submit(_req(0), deadline_ms=500)
    orig = eng._execute_stage

    def late_execute(st):
        clk.advance(1.0)            # budget blows mid-pipeline
        return orig(st)

    eng._execute_stage = late_execute
    q.pump(force=True)
    assert doomed.outcome == "deadline_exceeded"
    assert doomed.response is not None and doomed.response.deadline_exceeded
    assert q.snapshot()["pipeline_expired"] == 1
    q.close()
    _assert_released(eng)


def test_submit_after_close_raises():
    eng = SparseKernelEngine()
    q = AdmissionQueue(eng, capacity=4, start=False)
    q.close()
    with pytest.raises(QueueClosed):
        q.submit(_req(0))


def test_batch_failure_resolves_every_ticket_loudly():
    eng = SparseKernelEngine(max_retries=0, warm_lane=False)
    inject_faults(eng.backends, eng.default_platform, "spmm",
                  FaultPlan.fail_calls(0))
    q = AdmissionQueue(eng, capacity=8, start=False)
    tickets = [q.submit(_req(i)) for i in range(3)]
    q.pump(force=True)
    for t in tickets:
        assert t.outcome == "failed" and t.error is not None
        with pytest.raises(Exception):
            t.result()
    assert q.snapshot()["failed"] == 3
    q.close()
    _assert_released(eng)


def test_open_loop_overload_every_submit_resolves():
    eng = SparseKernelEngine()
    with AdmissionQueue(eng, capacity=24, high_watermark=16,
                        max_batch=8) as q:
        tickets = [q.submit(_req(i % 12), deadline_ms=5_000,
                            priority=i % 3) for i in range(120)]
    outs = [t.outcome for t in tickets]
    assert all(o in ("served", "shed", "deadline_exceeded") for o in outs)
    s = q.snapshot()
    assert s["submitted"] == 120
    assert s["served"] + s["shed"] + s["deadline_exceeded"] + s["failed"] \
        == 120
    assert s["peak_depth"] <= 24
    _assert_released(eng)


def test_admission_prometheus_round_trip():
    eng = SparseKernelEngine()
    q = AdmissionQueue(eng, capacity=4, high_watermark=2, start=False)
    q.submit(_req(0), deadline_ms=0)              # deadline at submit
    q.submit(_req(1))
    q.submit(_req(2))
    q.submit(_req(3))                             # over watermark: shed
    samples = parse_prometheus_text(
        admission_prometheus_text(q, labels={"queue": "front"}))
    assert prom_get(samples, "repro_serving_admission_depth",
                    queue="front") == 2
    assert prom_get(samples, "repro_serving_admission_shed_total") == 1
    assert prom_get(samples,
                    "repro_serving_admission_deadline_exceeded_total") == 1
    assert prom_get(samples, "repro_serving_admission_submitted_total") == 4
    q.close()
    samples = parse_prometheus_text(admission_prometheus_text(q))
    assert prom_get(samples, "repro_serving_admission_closed") == 1
    assert prom_get(samples, "repro_serving_admission_served_total") == 2
    _assert_released(eng)


def test_engine_exposition_carries_deadline_counters():
    from repro.serving import prometheus_text
    clk = FakeClock()
    eng = SparseKernelEngine(clock=clk)
    r = _req(0)
    r.deadline_ts = 0.0
    clk.advance(1.0)
    eng.step([r])
    samples = parse_prometheus_text(prometheus_text(eng))
    assert prom_get(samples, "repro_serving_deadline_expired_total") == 1
    eng.drain()


# ------------------------------------------------------- fault-mode tests

def test_hang_fault_blocks_until_released():
    done = threading.Event()
    fx = FaultyExecutor(lambda c, m, o: "ok", FaultPlan.hang_calls(0, 1))
    out = {}

    def call():
        out["v"] = fx(None, None, None)
        done.set()

    t = threading.Thread(target=call, daemon=True)
    t.start()
    deadline = time.monotonic() + 5
    while fx.hanging == 0 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert fx.hanging == 1 and not done.is_set()
    fx.release_hangs()
    assert done.wait(5)
    assert out["v"] == "ok"                 # hang executes after release
    assert fx.injected["hang"] == 1
    assert fx(None, None, None) == "ok"     # outside the window: clean


def test_crash_fault_raises_base_exception():
    fx = FaultyExecutor(lambda c, m, o: "ok", FaultPlan.crash_calls(1, 2))
    assert fx(None, None, None) == "ok"
    with pytest.raises(ReplicaCrash):
        fx(None, None, None)
    assert not isinstance(ReplicaCrash("x"), Exception)
    assert fx.injected["crash"] == 1


# ------------------------------------------------- supervisor + shutdown

@pytest.mark.slow
def test_hung_replica_quarantined_rehomed_and_readmitted():
    se = ShardedEngine(n_replicas=2, cache_size=64, step_timeout_s=1.0,
                       hang_timeout_s=0.3, probation_s=0.05)
    r0 = se.replica("r0")
    fx = inject_faults(r0.backends, r0.default_platform, "spmm",
                       FaultPlan.hang_calls(0))
    out = se.step([_req(i) for i in range(12)])
    # zero lost requests: the hung replica's sub-batch re-served elsewhere
    assert all(r is not None and r.output is not None for r in out)
    s = se.stats()
    assert s["routing"]["step_timeouts"] >= 1
    assert s["routing"]["redispatched"] >= 1
    assert s["supervisor"]["replicas"]["r0"]["state"] == "quarantined"
    assert "r0" not in s["ring"]["nodes"]
    fx.release_hangs()
    fx.restore()
    deadline = time.monotonic() + 5
    while (se.stats()["load"]["r0"]["inflight"] and
           time.monotonic() < deadline):
        time.sleep(0.02)
    assert se.stats()["load"]["r0"]["inflight"] == 0
    time.sleep(0.1)                                 # probation elapses
    assert se.supervisor.poll_once() == 1           # probe + readmit
    s2 = se.stats()
    assert s2["supervisor"]["replicas"]["r0"]["state"] == "live"
    assert s2["supervisor"]["counters"]["readmissions"] == 1
    assert "r0" in s2["ring"]["nodes"]
    out2 = se.step([_req(100 + i) for i in range(6)])
    assert all(r.output is not None for r in out2)
    se.close()


def test_crashed_replica_quarantined_and_batch_reserved():
    se = ShardedEngine(n_replicas=2, cache_size=64)
    r0 = se.replica("r0")
    fx = inject_faults(r0.backends, r0.default_platform, "spmm",
                       FaultPlan.crash_calls(0, 1))
    out = se.step([_req(i) for i in range(12)])
    assert all(r is not None and r.output is not None for r in out)
    s = se.stats()
    assert s["routing"]["replica_crashes"] == 1
    assert s["supervisor"]["counters"]["quarantines"] == 1
    assert r0.stats()["arenas"]["outstanding_leases"] == 0
    fx.restore()
    se.supervisor.probation_s = 0.0
    assert se.supervisor.poll_once() == 1
    assert se.stats()["supervisor"]["replicas"]["r0"]["state"] == "live"
    se.close()


def test_watchdog_state_machine_with_fake_clock():
    clk = FakeClock()
    se = ShardedEngine(n_replicas=2, cache_size=16, clock=clk,
                       hang_timeout_s=2.0, probation_s=5.0)
    rep = se._replicas["r0"]
    with rep._hb_lock:
        rep.busy_since = 0.0            # a call that began at t=0
    clk.advance(1.0)
    assert se.supervisor.poll_once() == 0          # within hang_timeout
    clk.advance(2.0)
    assert se.supervisor.poll_once() == 1          # quarantined
    assert se.supervisor.state("r0") == "quarantined"
    assert se.stats()["supervisor"]["counters"]["hangs_detected"] == 1
    with rep._hb_lock:
        rep.busy_since = None           # the thread woke up
    clk.advance(4.0)
    assert se.supervisor.poll_once() == 0          # probation not over
    clk.advance(2.0)
    assert se.supervisor.poll_once() == 1          # probed, re-admitted
    assert se.supervisor.state("r0") == "live"
    se.close()


def test_last_replica_never_quarantined():
    se = ShardedEngine(n_replicas=1, cache_size=8)
    assert not se.supervisor.quarantine("r0", "hang")
    assert se.supervisor.state("r0") == "live"
    kinds = se.supervisor.events.snapshot()["by_kind"]
    assert kinds.get("quarantine_refused", 0) == 1
    se.close()


def test_graceful_shutdown_joins_threads_and_saves(tmp_path):
    path = tmp_path / "fleet.npz"
    se = ShardedEngine(n_replicas=2, cache_size=64, persist_path=path,
                       supervise=True, watchdog_interval_s=0.05)
    q = AdmissionQueue(se, capacity=32, max_batch=8)
    tickets = [q.submit(_req(i), deadline_ms=10_000) for i in range(10)]
    q.close()                       # drains, joins the batcher, drains se
    assert all(t.outcome == "served" for t in tickets)
    before = threading.active_count()
    se.close()                      # joins watchdog + serving threads
    assert path.exists()            # warm state saved on close
    assert threading.active_count() < before
    assert se.supervisor._thread is None
    for rep in se._replicas.values():
        for eng in (rep.engine,):
            assert eng.stats()["arenas"]["outstanding_leases"] == 0
    # idempotent, and the context manager re-enters the same path
    se.close()
    # a fresh fleet warm-starts from the close-time save
    with ShardedEngine(n_replicas=2, cache_size=64,
                       persist_path=path) as se2:
        assert se2.stats()["routing"]["warm_start_entries"] > 0
