"""Fig. 7: component-level ablation on SPADE SpMM.

Knock out each of IFE / FM (mapper) / LE (latent) through the full
pretrain->finetune pipeline (paper: 1.40 -> 1.26 / 1.16 / 1.01).
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import CostModelConfig, evaluate, finetune_target, pretrain_source

PAPER = {"full": 1.40, "no_ife": 1.26, "no_fm": 1.16, "no_le": 1.01}


def run():
    s = common.scale()
    ev = common.eval_dataset("spade", "spmm")
    rows = []
    variants = {
        "full": {},
        "no_ife": {"use_featurizer": False},
        "no_fm": {"use_mapper": False},
        "no_le": {"use_latent": False},
    }
    for name, kw in variants.items():
        def build(kw=kw):
            cfg = dataclasses.replace(common.model_config("cognate"), **kw)
            src, _ = common.source_dataset("spmm")
            latent = "ae" if cfg.use_latent else "none"
            pre = pretrain_source(cfg, src, epochs=s.pre_epochs,
                                  latent_kind=latent, ae_epochs=s.ae_epochs)
            ft_ds, _ = common.finetune_dataset("spade", "spmm")
            ft = finetune_target(pre, ft_ds, epochs=s.ft_epochs,
                                 latent_kind=latent, ae_epochs=s.ae_epochs)
            return evaluate(ft, ev)
        m = common.cached(f"fig7_{name}", build)
        rows.append((f"fig7/{name}_top1", f"{m['top1_geomean']:.3f}",
                     PAPER[name], ""))
    common.emit(rows)


if __name__ == "__main__":
    run()
