"""Benchmark entry point — one section per paper table/figure.

Prints ``name,value,paper,notes`` CSV per figure. Results are cached under
benchmarks/artifacts/ (first full run trains the models; later runs replay).
Scale via REPRO_BENCH_SCALE=tiny|default|paper (see benchmarks/common.py).

``--json PATH`` additionally writes every emitted row (with parsed numeric
values and any per-row metrics dicts, e.g. the serving scenarios' req/s and
p50/p99) to one JSON document — the ``BENCH_*.json`` artifacts the perf
trajectory is tracked with::

    PYTHONPATH=src python -m benchmarks.run serving routing --json BENCH_pr4.json
"""
from __future__ import annotations

import sys
import time
import traceback


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            sys.exit("--json requires a PATH argument")
        del argv[i:i + 2]

    t0 = time.time()
    from benchmarks import common
    s = common.scale()
    print(f"# REPRO_BENCH_SCALE={s.name}: {s.n_source} source / "
          f"{s.n_finetune} finetune / {s.n_eval} eval matrices, "
          f"{s.n_cfg_samples} cfg samples, res={s.resolution}, "
          f"ch_scale={s.ch_scale}, epochs={s.pre_epochs}/{s.ft_epochs} "
          f"(paper: 100/5/715, 100 cfgs, res~256, 100 epochs)")
    print()

    figures = [
        ("fig4", "benchmarks.fig4_speedups"),
        ("fig5", "benchmarks.fig5_per_matrix"),
        ("fig6", "benchmarks.fig6_training_curves"),
        ("fig7", "benchmarks.fig7_ablation_components"),
        ("fig8", "benchmarks.fig8_predictors"),
        ("fig9", "benchmarks.fig9_latent_choices"),
        ("fig10", "benchmarks.fig10_data_overhead"),
        ("fig11", "benchmarks.fig11_negative_transfer"),
        ("fig12", "benchmarks.fig12_finetune_samples"),
        ("table2", "benchmarks.table2_dce"),
        ("kernel", "benchmarks.kernel_bench"),
        ("bsr_preproc", "benchmarks.bsr_preproc"),
        ("serving", "benchmarks.serving_engine"),
        ("routing", "benchmarks.serving_routing"),
        ("faults", "benchmarks.serving_faults"),
        ("observability", "benchmarks.serving_observability"),
        ("shard", "benchmarks.serving_shard"),
        ("admission", "benchmarks.serving_admission"),
    ]
    only = set(argv)
    failures = []
    for name, module in figures:
        if only and name not in only:
            continue
        print(f"## {name} ({module})")
        common.begin_section(name)
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception as e:
            failures.append(name)
            print(f"{name}/ERROR,{type(e).__name__}: {e},,")
            traceback.print_exc()
        print(flush=True)
    elapsed = time.time() - t0
    print(f"# done in {elapsed:.0f}s; failures: {failures or 'none'}")
    if json_path:
        common.write_json(json_path, {"elapsed_s": round(elapsed, 1),
                                      "failures": failures,
                                      "argv": argv})
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
