"""Fig. 6: PRL loss + OPA / Kendall-tau across fine-tuning epochs.

Retrains the SPADE SpMM fine-tune with per-epoch validation to reproduce the
training-dynamics figure (paper: OPA -> 0.80, K-tau -> 0.61).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import finetune_target
from repro.core.trainer import TrainConfig, train_cost_model


def run():
    s = common.scale()
    pre = common.get_source_model("spmm", "cognate")
    ft_ds, _ = common.finetune_dataset("spade", "spmm")
    ev = common.eval_dataset("spade", "spmm")

    def build():
        from repro.core.latent import make_codec
        codec = make_codec("ae", ft_ds.het, epochs=s.ae_epochs)
        cfg = TrainConfig(epochs=s.ft_epochs, seed=0,
                          freeze_prefixes=("featurizer/blocks/0",
                                           "featurizer/blocks/1"),
                          batch_matrices=min(8, ft_ds.n_matrices),
                          eval_every=max(s.ft_epochs // 10, 1))
        params, hist = train_cost_model(pre.model_cfg, ft_ds, codec, cfg,
                                        init_params=pre.params,
                                        val_dataset=ev)
        return hist

    hist = common.cached("fig6_history", build)
    rows = [("fig6/train_prl_first", f"{hist['loss'][0]:.4f}", "", ""),
            ("fig6/train_prl_last", f"{hist['loss'][-1]:.4f}", "",
             "steady decline expected"),
            ("fig6/val_opa_last", f"{hist['val_opa'][-1]:.3f}", 0.80, ""),
            ("fig6/val_ktau_last", f"{hist['val_ktau'][-1]:.3f}", 0.61, ""),
            ("fig6/val_opa_curve",
             "|".join(f"{v:.2f}" for v in hist["val_opa"]), "", ""),
            ("fig6/val_ktau_curve",
             "|".join(f"{v:.2f}" for v in hist["val_ktau"]), "", "")]
    common.emit(rows)


if __name__ == "__main__":
    run()
