"""Serving-engine benchmark (ours): batched tuning + steady-state serving.

Measures what ``repro.serving`` buys over the PR-1 one-pattern-at-a-time
loop:

* **Batched-miss path** — a 32-request cold batch tuned via one
  ``KernelAutotuner.get_batch`` (a single jitted cost-model embed+score
  dispatch for all misses) vs 32 sequential ``KernelAutotuner.get`` calls.
  Both paths use the same learned ``Autotuner`` (randomly initialized
  tpu_pallas cost model — prediction quality is irrelevant to the dispatch
  cost being measured) with jits warmed, so the measured gap is the
  amortization, not compilation.  Acceptance bar: >= 3x.
* **Traffic mixes** — steady-state requests/sec and per-step p50/p99 latency
  through the full engine (partition -> batched score -> arena build) on
  three mixes: ``repeated`` (one hot 32-pattern working set served every
  step — hot LRU, pure slot rotation), ``shifting`` (the working set slides
  4 patterns per step), and ``cold`` (every pattern new — pure miss
  traffic).
* **Warm start** — the populated cache round-trips through
  ``repro.serving.persist``; a restarted engine serves the repeated mix with
  zero featurizations (asserted via ``featurize_calls``).
* **Mixed-platform traffic** — one engine fronts all three stock backends
  (``tpu_interpret``, ``tpu_pallas``, ``cpu_ref``) and a single ``step()``
  stream carries requests tagged per platform; per-backend requests/sec, hit
  rate, and serve p50/p99 come straight from ``stats()["backends"]``.  The
  scenario also restarts the engine from a *legacy* (version-1, pre-tag)
  persistence file and asserts the default backend warm-starts with zero
  featurizations.
* **Device-resident builds** — cold vs warm build latency of the numpy host
  scatter against the jitted device scatter (``BsrPlan.build_device``), and
  the async pipeline: a repeated-pattern mix with device-resident values
  and real kernel execution, timed in short interleaved segments served
  **overlapped** (default — batch N+1's scatter dispatches over batch N's
  in-flight kernels, ``drain()`` only at segment end) vs **synchronous**
  (``drain()`` after every step).  Asserts the warm path did zero
  host-numpy scatters via ``stats()["build_paths"]``; ``scripts/smoke.sh``
  gates overlapped req/s against synchronous req/s from the emitted
  metrics.

``python benchmarks/serving_engine.py --quick`` runs a reduced protocol for
smoke checks (``REPRO_BENCH_QUICK=1`` selects the same protocol through
``benchmarks.run``); ``python -m benchmarks.run serving`` runs the full one.
``--json PATH`` (standalone) writes the rows machine-readably — per-scenario
req/s and p50/p99 land as a per-row metrics dict (see
``benchmarks.common.emit``); routing policies are benchmarked separately in
``benchmarks/serving_routing.py``.
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_engine.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.core.autotune import Autotuner, KernelAutotuner
from repro.core.cognate import CostModelConfig, init_cost_model
from repro.core.latent import zero_codec
from repro.data import generate_matrix
from repro.serving import (KernelRequest, SparseKernelEngine, save_cache)

FAMILIES = ("uniform", "banded", "powerlaw", "blockdiag")


def _make_tuner(resolution: int = 8) -> Autotuner:
    """A learned tpu_pallas Autotuner with randomly initialized weights —
    the dispatch/batching economics of scoring are identical to a trained
    model's, without paying for training in a benchmark.  Sized small
    (ch_scale 0.125, res 8) so per-call dispatch overhead — the cost batching
    removes — dominates over raw conv FLOPs, which on this 1-core container
    do not amortize with batch size (on a real accelerator they would: one
    kernel launch for the whole batch)."""
    cfg = CostModelConfig(ch_scale=0.125)
    params = init_cost_model(jax.random.PRNGKey(0), cfg)
    return Autotuner("tpu_pallas", "spmm", params, cfg, zero_codec(),
                     resolution=resolution)


def _warm_buckets(tuner, pool, up_to: int):
    """Compile every power-of-two scoring shape once, outside timed loops."""
    b = 1
    while b <= up_to:
        tuner.scores_batch(pool[:b])
        b *= 2


def _matrices(n, seed0=0, n_rows=512, nnz=4000):
    return [generate_matrix(FAMILIES[i % len(FAMILIES)], seed=seed0 + i,
                            n_rows=n_rows, n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _bench_cold_batch(rows, batch: int, reps: int):
    """Sequential ``get`` loop vs one ``get_batch`` on a cold batch."""
    tuner = _make_tuner()
    warm = _matrices(batch, seed0=10_000)
    _warm_buckets(tuner, warm, batch)               # compile scoring shapes

    # best-of-reps on one fixed matrix set: each rep is a fresh (cold)
    # KernelAutotuner, so both paths re-tune every pattern every rep
    mats = _matrices(batch, seed0=20_000)
    t_seq = t_bat = float("inf")
    for _ in range(reps):
        kt = KernelAutotuner(tuner)
        t0 = time.perf_counter()
        seq_entries = [kt.get(m) for m in mats]
        t_seq = min(t_seq, time.perf_counter() - t0)

        kt2 = KernelAutotuner(tuner)
        t0 = time.perf_counter()
        bat_entries = kt2.get_batch(mats)
        t_bat = min(t_bat, time.perf_counter() - t0)
        same = all(a.config == b.config
                   for a, b in zip(seq_entries, bat_entries))
        assert kt2.featurize_calls == batch
    speedup = t_seq / t_bat
    rows.append((f"serving/cold{batch}/sequential_ms", f"{t_seq * 1e3:.1f}",
                 "", f"{batch} x KernelAutotuner.get"))
    rows.append((f"serving/cold{batch}/batched_ms", f"{t_bat * 1e3:.1f}", "",
                 f"one get_batch dispatch speedup={speedup:.1f}x "
                 f"configs_match={same} (bar: >=3x)"))
    return speedup


def _traffic(mix: str, n_steps: int, batch: int):
    """Per-step pattern indices.  Patterns within a micro-batch are distinct
    (one request per layer/expert/mask); repetition happens *across* steps —
    the double-buffered steady state the arena is built for."""
    for step in range(n_steps):
        if mix == "repeated":          # one hot working set, every step
            yield [j for j in range(batch)]
        elif mix == "shifting":        # working set slides 4 patterns/step
            yield [step * 4 + j for j in range(batch)]
        elif mix == "cold":            # every pattern brand new
            yield [step * batch + j for j in range(batch)]
        else:
            raise ValueError(mix)


def _values_for(pool):
    rng = np.random.default_rng(1)
    return {i: rng.normal(size=pool[i].nnz).astype(np.float32)
            for i in range(len(pool))}


def _bench_mix(rows, mix: str, tuner, n_steps: int, batch: int, pool):
    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256))
    values = _values_for(pool)
    t0 = time.perf_counter()
    for idxs in _traffic(mix, n_steps, batch):
        engine.step([KernelRequest(pool[i], values[i]) for i in idxs])
    elapsed = time.perf_counter() - t0
    engine.flush()
    s = engine.stats()

    # the PR-1 shape on identical traffic: one get + reuse-build per request,
    # no batched scoring, no arena, no telemetry
    kt = KernelAutotuner(tuner, cache_size=256)
    t0 = time.perf_counter()
    n = 0
    for idxs in _traffic(mix, n_steps, batch):
        for i in idxs:
            kt.get(pool[i]).build(values[i], reuse=True)
            n += 1
    t_base = time.perf_counter() - t0

    step_h = s["stages"]["step"]
    rows.append((
        f"serving/{mix}/engine_requests_per_s",
        f"{s['requests'] / elapsed:.0f}", "",
        f"hit_rate={s['hit_rate']:.2f} p50={step_h['p50_ms']:.2f}ms "
        f"p99={step_h['p99_ms']:.2f}ms featurize={s['featurize_calls']} "
        f"fallbacks={s['arena_fallbacks']}",
        {"req_per_s": s["requests"] / elapsed, "hit_rate": s["hit_rate"],
         "p50_ms": step_h["p50_ms"], "p99_ms": step_h["p99_ms"]}))
    rows.append((
        f"serving/{mix}/pr1_loop_requests_per_s", f"{n / t_base:.0f}", "",
        f"sequential get + reuse build; engine speedup="
        f"{t_base / elapsed:.2f}x",
        {"req_per_s": n / t_base, "engine_speedup": t_base / elapsed}))
    return s


def _bench_warm_start(rows, tuner, pool, batch: int):
    path = os.path.join(tempfile.mkdtemp(prefix="serving_bench_"),
                        "autotune_cache.npz")
    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256),
                                persist_path=path)
    engine.step([KernelRequest(pool[i]) for i in range(batch)])
    engine.flush()
    engine.save()
    t0 = time.perf_counter()
    engine2 = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256),
                                 persist_path=path)
    t_load = time.perf_counter() - t0
    engine2.step([KernelRequest(pool[i]) for i in range(batch)])
    engine2.flush()
    s = engine2.stats()
    zero_featurize = s["featurize_calls"] == 0
    rows.append(("serving/warm_start/restore_ms", f"{t_load * 1e3:.1f}", "",
                 f"{s['warm_start_entries']} entries; repeat traffic "
                 f"featurize_calls={s['featurize_calls']} "
                 f"zero_featurize={zero_featurize}"))
    assert zero_featurize, "warm-started engine re-featurized known traffic"


def _bench_mixed_platform(rows, tuner, n_steps: int, batch: int, pool):
    """All three stock backends behind one engine, one ``step()`` stream.

    Each step's micro-batch is split evenly across platform tags over a
    repeated working set (so steady state is per-backend cache hits), with
    a dense operand so every backend really executes its kernel.  Reports
    per-backend requests/sec, hit rate, and serve p50/p99 from
    ``stats()["backends"]``."""
    platforms = ("tpu_interpret", "tpu_pallas", "cpu_ref")
    per = batch // len(platforms)
    rhs = np.random.default_rng(2).normal(size=(pool[0].n_cols, 64)) \
        .astype(np.float32)
    values = _values_for(pool)
    # warm the (process-global) jit/compile caches on the same matrices via
    # a throwaway engine, so the timed loop measures serving, not first-call
    # compilation — the timed engine's own pattern caches still start cold
    warmup = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256))
    warmup.step([KernelRequest(pool[p * per + j], values[p * per + j],
                               "spmm", rhs, platform=plat)
                 for p, plat in enumerate(platforms) for j in range(per)])
    warmup.flush()
    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256))
    t0 = time.perf_counter()
    for _ in range(n_steps):
        reqs = [KernelRequest(pool[p * per + j], values[p * per + j],
                              "spmm", rhs, platform=plat)
                for p, plat in enumerate(platforms) for j in range(per)]
        engine.step(reqs)
    elapsed = time.perf_counter() - t0
    engine.flush()
    s = engine.stats()
    for plat in platforms:
        b = s["backends"][f"{plat}/spmm"]
        rows.append((
            f"serving/mixed/{plat}_requests_per_s",
            f"{b['requests'] / elapsed:.0f}", "",
            f"hit_rate={b['hit_rate']:.2f} "
            f"serve_p50={b['serve']['p50_ms']:.2f}ms "
            f"p99={b['serve']['p99_ms']:.2f}ms",
            {"req_per_s": b["requests"] / elapsed,
             "hit_rate": b["hit_rate"],
             "p50_ms": b["serve"]["p50_ms"],
             "p99_ms": b["serve"]["p99_ms"]}))
    assert set(s["backends"]) == {f"{p}/spmm" for p in platforms}, \
        "mixed stream did not reach all three backends"

    # legacy (pre-tag, version-1) persistence file: still warm-starts the
    # default backend with zero featurizations
    path = os.path.join(tempfile.mkdtemp(prefix="serving_bench_"),
                        "legacy_cache.npz")
    kt = KernelAutotuner(tuner, cache_size=256)
    kt.get_batch(pool[:per])
    save_cache(kt.cache, path, version=1)
    engine2 = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256),
                                 persist_path=path)
    engine2.step([KernelRequest(pool[i], values[i]) for i in range(per)])
    engine2.flush()
    s2 = engine2.stats()
    rows.append(("serving/mixed/legacy_warm_start_entries",
                 f"{s2['warm_start_entries']}", "",
                 f"v1 file -> default backend; repeat traffic "
                 f"featurize_calls={s2['featurize_calls']}"))
    assert s2["featurize_calls"] == 0, \
        "legacy warm-started engine re-featurized known traffic"


def _bench_device_build(rows, tuner, n_segments: int, seg_steps: int,
                        batch: int, reps: int):
    """Device-resident build path + async overlapped execution.

    Part 1 — one plan, build latency: cold (first jitted dispatch, incl.
    compile) and warm best-of for the host numpy scatter vs the device
    scatter (both forced to completion for a fair measurement; in serving
    the device dispatch returns immediately).

    Part 2 — a repeated-pattern mix with **device-resident** values and a
    dense operand (real kernel execution) through ONE engine, timed in
    short alternating segments: **synchronous** (``drain()`` after every
    step — no overlap window) vs **overlapped** (the engine's two-deep
    pipeline: batch N+1's scatter+dispatch rides over batch N's in-flight
    kernels, drain only at segment end).  Interleaving segments and taking
    each mode's best makes the comparison robust to machine-load drift —
    on a single saturated CPU the expected ratio is ~1.0 (compute has no
    spare core to overlap into; on a real accelerator the async pipeline
    hides the whole host side), so the smoke gate allows small noise
    below 1x but catches the async path becoming materially slower.
    Asserts via the engine's build-path counters that the warm path did
    zero host-numpy scatters."""
    fams = ("uniform", "banded", "powerlaw", "blockdiag")
    from repro.data import generate_matrix
    mats = [generate_matrix(fams[i % 4], seed=40_000 + i, n_rows=256,
                            n_cols=256, target_nnz=1500)
            for i in range(batch)]
    rng = np.random.default_rng(4)
    rhs = rng.normal(size=(mats[0].n_cols, 32)).astype(np.float32)
    values = _values_for(mats)
    dev_values = {i: jnp.asarray(values[i]) for i in range(batch)}

    kt = KernelAutotuner(tuner, cache_size=256)
    plan = kt.get(mats[0]).plan
    v, dv = values[0], dev_values[0]
    t0 = time.perf_counter()
    jax.block_until_ready(plan.build_device(dv).data)
    cold_dev_ms = (time.perf_counter() - t0) * 1e3      # incl. jit compile
    t_host = t_dev = float("inf")
    plan.build(v, reuse=True)           # pre-zero the reusable host buffer
    for _ in range(reps * 4):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.build(v, reuse=True).data)
        t_host = min(t_host, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(plan.build_device(dv).data)
        t_dev = min(t_dev, time.perf_counter() - t0)
    rows.append(("serving/device_build/warm_build_ms",
                 f"{t_dev * 1e3:.3f}", "",
                 f"device scatter (forced complete); host={t_host*1e3:.3f}ms "
                 f"cold_device={cold_dev_ms:.1f}ms (incl. jit compile)",
                 {"device_ms": t_dev * 1e3, "host_ms": t_host * 1e3,
                  "cold_device_ms": cold_dev_ms}))

    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256))
    reqs = [KernelRequest(mats[i], dev_values[i], "spmm", rhs)
            for i in range(batch)]
    engine.step(reqs)                   # untimed: tune patterns + compile
    engine.drain()
    best = {True: 0.0, False: 0.0}      # sync? -> best req/s
    for seg in range(n_segments):
        sync = (seg % 2 == 0)           # alternate so load drift hits both
        t0 = time.perf_counter()
        for _ in range(seg_steps):
            engine.step(reqs)
            if sync:
                engine.drain()
        engine.drain()                  # isolate segments from each other
        best[sync] = max(best[sync],
                         seg_steps * batch / (time.perf_counter() - t0))
    best_async, best_sync = best[False], best[True]
    s = engine.stats()
    bp = s["build_paths"]
    assert bp["host"] == 0, \
        f"device-resident mix fell back to {bp['host']} host scatters"
    assert bp["device"] == (n_segments * seg_steps + 1) * batch
    rows.append((
        "serving/device_build/overlapped_requests_per_s",
        f"{best_async:.0f}", "",
        f"two-deep async pipeline; drain at segment end; "
        f"device_builds={bp['device']} host_builds={bp['host']} "
        f"overlap_ratio={bp['overlap_ratio']:.2f} "
        f"drain_waits={bp['drain_waits']}",
        {"req_per_s": best_async, "overlap_ratio": bp["overlap_ratio"],
         "device_builds": float(bp["device"]),
         "host_builds": float(bp["host"])}))
    rows.append((
        "serving/device_build/synchronous_requests_per_s",
        f"{best_sync:.0f}", "",
        f"drain() after every step; overlap speedup="
        f"{best_async / best_sync:.2f}x (target >=1x; smoke gates "
        f">=0.95x for single-host CPU noise)",
        {"req_per_s": best_sync, "overlap_speedup": best_async / best_sync}))
    if best_async < best_sync:
        print(f"# WARNING: overlapped {best_async:.0f} req/s below "
              f"synchronous {best_sync:.0f} req/s")


def _bench_warm_lane(rows, tuner, n_segments: int, seg_steps: int,
                     batch: int):
    """The fused warm fast path vs the PR-1 loop on hot traffic.

    Build-only repeated traffic (host values, no operand) over one hot
    working set — the steady state the warm lane collapses to pattern
    digest -> warm-table replay -> fused aligned-buffer scatter -> async
    dispatch.  The baseline is the PR-1 shape on identical traffic: one
    ``get`` + ``build(reuse=True)`` per request.  Timed in short
    **interleaved A/B segments** (engine segment, then loop segment,
    repeated) with best-of per mode, so machine-load drift hits both
    modes instead of biasing whichever ran last; the engine drains only
    at segment ends, so within a segment batch N+1's scatter overlaps
    batch N's in-flight dispatches.  ``scripts/smoke.sh`` gates
    ``engine_speedup >= 1.2x`` and ``overlap_ratio >= 0.6`` from the
    emitted metrics."""
    mats = _matrices(batch, seed0=50_000)
    values = _values_for(mats)
    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256))
    kt = KernelAutotuner(tuner, cache_size=256)

    def reqs():
        return [KernelRequest(mats[i], values[i]) for i in range(batch)]

    engine.step(reqs())                 # untimed: tune + record warm table
    engine.drain()
    for i in range(batch):              # untimed: tune the baseline cache
        kt.get(mats[i]).build(values[i], reuse=True)

    best_e = best_b = 0.0
    for _seg in range(n_segments):
        t0 = time.perf_counter()
        for _ in range(seg_steps):
            engine.step(reqs())
        engine.drain()                  # only at segment end: async inside
        best_e = max(best_e,
                     seg_steps * batch / (time.perf_counter() - t0))
        t0 = time.perf_counter()
        for _ in range(seg_steps):
            for i in range(batch):
                kt.get(mats[i]).build(values[i], reuse=True)
        best_b = max(best_b,
                     seg_steps * batch / (time.perf_counter() - t0))

    s = engine.stats()
    wl, bp = s["warm_lane"], s["build_paths"]
    speedup = best_e / best_b
    assert wl["steps"] == n_segments * seg_steps, \
        f"hot traffic fell off the warm lane: {wl['steps']} warm steps " \
        f"of {n_segments * seg_steps}"
    assert wl["fused_builds"] == n_segments * seg_steps * batch, \
        "warm steps did not all take the fused build path"
    rows.append((
        "serving/warm_lane/engine_requests_per_s", f"{best_e:.0f}", "",
        f"fused warm lane; warm_steps={wl['steps']} "
        f"fused_builds={wl['fused_builds']} "
        f"overlap_ratio={bp['overlap_ratio']:.2f} "
        f"sampled_steps={wl['sampled_steps']}",
        {"req_per_s": best_e, "overlap_ratio": bp["overlap_ratio"],
         "warm_steps": float(wl["steps"]),
         "fused_builds": float(wl["fused_builds"])}))
    rows.append((
        "serving/warm_lane/pr1_loop_requests_per_s", f"{best_b:.0f}", "",
        f"sequential get + reuse build on the same hot mix; "
        f"engine_speedup={speedup:.2f}x (gate: >=1.2x)",
        {"req_per_s": best_b, "engine_speedup": speedup}))
    if speedup < 1.2:
        print(f"# WARNING: warm-lane speedup {speedup:.2f}x below 1.2x bar")


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    batch = 32
    n_steps = 10 if quick else 40
    reps = 4 if quick else 8

    speedup = _bench_cold_batch(rows, batch=batch, reps=reps)

    tuner = _make_tuner()
    pool = _matrices(n_steps * batch + batch, seed0=0)
    _warm_buckets(tuner, pool, batch)   # compile shapes outside timed loops
    for mix in ("repeated", "shifting", "cold"):
        _bench_mix(rows, mix, tuner, n_steps, batch, pool)
    _bench_warm_start(rows, tuner, pool, batch)
    _bench_mixed_platform(rows, tuner, n_steps=4 if quick else 12,
                          batch=12, pool=pool)
    _bench_device_build(rows, tuner, n_segments=8 if quick else 12,
                        seg_steps=3, batch=16, reps=2 if quick else 3)
    _bench_warm_lane(rows, tuner, n_segments=4 if quick else 8,
                     seg_steps=5 if quick else 8, batch=batch)
    common.emit(rows)
    if speedup < 3.0:
        print(f"# WARNING: batched-miss speedup {speedup:.1f}x below 3x bar")


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("serving")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
