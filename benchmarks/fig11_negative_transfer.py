"""Fig. 11: negative transfer — source-set size sweep (paper: 100 source
matrices beats 1000; over-specialization to the source platform hurts)."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate


def run():
    s = common.scale()
    ev = common.eval_dataset("spade", "spmm")
    rows = []
    sizes = sorted({max(s.n_finetune, 5), s.n_source // 3, s.n_source,
                    s.max_suite})
    for n in sizes:
        model = common.get_finetuned("spade", "spmm", "cognate", n_src=n)
        m = common.cached(f"fig11_src{n}",
                          lambda model=model: evaluate(model, ev))
        rows.append((f"fig11/src_{n}_top1", f"{m['top1_geomean']:.3f}",
                     {100: 1.40}.get(n, ""),
                     f"source pretrain on {n} matrices"))
    common.emit(rows)


if __name__ == "__main__":
    run()
