"""Fig. 10: data-collection overhead without transfer learning — accelerator-
only models need 20-200x more target samples to match COGNATE's few-shot
speedup (paper: NT needs 100-1000 matrices vs TL's 5)."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate


def run():
    s = common.scale()
    ev = common.eval_dataset("spade", "spmm")
    tl5 = common.cached("eval_fig4_cognate_spade_spmm",
                        lambda: evaluate(common.get_finetuned(
                            "spade", "spmm", "cognate"), ev))
    rows = [("fig10/TL_5_top1", f"{tl5['top1_geomean']:.3f}", 1.40,
             f"5 target matrices, DCE={5 * s.n_cfg_samples * 1000:.0f}")]
    # no-transfer at increasing target-set sizes (scaled from 5/100/1000)
    for n in (s.n_finetune, s.n_finetune * 4, s.n_source):
        model = common.get_scratch("spade", "spmm", n_mat=n)
        m = common.cached(f"fig10_nt_{n}",
                          lambda model=model: evaluate(model, ev))
        rows.append((f"fig10/NT_{n}_top1", f"{m['top1_geomean']:.3f}",
                     {5: 1.29, 1000: 1.43}.get(n, ""),
                     f"DCE={n * s.n_cfg_samples * 1000:.0f}"))
    common.emit(rows)


if __name__ == "__main__":
    run()
