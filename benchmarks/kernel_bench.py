"""Kernel benchmark (ours): Pallas BSR SpMM/SDDMM tile-config sweep.

Wall-times in interpret mode are meaningless for TPU perf, so this bench
reports (a) correctness vs the jnp oracle across the tile space, (b) the
analytic roofline cost of each tile config from the TPU platform model, and
(c) the config chosen by the COGNATE KernelAutotuner heuristic vs the model's
own optimum — the kernels' autotuning story end-to-end.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core.autotune import KernelAutotuner
from repro.data import generate_matrix, matrix_stats
from repro.hw import get_platform
from repro.kernels import ops


def run():
    rows = []
    tpu = get_platform("tpu_pallas")
    rng = np.random.default_rng(0)
    for fam in ("banded", "uniform", "powerlaw", "blockdiag"):
        mat = generate_matrix(fam, seed=7, n_rows=4096, n_cols=4096,
                              target_nnz=200_000)
        stats = matrix_stats(mat)
        rts = tpu.runtime(stats, "spmm", n_cols=mat.n_cols, noise=False)
        best = int(np.argmin(rts))
        best_params = {k: int(v[best]) for k, v in tpu.space.params.items()}
        heur = KernelAutotuner.heuristic(mat)
        # model runtime of the heuristic's bm (match on bm, best over rest)
        mask = tpu.space.params["bm"] == heur["block_m"]
        heur_rt = float(rts[mask].min())
        rows.append((f"kernel/{fam}/model_best",
                     f"bm={best_params['bm']} rt={rts[best]:.3f}ms", "", ""))
        rows.append((f"kernel/{fam}/heuristic",
                     f"bm={heur['block_m']} rt={heur_rt:.3f}ms", "",
                     f"gap={(heur_rt/rts[best]):.2f}x"))

    # correctness sweep on a small slice (interpret mode, CPU)
    dense = ((rng.random((128, 256)) < 0.08) *
             rng.normal(size=(128, 256))).astype(np.float32)
    b = rng.normal(size=(256, 128)).astype(np.float32)
    worst = 0.0
    for bm in (8, 32, 64):
        a = ops.bsr_from_dense(dense, block_m=bm)
        got = np.asarray(ops.spmm(a, jnp.asarray(b)))
        want = np.asarray(ops.spmm_ref(a, jnp.asarray(b)))
        worst = max(worst, float(np.abs(got - want).max()))
    rows.append(("kernel/spmm_sweep_maxerr", f"{worst:.2e}", "", "vs ref.py"))
    common.emit(rows)


if __name__ == "__main__":
    run()
