"""Fig. 9: heterogeneous-component encoders — AE vs PCA vs VAE vs FA
(paper: autoencoders best, judged by downstream speedup + AE val loss)."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate


def run(seeds=(0, 1, 2)):
    import numpy as np
    ev = common.eval_dataset("spade", "spmm")
    rows = []
    for latent in ("ae", "vae", "pca", "fa"):
        vals = []
        for seed in seeds:
            model = common.get_finetuned("spade", "spmm", "cognate",
                                         latent_kind=latent, seed=seed)
            m = common.cached(f"fig9_{latent}_{seed}",
                              lambda model=model: evaluate(model, ev))
            vals.append(m["top1_geomean"])
        vals = np.asarray(vals)
        rows.append((f"fig9/{latent}_top1",
                     f"{vals.mean():.3f}±{vals.std():.3f}",
                     1.40 if latent == "ae" else "", ""))
    # AE reconstruction-loss comparison (the paper's selection criterion)
    from repro.core.latent import train_autoencoder
    ft_ds, _ = common.finetune_dataset("spade", "spmm")
    for kind, var in (("ae", False), ("vae", True)):
        codec = common.cached(
            f"fig9_codec_{kind}",
            lambda var=var: train_autoencoder(ft_ds.het, epochs=200,
                                              variational=var))
        rows.append((f"fig9/{kind}_recon_loss",
                     f"{codec.history['loss'][-1]:.5f}", "",
                     "final reconstruction MSE"))
    common.emit(rows)


if __name__ == "__main__":
    run()
