"""Fig. 8: predictor-head alternatives (MLP vs LSTM/GRU/Transformer) through
the full transfer pipeline (paper: MLP 1.40 best; TF next at 1.36)."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate

PAPER = {"mlp": 1.40, "tf": 1.36, "lstm": "", "gru": ""}


def run():
    ev = common.eval_dataset("spade", "spmm")
    rows = []
    for pred in ("mlp", "lstm", "gru", "tf"):
        model = common.get_finetuned("spade", "spmm", "cognate", predictor=pred)
        m = common.cached(f"fig8_{pred}",
                          lambda model=model: evaluate(model, ev))
        rows.append((f"fig8/{pred}_top1", f"{m['top1_geomean']:.3f}",
                     PAPER[pred], ""))
    common.emit(rows)


if __name__ == "__main__":
    run()
