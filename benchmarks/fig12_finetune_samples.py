"""Fig. 12: fine-tuning sample-count sweep (paper: 3 -> 1.30, 5 -> 1.40,
7 -> 1.41; diminishing returns beyond 5 matrices)."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate

PAPER = {3: 1.30, 5: 1.40, 7: 1.41}


def run():
    s = common.scale()
    ev = common.eval_dataset("spade", "spmm")
    rows = []
    for n in (3, 5, 7, s.n_finetune * 4):
        model = common.get_finetuned("spade", "spmm", "cognate", n_ft=n)
        m = common.cached(f"fig12_ft{n}",
                          lambda model=model: evaluate(model, ev))
        rows.append((f"fig12/ft_{n}_top1", f"{m['top1_geomean']:.3f}",
                     PAPER.get(n, ""), f"{n} fine-tune matrices"))
    common.emit(rows)


if __name__ == "__main__":
    run()
