"""Table 2 (App. A.3): speedup / APE / DCE across NT-d, TL-d, CPU-d and
zero-shot model categories. DCE uses beta_CPU=1, beta_SPADE=1000."""
from __future__ import annotations

from benchmarks import common
from repro.core import evaluate

BETA_SPADE, BETA_CPU = 1000.0, 1.0

PAPER = {  # (category) -> (speedup, APE, DCE/1e6) at paper scale
    "NT_5": (1.29, 15.02, 0.50), "TL_5": (1.40, 9.58, 0.51),
    "CPU_5": (1.07, 27.80, 0.50), "ZeroShot": (0.71, 46.22, 0.01),
}


def run():
    s = common.scale()
    ev = common.eval_dataset("spade", "spmm")
    cfgs = s.n_cfg_samples
    rows = []

    def emit_row(name, m, dce):
        p = PAPER.get(name, ("", "", ""))
        rows.append((f"table2/{name}",
                     f"speedup={m['top1_geomean']:.3f} ape={m['top1_ape']:.1f} "
                     f"dce_m={dce/1e6:.3f}",
                     f"speedup={p[0]} ape={p[1]} dce_m={p[2]}", ""))

    # NT d: target-only models
    for n in (s.n_finetune, s.n_finetune * 4, s.n_source):
        m = common.cached(f"fig10_nt_{n}", lambda n=n: evaluate(
            common.get_scratch("spade", "spmm", n_mat=n), ev))
        emit_row(f"NT_{n}" if n != s.n_finetune else "NT_5", m,
                 n * cfgs * BETA_SPADE)
    # TL 5: the headline transfer model
    m = common.cached("eval_fig4_cognate_spade_spmm", lambda: evaluate(
        common.get_finetuned("spade", "spmm", "cognate"), ev))
    emit_row("TL_5", m, s.n_source * cfgs * BETA_CPU
             + s.n_finetune * cfgs * BETA_SPADE)
    # CPU d: source-size variants fine-tuned on 5 (shared with fig11)
    small = max(s.n_finetune, 5)
    m = common.cached(f"fig11_src{small}", lambda: evaluate(
        common.get_finetuned("spade", "spmm", "cognate", n_src=small), ev))
    emit_row("CPU_5", m, small * cfgs * BETA_CPU
             + s.n_finetune * cfgs * BETA_SPADE)
    # Zero-shot
    m = common.cached("eval_fig4_zero_shot_spade_spmm", lambda: evaluate(
        common.get_zero_shot("spade", "spmm"), ev))
    emit_row("ZeroShot", m, s.n_source * cfgs * BETA_CPU)
    common.emit(rows)


if __name__ == "__main__":
    run()
