"""Sharded-serving benchmark: N replicas vs 1 over a simulated device mesh.

What sharding buys on this 1-core container is **aggregate cache
capacity**, not thread parallelism: the scenarios are sized so the working
set W overflows one replica's LRU (C < W) but fits the fleet's (W <= N*C).

* **Cold capacity mix** — W distinct patterns cycled pass after pass,
  prepare-only (tune + plan, no kernel execution), with per-replica
  autotune cache C < W <= 4C.  The single replica LRU-thrashes
  perpetually — every pass re-featurizes, re-scores, and re-sorts all W
  patterns; four replicas partition the digest space so each shard's
  share fits its cache and steady state is pure cache hits.  Timed in
  interleaved best-of passes; ``scripts/smoke.sh`` gates ``speedup >=
  2.5x`` from the emitted metrics.  Both sides run through
  ``ShardedEngine`` (n=1 vs n=4) so the comparison isolates replica
  count, not layer overhead.
* **Shifting mix** — the working set slides a few patterns per step (the
  steady cold-tail regime); parity row, no gate.
* **Rebalance, synchronized** — a 3-replica fleet's outputs are compared
  bit-for-bit against an unsharded reference engine sharing the same
  tuner; then ``add_replica`` + ``remove_replica`` and the moved digests
  must serve warm (zero featurize delta, ``migrated_entries > 0``).
* **Rebalance under load** — a driver thread serves continuously while a
  replica is added and then removed.  Gate: ``lost_requests == 0`` (every
  step returns a full response set, nothing raises); hit-rate recovery is
  reported as the post-rebalance featurize delta (a digest served in the
  migration window may go cold once — that race is allowed, losing a
  request is not).
* **Device placement** — replicas place round-robin over the host mesh's
  data slices (``parallel.sharding.replica_devices``).  Under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` this is 8 real
  XLA devices; the smoke gate asserts the bench saw all 8 and spread the
  4-replica fleet over 4 distinct devices.

``python benchmarks/serving_shard.py --quick`` runs the reduced smoke
protocol (``REPRO_BENCH_QUICK=1`` selects it through ``benchmarks.run``);
``--json PATH`` (standalone) writes the rows machine-readably.
"""
from __future__ import annotations

import os
import sys
import threading
import time

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_shard.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from benchmarks.serving_engine import _make_tuner, _warm_buckets
from repro.core.autotune import KernelAutotuner
from repro.data import generate_matrix
from repro.serving import KernelRequest, ShardedEngine, SparseKernelEngine

FAMILIES = ("uniform", "banded", "powerlaw", "blockdiag")


def _matrices(n, seed0=0, n_rows=256, nnz=1500):
    return [generate_matrix(FAMILIES[i % len(FAMILIES)], seed=seed0 + i,
                            n_rows=n_rows, n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _values_for(pool):
    rng = np.random.default_rng(1)
    return {i: rng.normal(size=pool[i].nnz).astype(np.float32)
            for i in range(len(pool))}


def _factory(tuner, cache_size):
    """Replica factory sharing one learned ``Autotuner`` (one set of cost-
    model weights, one jit cache) while giving each replica its own
    ``KernelAutotuner`` LRU — the per-shard capacity being measured."""
    def make(rid, device):
        return SparseKernelEngine(KernelAutotuner(tuner,
                                                  cache_size=cache_size))
    return make


def _mesh_or_none():
    try:
        from repro.launch.mesh import make_host_mesh
        return make_host_mesh()
    except Exception as e:                       # noqa: BLE001
        print(f"# no host mesh ({e}); placing replicas on jax.devices()")
        return None


def _cycle_pass(se, pool, batch):
    """One prepare-only pass over the working set in ``batch``-sized
    steps — pure tuning traffic, where a hit is a cache lookup and a miss
    pays the full featurize + score + plan-sort pipeline."""
    for s0 in range(0, len(pool), batch):
        idxs = range(s0, min(s0 + batch, len(pool)))
        se.step([KernelRequest(pool[i]) for i in idxs])
    se.drain()


def _bench_capacity(rows, tuner, mesh, *, n_big, cache, w_set, batch,
                    segments):
    # larger nnz than the other scenarios: the miss pipeline (featurize +
    # coordinate sort) scales with nnz, the hit path barely does — the
    # capacity regime's hit/miss gap is the quantity under test
    pool = _matrices(w_set, seed0=60_000, nnz=3000)
    engines = {
        1: ShardedEngine(n_replicas=1, engine_factory=_factory(tuner, cache),
                         mesh=mesh),
        n_big: ShardedEngine(n_replicas=n_big,
                             engine_factory=_factory(tuner, cache),
                             mesh=mesh),
    }
    best = {n: 0.0 for n in engines}
    try:
        for se in engines.values():
            _cycle_pass(se, pool, batch)            # untimed warmup pass
        for _seg in range(segments):
            for n, se in engines.items():           # interleaved best-of
                t0 = time.perf_counter()
                _cycle_pass(se, pool, batch)
                best[n] = max(best[n],
                              w_set / (time.perf_counter() - t0))
        stats = {n: se.stats() for n, se in engines.items()}
        devices = {n: se.stats()["devices"] for n, se in engines.items()}
    finally:
        for se in engines.values():
            se.close()
    speedup = best[n_big] / best[1]
    s1, sN = stats[1], stats[n_big]
    # the mechanism check: N=1 thrashed (a cache smaller than the working
    # set never stops featurizing), the fleet went warm
    rows.append((
        f"shard/cold/n{n_big}_requests_per_s", f"{best[n_big]:.0f}", "",
        f"{n_big}x cache={cache} vs working set {w_set}: "
        f"hit_rate={sN['aggregate']['hit_rate']:.2f} "
        f"featurize={sN['aggregate']['featurize_calls']} "
        f"cache_size={sN['aggregate']['cache_size']}",
        {"req_per_s": best[n_big],
         "hit_rate": sN["aggregate"]["hit_rate"],
         "featurize_calls": float(sN["aggregate"]["featurize_calls"]),
         "n_replicas": float(n_big)}))
    rows.append((
        f"shard/cold/n1_requests_per_s", f"{best[1]:.0f}", "",
        f"single replica LRU-thrashes (cache {cache} < {w_set}): "
        f"hit_rate={s1['aggregate']['hit_rate']:.2f} "
        f"featurize={s1['aggregate']['featurize_calls']}; "
        f"shard speedup={speedup:.2f}x (gate: >=2.5x)",
        {"req_per_s": best[1], "hit_rate": s1["aggregate"]["hit_rate"],
         "featurize_calls": float(s1["aggregate"]["featurize_calls"]),
         "speedup": speedup}))
    n_devices = len(jax.devices())
    rows.append((
        "shard/devices", f"{n_devices}", "",
        f"replica placement: n1={sorted(set(devices[1].values()))} "
        f"n{n_big} spread over "
        f"{len(set(devices[n_big].values()))} distinct devices",
        {"n_devices": float(n_devices),
         "distinct_replica_devices":
             float(len(set(devices[n_big].values())))}))
    if speedup < 2.5:
        print(f"# WARNING: shard capacity speedup {speedup:.2f}x "
              f"below 2.5x bar")
    return speedup


def _bench_shifting(rows, tuner, mesh, *, n_big, cache, batch, n_steps):
    warm_steps = 8          # untimed prefix: lets every replica device
                            # compile its scoring buckets before the clock
    pool = _matrices((warm_steps + n_steps) * 4 + batch, seed0=70_000)
    values = _values_for(pool)
    res = {}
    for n in (1, n_big):
        se = ShardedEngine(n_replicas=n, engine_factory=_factory(tuner, cache),
                           mesh=mesh)
        try:
            for step in range(warm_steps):
                idxs = range(step * 4, step * 4 + batch)
                se.step([KernelRequest(pool[i], values[i]) for i in idxs])
            se.drain()
            t0 = time.perf_counter()
            for step in range(warm_steps, warm_steps + n_steps):
                idxs = range(step * 4, step * 4 + batch)
                se.step([KernelRequest(pool[i], values[i]) for i in idxs])
            se.drain()
            res[n] = (n_steps * batch / (time.perf_counter() - t0),
                      se.stats()["aggregate"]["hit_rate"])
        finally:
            se.close()
    for n, (rps, hr) in res.items():
        rows.append((
            f"shard/shifting/n{n}_requests_per_s", f"{rps:.0f}", "",
            f"working set slides 4 patterns/step; hit_rate={hr:.2f}"
            + ("" if n == 1 else
               f"; vs n1: {rps / res[1][0]:.2f}x (parity row, no gate: "
               f"fan-out splits each step's miss batch into smaller "
               f"scoring dispatches — on one core sharding pays via "
               f"capacity, not per-step parallelism)"),
            {"req_per_s": rps, "hit_rate": hr}))


def _bench_rebalance_sync(rows, tuner, mesh, *, cache, batch):
    """Correctness anchor: sharded == unsharded bit for bit, and a replica
    add/remove re-homes cache rows warm."""
    mats = _matrices(batch, seed0=80_000)
    values = _values_for(mats)
    rhs = np.random.default_rng(5).normal(size=(mats[0].n_cols, 32)) \
        .astype(np.float32)

    def reqs():
        return [KernelRequest(mats[i], values[i], "spmm", rhs)
                for i in range(batch)]

    ref = SparseKernelEngine(KernelAutotuner(tuner, cache_size=cache))
    want = [np.asarray(r.output) for r in ref.step(reqs())]
    ref.drain()
    se = ShardedEngine(n_replicas=3, engine_factory=_factory(tuner, cache),
                       mesh=mesh)
    try:
        got = se.step(reqs())
        se.drain()
        outputs_match = all(np.array_equal(w, np.asarray(g.output))
                            for w, g in zip(want, got))
        rid = se.add_replica()
        fz0 = se.featurize_calls
        se.step(reqs())
        se.drain()
        grow_delta = se.featurize_calls - fz0
        grow_moved = se.stats()["routing"]["migrated_entries"]
        se.remove_replica(rid)
        fz0 = se.featurize_calls
        out2 = se.step(reqs())
        se.drain()
        shrink_delta = se.featurize_calls - fz0
        still_match = all(np.array_equal(w, np.asarray(g.output))
                          for w, g in zip(want, out2))
        s = se.stats()
    finally:
        se.close()
    outputs_match = outputs_match and still_match
    rows.append((
        "shard/rebalance/synchronized", f"{s['routing']['migrated_entries']}",
        "", f"outputs_match={outputs_match} grow: moved={grow_moved} "
        f"featurize_delta={grow_delta}; shrink: "
        f"moved={s['routing']['migrated_entries'] - grow_moved} "
        f"featurize_delta={shrink_delta} (both deltas must be 0: "
        f"migrated rows serve warm)",
        {"outputs_match": float(outputs_match),
         "migrated_entries": float(s["routing"]["migrated_entries"]),
         "featurize_delta": float(grow_delta + shrink_delta)}))
    if not outputs_match:
        common.dump_debug("shard_rebalance", s)
        raise AssertionError("sharded outputs diverged from the unsharded "
                             "reference")
    return grow_delta + shrink_delta


def _bench_rebalance_under_load(rows, tuner, mesh, *, cache, batch,
                                settle_s):
    """Serving never stops while the fleet grows and shrinks.  Lost = a
    ``None`` response, a short response set, or a raised step."""
    mats = _matrices(batch, seed0=90_000)
    values = _values_for(mats)
    se = ShardedEngine(n_replicas=2, engine_factory=_factory(tuner, cache),
                       mesh=mesh)
    try:
        se.step([KernelRequest(mats[i], values[i]) for i in range(batch)])
        se.drain()                                 # warm the steady state
        stop = threading.Event()
        served, lost = [0], [0]
        errors: list[BaseException] = []

        def drive():
            try:
                while not stop.is_set():
                    rs = se.step([KernelRequest(mats[i], values[i])
                                  for i in range(batch)])
                    ok = sum(r is not None for r in rs)
                    served[0] += ok
                    lost[0] += batch - ok
            except BaseException as e:  # noqa: BLE001 — counted as loss
                errors.append(e)
                lost[0] += batch

        t = threading.Thread(target=drive)
        fz0 = se.featurize_calls
        t.start()
        time.sleep(settle_s)
        rid = se.add_replica()
        time.sleep(settle_s)
        se.remove_replica(rid)
        time.sleep(settle_s)
        stop.set()
        t.join(timeout=120)
        alive = t.is_alive()
        # recovery probe: one more synchronized pass must be all-warm
        se.step([KernelRequest(mats[i], values[i]) for i in range(batch)])
        se.drain()
        fz_delta = se.featurize_calls - fz0
        s = se.stats()
    finally:
        se.close()
    n_lost = lost[0] + (batch if alive else 0)
    rows.append((
        "shard/rebalance/under_load_lost_requests", f"{n_lost}", "",
        f"served={served[0]} requests across "
        f"{s['routing']['steps']} steps while growing 2->3->2; "
        f"errors={[type(e).__name__ for e in errors] or 'none'} "
        f"migrated={s['routing']['migrated_entries']} "
        f"featurize_delta={fz_delta} (gate: lost==0)",
        {"lost_requests": float(n_lost), "served": float(served[0]),
         "rebalances": float(s["routing"]["rebalances"]),
         "migrated_entries": float(s["routing"]["migrated_entries"]),
         "featurize_delta": float(fz_delta)}))
    if n_lost or errors:
        common.dump_debug("shard_under_load", s)
        raise AssertionError(
            f"rebalance under load lost {n_lost} requests ({errors})")


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    n_big, cache, batch = 4, 32, 16
    w_set = 80              # cache < 80 <= 4*cache: the capacity regime
                            # (~20 digests/shard — headroom under C=32, so
                            # no shard spills its own LRU)
    tuner = _make_tuner()
    mesh = _mesh_or_none()
    _warm_buckets(tuner, _matrices(batch, seed0=50_000), batch)

    # big steps (2 per pass): the fleet's warm pass is all fixed per-step
    # overhead, the single replica's thrash cost is per-request — request
    # count, not step count, is what the capacity mix scales with
    _bench_capacity(rows, tuner, mesh, n_big=n_big, cache=cache,
                    w_set=w_set, batch=40, segments=3 if quick else 5)
    _bench_shifting(rows, tuner, mesh, n_big=n_big, cache=cache,
                    batch=batch, n_steps=12 if quick else 30)
    _bench_rebalance_sync(rows, tuner, mesh, cache=64, batch=12)
    _bench_rebalance_under_load(rows, tuner, mesh, cache=64, batch=12,
                                settle_s=0.25 if quick else 0.6)
    common.emit(rows)


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("shard")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
