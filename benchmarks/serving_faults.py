"""Degraded-mode serving benchmark (ours): kill one backend mid-stream.

The same repeated traffic — micro-batches of untagged requests, every
request carrying a dense operand so backends really execute — is served
twice through identical stock registries under ``StaticRouter``:

* **baseline** — no faults: every request lands on the default platform
  (``tpu_interpret``) and the health layer must be invisible (zero
  failures, zero failovers, all-``default`` routing decisions).
* **degraded** — ``repro.serving.faults`` hard-fails the default backend's
  executor on calls ``[16, 40)``.  With an 8-request batch that is exactly
  the deterministic script from the faults module docstring: two healthy
  warm-up steps, one hard-down **kill step** (the breaker trips on the
  third consecutive error; all eight requests fail over to ``cpu_ref``
  through the retry lane), two **failed half-open probes** (still served,
  degraded), then a successful probe that closes the breaker — and healthy
  traffic returns to the default platform, undegraded.

Faults are keyed on executor call index, not wall clock (the breaker runs
a zero backoff so every open step probes), so the failure script replays
identically on any machine.  The scenario asserts the ISSUE's degradation
contract in-process: **zero lost requests** (every request gets a
response), every failed-over output **bit-identical** to the ``cpu_ref``
oracle (``spmm_ref``), breaker opens/probes/recovery exactly on schedule,
and ``stats()["health"]`` accounting for every failure, failover, and
probe.  Wall-clock p99 inflation of the degraded stream over the baseline
is *emitted* (``p99_inflation_x``) and gated in ``scripts/smoke.sh``
(``<= 3x``) rather than asserted here, since it is the one
machine-dependent number.

A second mini-scenario drives the opt-in output guard: the default
backend's outputs are NaN-poisoned for one batch (``validate_outputs=True``),
every poisoned request fails over with a finite, reference-exact output,
and the guard counters account for all of it.

``python benchmarks/serving_faults.py [--quick] [--json PATH]`` runs it
standalone; ``python -m benchmarks.run faults`` runs it registered.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_faults.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.data import generate_matrix
from repro.kernels import spmm_ref
from repro.serving import (DEFAULT_PLATFORM, FaultPlan, HealthConfig,
                           HealthRegistry, KernelRequest, SparseKernelEngine,
                           default_registry, inject_faults)

FAMILIES = ("uniform", "banded", "powerlaw", "blockdiag")
BATCH = 8
#: Executor-call fault window (counted from post-warm-up injection): two
#: healthy steps (calls 0..15), one kill step (16..23), two failed
#: half-open probes (24..31, 32..39), then recovery — pure arithmetic on
#: BATCH, independent of machine speed.
KILL_WINDOW = (16, 40)
KILL_STEP, RECOVERY_STEP = 2, 5


def _pool(n=BATCH, seed0=0, n_rows=256, nnz=1200):
    return [generate_matrix(FAMILIES[i % len(FAMILIES)], seed=seed0 + i,
                            n_rows=n_rows, n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _engine(registry):
    # zero backoff: every step an open breaker is due its half-open probe,
    # so breaker transitions are a pure function of executor call indices.
    # warm_lane off: this benchmark asserts the *staged* pipeline's
    # deterministic degradation script (all-`default` routing decisions,
    # scripted call-index fault windows); the warm lane x faults
    # interaction is covered by tests/test_warm_lane.py (differential +
    # threaded stress) and the error-ring scenario in
    # benchmarks/serving_observability.py.
    return SparseKernelEngine(
        backends=registry, warm_lane=False,
        health=HealthRegistry(HealthConfig(consecutive_errors=3,
                                           backoff_s=0.0)))


def _warm(engine, pool, values, rhs):
    """Per-engine warm-up, untimed and pre-fault: one untagged step tunes
    the default platform's caches, one pinned step tunes ``cpu_ref`` — so
    the timed runs (and the retry lane) serve steady-state cache hits and
    the p99 comparison measures serving, not compilation or tuning."""
    engine.step([KernelRequest(m, v, "spmm", rhs)
                 for m, v in zip(pool, values)])
    engine.step([KernelRequest(m, v, "spmm", rhs, platform="cpu_ref")
                 for m, v in zip(pool, values)])
    engine.drain()


def _serve(engine, pool, values, rhs, n_steps):
    per_step, step_s = [], []
    for _ in range(n_steps):
        t0 = time.perf_counter()
        per_step.append(engine.step(
            [KernelRequest(m, v, "spmm", rhs)
             for m, v in zip(pool, values)]))
        step_s.append(time.perf_counter() - t0)
    engine.drain()
    return per_step, step_s


def _p(step_s, q):
    return float(np.percentile(np.asarray(step_s) * 1e3, q))


def _check_degraded_contract(per_step, rhs, engine, fx, n_steps):
    """The deterministic degradation contract, asserted in-process."""
    flat = [r for step in per_step for r in step]
    lost = sum(r.output is None for r in flat)
    assert lost == 0, f"{lost} requests lost a response"
    degraded = [r for r in flat if r.degraded]
    # kill step + two failed probes: every one of those batches failed over
    assert len(degraded) == 3 * BATCH, len(degraded)
    for r in degraded:
        assert r.platform == "cpu_ref" and r.attempts >= 1
        assert r.failed_over_from == DEFAULT_PLATFORM
        np.testing.assert_array_equal(         # bit-identical to the oracle
            np.asarray(r.output), np.asarray(spmm_ref(r.matrix, rhs)))
    for step in (KILL_STEP, KILL_STEP + 1, KILL_STEP + 2):
        assert all(r.degraded for r in per_step[step])
    for step in list(range(KILL_STEP)) + list(range(RECOVERY_STEP, n_steps)):
        assert all(r.platform == DEFAULT_PLATFORM and not r.degraded
                   for r in per_step[step]), f"step {step} not healthy"

    n_faults = (KILL_WINDOW[1] - KILL_WINDOW[0])
    assert fx.calls == n_steps * BATCH          # probes always granted
    assert fx.injected["error"] == n_faults
    h = engine.stats()["health"]
    assert h["execute_failures"] == n_faults    # every failure accounted
    assert h["failovers"] == n_faults           # ...and every failover
    assert h["retry_failures"] == 0
    br = h["breakers"][f"{DEFAULT_PLATFORM}/spmm"]
    assert br["state"] == "closed"              # recovered
    assert br["opens"] == 3                     # trip + two probe reopens
    assert br["probe_failures"] == 2 and br["probe_successes"] == 1
    assert br["failures"] == n_faults
    return len(degraded)


def _bench_kill_one_backend(rows, pool, values, rhs, n_steps):
    base_engine = _engine(default_registry())
    _warm(base_engine, pool, values, rhs)
    base_steps, base_s = _serve(base_engine, pool, values, rhs, n_steps)
    bs = base_engine.stats()
    # the no-fault path must be indistinguishable from a health-less engine
    assert bs["health"]["execute_failures"] == 0
    assert bs["health"]["failovers"] == 0
    assert bs["health"]["circuit_fast_fails"] == 0
    assert bs["routing"]["decisions"] == {"default": (n_steps + 1) * BATCH,
                                          "explicit": BATCH}  # +warm-up
    assert all(not r.degraded and r.attempts == 1
               for step in base_steps for r in step)
    n_req = n_steps * BATCH
    base_p50, base_p99 = _p(base_s, 50), _p(base_s, 99)
    rows.append((
        "faults/baseline/requests_per_s", f"{n_req / sum(base_s):.0f}",
        "", f"p50={base_p50:.2f}ms p99={base_p99:.2f}ms no faults, all "
            f"{DEFAULT_PLATFORM}, health layer silent",
        {"req_per_s": n_req / sum(base_s),
         "p50_ms": base_p50, "p99_ms": base_p99}))

    reg = default_registry()
    engine = _engine(reg)
    _warm(engine, pool, values, rhs)    # fault window starts post-warm-up
    fx = inject_faults(reg, DEFAULT_PLATFORM, "spmm",
                       FaultPlan.fail_calls(*KILL_WINDOW))
    per_step, fault_s = _serve(engine, pool, values, rhs, n_steps)
    n_degraded = _check_degraded_contract(per_step, rhs, engine, fx, n_steps)
    p50, p99 = _p(fault_s, 50), _p(fault_s, 99)
    inflation = p99 / max(base_p99, 1e-9)
    h = engine.stats()["health"]
    rows.append((
        "faults/degraded/requests_per_s", f"{n_req / sum(fault_s):.0f}",
        "", f"p50={p50:.2f}ms p99={p99:.2f}ms "
            f"({inflation:.2f}x baseline) kill={DEFAULT_PLATFORM} "
            f"calls[{KILL_WINDOW[0]},{KILL_WINDOW[1]}) "
            f"degraded={n_degraded}/{n_req} lost=0 "
            f"failovers={h['failovers']} opens=3 probes=2fail+1ok "
            f"-> recovered",
        {"req_per_s": n_req / sum(fault_s),
         "p50_ms": p50, "p99_ms": p99,
         "p99_inflation_x": inflation, "lost_requests": 0.0,
         "degraded_responses": float(n_degraded),
         "failovers": float(h["failovers"]),
         "execute_failures": float(h["execute_failures"]),
         "breaker_opens": 3.0, "probe_failures": 2.0,
         "probe_successes": 1.0, "recovered": 1.0}))
    # evidence for smoke-gate failures: the degraded engine's full stats,
    # its tail-retained error-ring traces, and the structured event log
    common.dump_debug("faults", {
        "degraded_stats": engine.stats(),
        "error_traces": [t.to_dict() for t in engine.traces(errors=True)],
        "events": engine.events.events()})


def _bench_nan_guard(rows, pool, values, rhs):
    reg = default_registry()
    inject_faults(reg, DEFAULT_PLATFORM, "spmm",
                  FaultPlan.nan_calls(0, BATCH))
    engine = SparseKernelEngine(
        backends=reg, validate_outputs=True,
        health=HealthRegistry(HealthConfig(consecutive_errors=3,
                                           backoff_s=0.0)))
    poisoned, = [engine.step([KernelRequest(m, v, "spmm", rhs)
                              for m, v in zip(pool, values)])]
    healthy = engine.step([KernelRequest(m, v, "spmm", rhs)
                           for m, v in zip(pool, values)])
    engine.drain()
    for r in poisoned:                  # every poisoned output was caught
        assert r.degraded and r.platform == "cpu_ref"
        assert np.isfinite(np.asarray(r.output)).all()
        np.testing.assert_array_equal(
            np.asarray(r.output), np.asarray(spmm_ref(r.matrix, rhs)))
    assert all(not r.degraded and r.platform == DEFAULT_PLATFORM
               for r in healthy)        # probe succeeded, breaker closed
    h = engine.stats()["health"]
    assert h["output_guard_failures"] == BATCH
    rows.append((
        "faults/nan_guard/guarded_failovers", f"{h['failovers']}", "",
        f"one NaN-poisoned batch: {h['output_guard_failures']} guard "
        f"failures, all failed over finite + reference-exact, next batch "
        f"healthy on {DEFAULT_PLATFORM}",
        {"output_guard_failures": float(h["output_guard_failures"]),
         "failovers": float(h["failovers"]), "finite_outputs": 1.0}))


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    n_steps = 8 if quick else 12        # >= RECOVERY_STEP + post-recovery
    pool = _pool()
    rng = np.random.default_rng(5)
    values = [rng.normal(size=m.nnz).astype(np.float32) for m in pool]
    rhs = rng.normal(size=(pool[0].n_cols, 64)).astype(np.float32)

    _bench_kill_one_backend(rows, pool, values, rhs, n_steps)
    _bench_nan_guard(rows, pool, values, rhs)
    common.emit(rows)


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("faults")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
