"""Fig. 4: geomean speedups of COGNATE vs all baselines, 2 ops x 2 targets.

Methods: zero-shot, no-transfer, WACO+FA, WACO+FM, COGNATE top-1/top-5,
plus the exhaustive-search optimal — normalized to the platform default
configuration, geomean over the evaluation suite, averaged over SEEDS
training seeds (mean±std reported; the paper reports a single run).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import evaluate

SEEDS = (0, 1, 2)

PAPER = {  # (platform, op, method) -> paper geomean speedup
    ("spade", "spmm", "cognate_top1"): 1.40, ("spade", "spmm", "cognate_top5"): 1.47,
    ("spade", "spmm", "optimal"): 1.55, ("spade", "spmm", "waco_fa"): 1.04,
    ("spade", "spmm", "waco_fm"): 1.09, ("spade", "spmm", "no_transfer"): 1.29,
    ("spade", "spmm", "zero_shot"): 0.71,
    ("spade", "sddmm", "cognate_top1"): 1.27, ("spade", "sddmm", "cognate_top5"): 1.39,
    ("gpu", "spmm", "cognate_top1"): 1.03, ("gpu", "spmm", "cognate_top5"): 1.17,
    ("gpu", "spmm", "optimal"): 1.25,
    ("gpu", "sddmm", "cognate_top1"): 1.07, ("gpu", "sddmm", "cognate_top5"): 1.15,
    ("gpu", "sddmm", "optimal"): 1.22,
}


def _ms(vals):
    vals = np.asarray(vals, np.float64)
    if vals.size == 1:
        return f"{vals[0]:.3f}"
    return f"{vals.mean():.3f}±{vals.std():.3f}"


def run(platforms=("spade", "gpu"), ops=("spmm", "sddmm"), seeds=SEEDS):
    rows = []
    results = {}
    for platform in platforms:
        for op in ops:
            ev = common.eval_dataset(platform, op)
            agg = {}
            for seed in seeds:
                methods = {
                    "zero_shot": common.get_zero_shot(platform, op, seed=seed),
                    "no_transfer": common.get_scratch(platform, op, seed=seed),
                    "waco_fa": common.get_finetuned(platform, op, "waco_fa",
                                                    seed=seed),
                    "waco_fm": common.get_finetuned(platform, op, "waco_fm",
                                                    seed=seed),
                    "cognate": common.get_finetuned(platform, op, "cognate",
                                                    seed=seed),
                }
                for mname, model in methods.items():
                    m = common.cached(
                        f"eval_fig4_{mname}_{platform}_{op}_{seed}",
                        lambda model=model: evaluate(model, ev))
                    results[(platform, op, mname, seed)] = m
                    agg.setdefault((mname, "top1"), []).append(m["top1_geomean"])
                    agg.setdefault((mname, "top5"), []).append(m["top5_geomean"])
                    if mname == "cognate":
                        agg.setdefault(("optimal", ""), []).append(
                            m["optimal_geomean"])
                        agg.setdefault(("cognate", "opa"), []).append(m["opa"])
            for (mname, k), vals in agg.items():
                if mname == "optimal":
                    rows.append((f"fig4/{platform}/{op}/optimal", _ms(vals),
                                 PAPER.get((platform, op, "optimal"), ""),
                                 "exhaustive oracle"))
                elif k == "opa":
                    continue
                elif mname == "cognate":
                    rows.append((f"fig4/{platform}/{op}/cognate_{k}", _ms(vals),
                                 PAPER.get((platform, op, f"cognate_{k}"), ""),
                                 f"opa={_ms(agg[('cognate', 'opa')])}"
                                 if k == "top1" else ""))
                elif k == "top1":
                    rows.append((f"fig4/{platform}/{op}/{mname}_top1", _ms(vals),
                                 PAPER.get((platform, op, mname), ""), ""))
    common.emit(rows)
    return results


if __name__ == "__main__":
    run()
