"""BSR preprocessing benchmark (ours): construction throughput + cache hits.

Times the pattern -> tuned-kernel fast path that serves the deployment loop:

* BSR construction throughput (nnz/s): the seed dense-roundtrip
  implementation (materialize (M, K), Python loop over blocks) vs the
  vectorized O(nnz) path, on the four 4096x4096 / 200k-nnz family matrices.
  Two variants of the new path are timed: ``cold`` = ``bsr_from_coo`` from
  scratch (first sighting of a pattern), ``warm`` = value scatter through a
  cached ``BsrPlan`` (every subsequent request for that pattern — the
  deployment steady state, where the >= 10x acceptance bar applies).
* Autotune latency: first call (featurize + score + plan) vs a repeated
  pattern served from the pattern-keyed LRU cache.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core.autotune import KernelAutotuner
from repro.data import generate_matrix
from repro.kernels.format import (_dense_roundtrip_reference, bsr_from_coo,
                                  plan_from_coo)

FAMILIES = ("banded", "uniform", "powerlaw", "blockdiag")


def _seed_bsr_from_coo(rows, cols, values, shape, block_m=32):
    """The seed path as the baseline under measurement: dense roundtrip +
    per-block Python loop (the shared reference implementation in
    ``repro.kernels.format``) + the device conversion it ended with."""
    m, k = shape
    dense = np.zeros((m, k), np.float32)
    dense[rows, cols] = values
    data, rowids, colids, _, _ = _dense_roundtrip_reference(dense, block_m)
    return (jnp.asarray(data, jnp.float32), jnp.asarray(rowids, jnp.int32),
            jnp.asarray(colids, jnp.int32))


def _best_of(fn, reps=5):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    for fam in FAMILIES:
        mat = generate_matrix(fam, seed=7, n_rows=4096, n_cols=4096,
                              target_nnz=200_000)
        values = np.ones(mat.nnz, np.float32)
        shape = (mat.n_rows, mat.n_cols)

        t_old = _best_of(lambda: _seed_bsr_from_coo(mat.rows, mat.cols,
                                                    values, shape))
        t_cold = _best_of(lambda: bsr_from_coo(mat.rows, mat.cols, values,
                                               shape))
        plan = plan_from_coo(mat.rows, mat.cols, shape, assume_unique=True)
        t_warm = _best_of(lambda: plan.build(values, reuse=True))
        old_d, old_r, old_c = _seed_bsr_from_coo(mat.rows, mat.cols, values,
                                                 shape)
        a = bsr_from_coo(mat.rows, mat.cols, values, shape)
        exact = (np.array_equal(np.asarray(a.data), np.asarray(old_d))
                 and np.array_equal(np.asarray(a.rowids), np.asarray(old_r))
                 and np.array_equal(np.asarray(a.colids), np.asarray(old_c)))
        rows.append((f"bsr_preproc/{fam}/old_nnz_per_s",
                     f"{mat.nnz / t_old:.3e}", "", f"{t_old * 1e3:.1f}ms"))
        rows.append((f"bsr_preproc/{fam}/new_cold_nnz_per_s",
                     f"{mat.nnz / t_cold:.3e}", "",
                     f"{t_cold * 1e3:.1f}ms speedup={t_old / t_cold:.1f}x "
                     f"exact={exact}"))
        rows.append((f"bsr_preproc/{fam}/new_warm_nnz_per_s",
                     f"{mat.nnz / t_warm:.3e}", "",
                     f"{t_warm * 1e3:.2f}ms speedup={t_old / t_warm:.1f}x "
                     "cached-plan scatter"))

    # autotune-cache hit latency on one representative pattern
    mat = generate_matrix("powerlaw", seed=7, n_rows=4096, n_cols=4096,
                          target_nnz=200_000)
    values = np.ones(mat.nnz, np.float32)
    tuner = KernelAutotuner()
    t0 = time.perf_counter()
    entry = tuner.get(mat)
    t_miss = time.perf_counter() - t0
    t_hit = _best_of(lambda: tuner.get(mat))
    featurized_once = tuner.featurize_calls == 1
    t_scatter = _best_of(lambda: entry.build(values, reuse=True))
    rows.append(("bsr_preproc/autotune/miss_ms", f"{t_miss * 1e3:.2f}", "",
                 "featurize + score + plan"))
    rows.append(("bsr_preproc/autotune/hit_ms", f"{t_hit * 1e3:.3f}", "",
                 f"speedup={t_miss / max(t_hit, 1e-9):.0f}x "
                 f"no_refeaturize={featurized_once}"))
    rows.append(("bsr_preproc/autotune/value_scatter_ms",
                 f"{t_scatter * 1e3:.2f}", "",
                 "per-request cost for a cached pattern"))
    common.emit(rows)


if __name__ == "__main__":
    run()
