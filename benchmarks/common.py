"""Shared benchmark infrastructure: scales, datasets, model artifact cache.

The paper's campaign is 1,500 matrices x 100 configs x 3 platforms (~4M CPU
hours of simulator time). This container has one CPU core, so benchmarks run
at a disclosed reduced scale by default (REPRO_BENCH_SCALE=default); every
figure prints the scale next to the paper's number. REPRO_BENCH_SCALE=paper
selects the full protocol (100 source matrices @128px, 100 epochs).

Expensive artifacts (datasets, pretrained/fine-tuned models) are cached under
benchmarks/artifacts/ keyed by (scale, recipe) so the figure scripts compose
without retraining.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from pathlib import Path

import numpy as np

from repro.core import (CostModelConfig, TransferResult, finetune_target,
                        make_codec, pretrain_source, train_scratch, zero_shot)
from repro.data import CostMeter, collect_dataset, split_suite
from repro.hw import get_platform

ARTIFACT_DIR = Path(__file__).parent / "artifacts"
ARTIFACT_DIR.mkdir(exist_ok=True)


@dataclasses.dataclass(frozen=True)
class Scale:
    name: str
    n_source: int           # matrices for source pre-training (paper: 100)
    n_finetune: int         # few-shot matrices (paper: 5)
    n_eval: int             # evaluation matrices (paper: 715)
    n_cfg_samples: int      # sampled configs per matrix (paper: 100)
    resolution: int         # density pyramid resolution (paper analogue: 256)
    ch_scale: float         # featurizer channel multiplier (paper: 1.0)
    pre_epochs: int         # paper: 100
    ft_epochs: int          # paper: 100
    ae_epochs: int          # paper: 1000
    max_suite: int          # largest source suite for the sweeps


SCALES = {
    "tiny": Scale("tiny", 10, 3, 8, 24, 32, 0.25, 3, 4, 30, 16),
    "default": Scale("default", 60, 5, 100, 60, 32, 0.5, 30, 100, 200, 100),
    "paper": Scale("paper", 100, 5, 715, 100, 128, 1.0, 100, 100, 1000, 1000),
}


def scale() -> Scale:
    return SCALES[os.environ.get("REPRO_BENCH_SCALE", "default")]


def _key(name: str) -> Path:
    return ARTIFACT_DIR / f"{scale().name}_{name}.pkl"


def cached(name: str, builder, force: bool = False):
    path = _key(name)
    if path.exists() and not force:
        with open(path, "rb") as f:
            return pickle.load(f)
    t0 = time.time()
    obj = builder()
    tmp = path.with_suffix(".tmp")
    with open(tmp, "wb") as f:
        pickle.dump(obj, f)
    os.replace(tmp, path)  # atomic: a crash never leaves a torn artifact
    print(f"# built artifact {path.name} in {time.time() - t0:.1f}s", flush=True)
    return obj


# ------------------------------------------------------------------ suites

def suites():
    s = scale()
    def build():
        train, evl = split_suite(s.max_suite + s.n_finetune + 20, s.n_eval, seed=0)
        return train, evl
    return cached("suites", build)


def source_dataset(op: str, n_mat: int | None = None):
    s = scale()
    n = n_mat or s.n_source
    def build():
        train, _ = suites()
        meter = CostMeter()
        ds = collect_dataset(get_platform("cpu"), train[:n], op,
                             s.n_cfg_samples, seed=11, resolution=s.resolution,
                             meter=meter)
        return ds, meter.units
    return cached(f"src_ds_{op}_{n}", build)


def finetune_dataset(platform: str, op: str, n_mat: int | None = None):
    s = scale()
    n = n_mat or s.n_finetune
    def build():
        train, _ = suites()
        meter = CostMeter()
        base = s.max_suite  # finetune matrices disjoint from any source set
        ds = collect_dataset(get_platform(platform), train[base:base + n], op,
                             s.n_cfg_samples, seed=13, resolution=s.resolution,
                             meter=meter)
        return ds, meter.units
    return cached(f"ft_ds_{platform}_{op}_{n}", build)


def eval_dataset(platform: str, op: str):
    s = scale()
    def build():
        _, evl = suites()
        return collect_dataset(get_platform(platform), evl, op, 0, seed=17,
                               resolution=s.resolution)
    return cached(f"eval_ds_{platform}_{op}", build)


# ------------------------------------------------------------------ models

def model_config(kind: str, predictor: str = "mlp") -> CostModelConfig:
    s = scale()
    common = dict(ch_scale=s.ch_scale, predictor=predictor)
    if kind == "cognate":
        return CostModelConfig(featurizer="cognate", **common)
    if kind == "waco_fa":
        return CostModelConfig(featurizer="waco", use_mapper=False, **common)
    if kind == "waco_fm":
        return CostModelConfig(featurizer="waco", use_latent=False, **common)
    raise ValueError(kind)


_LATENT_FOR = {"cognate": "ae", "waco_fa": "fa", "waco_fm": "none"}


def get_source_model(op: str, kind: str = "cognate", n_mat: int | None = None,
                     predictor: str = "mlp", seed: int = 0) -> TransferResult:
    s = scale()
    n = n_mat or s.n_source
    name = f"src_model_{kind}_{op}_{n}_{predictor}_{seed}"
    def build():
        ds, _ = source_dataset(op, n)
        return pretrain_source(model_config(kind, predictor), ds,
                               epochs=s.pre_epochs, seed=seed,
                               latent_kind=_LATENT_FOR[kind],
                               ae_epochs=s.ae_epochs)
    return cached(name, build)


def get_finetuned(platform: str, op: str, kind: str = "cognate",
                  n_ft: int | None = None, n_src: int | None = None,
                  latent_kind: str | None = None, predictor: str = "mlp",
                  seed: int = 0) -> TransferResult:
    s = scale()
    n_ft = n_ft or s.n_finetune
    latent = latent_kind or _LATENT_FOR[kind]
    name = f"ft_{kind}_{platform}_{op}_{n_ft}_{n_src or s.n_source}_{latent}_{predictor}_{seed}"
    def build():
        pre = get_source_model(op, kind, n_mat=n_src, predictor=predictor,
                               seed=seed)
        ft_ds, _ = finetune_dataset(platform, op, n_ft)
        return finetune_target(pre, ft_ds, epochs=s.ft_epochs, seed=seed,
                               latent_kind=latent, ae_epochs=s.ae_epochs)
    return cached(name, build)


def get_scratch(platform: str, op: str, n_mat: int | None = None,
                seed: int = 0) -> TransferResult:
    s = scale()
    n = n_mat or s.n_finetune
    name = f"scratch_{platform}_{op}_{n}_{seed}"
    def build():
        ft_ds, _ = finetune_dataset(platform, op, n)
        return train_scratch(model_config("cognate"), ft_ds,
                             epochs=s.ft_epochs, seed=seed,
                             ae_epochs=s.ae_epochs)
    return cached(name, build)


def get_zero_shot(platform: str, op: str, seed: int = 0) -> TransferResult:
    name = f"zeroshot_{platform}_{op}_{seed}"
    def build():
        pre = get_source_model(op, "cognate", seed=seed)
        ft_ds, _ = finetune_dataset(platform, op)
        return zero_shot(pre, ft_ds, ae_epochs=scale().ae_epochs, seed=seed)
    return cached(name, build)


# ------------------------------------------------------------------ output

#: rows collected by every ``emit`` call this process, for ``--json``
#: output: dicts of {section, name, value, value_num, paper, notes, metrics}
_COLLECTED: list[dict] = []
_SECTION = ""


def begin_section(name: str) -> None:
    """Tag subsequent ``emit`` rows with the benchmark section (figure)
    name — ``benchmarks.run`` calls this before each figure module."""
    global _SECTION
    _SECTION = name


def _as_float(value) -> float | None:
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


def emit(rows, header=("name", "value", "paper", "notes")):
    """Print ``name,value,paper,notes`` CSV and collect the rows for
    machine-readable output.  A row may carry a 5th element — a dict of
    named numeric metrics (e.g. ``{"req_per_s": ..., "p50_ms": ...,
    "p99_ms": ...}``) — which is NOT printed but lands in the JSON payload,
    so quantities that the CSV only renders inside the notes string stay
    parseable."""
    print(",".join(header))
    for r in rows:
        metrics = r[4] if len(r) > 4 and isinstance(r[4], dict) else None
        cells = list(r[:4]) + [""] * (4 - min(len(r), 4))
        print(",".join(str(x) for x in cells))
        _COLLECTED.append({
            "section": _SECTION, "name": str(cells[0]),
            "value": str(cells[1]), "value_num": _as_float(cells[1]),
            "paper": str(cells[2]), "notes": str(cells[3]),
            "metrics": metrics or {}})
    print()


def provenance() -> dict:
    """Where/when/what produced this run: ISO UTC timestamp, git commit,
    jax version, platform, python — embedded in every ``BENCH_*.json`` so
    a number in the perf trajectory is always traceable to the tree and
    toolchain that produced it.  Every field degrades to ``"unknown"``
    rather than failing (benchmarks may run from a tarball without git)."""
    import datetime
    import platform as _platform
    import subprocess
    import sys
    prov = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_commit": "unknown",
        "jax_version": "unknown",
        "platform": _platform.platform(),
        "python": sys.version.split()[0],
    }
    try:
        prov["git_commit"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=Path(__file__).parent,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except Exception:
        pass
    try:
        import jax
        prov["jax_version"] = jax.__version__
    except Exception:
        pass
    return prov


def write_json(path, extra: dict | None = None) -> None:
    """Write every collected row (plus run metadata and ``provenance()``)
    as one JSON document — the ``BENCH_*.json`` artifact the perf
    trajectory is tracked with."""
    import json
    s = scale()
    doc = {
        "schema": 1,
        "scale": s.name,
        "provenance": provenance(),
        "rows": _COLLECTED,
    }
    doc.update(extra or {})
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    print(f"# wrote {len(_COLLECTED)} rows to {path}")


def dump_debug(name: str, payload) -> Path:
    """Drop a JSON debug artifact under ``benchmarks/artifacts/`` —
    engine stats snapshots, error-ring traces, anything a failed smoke
    gate should surface.  ``scripts/smoke.sh`` prints these on gate
    failure so CI logs carry the evidence, not just the assertion."""
    import json
    path = ARTIFACT_DIR / f"{name}_debug.json"
    tmp = path.with_suffix(".tmp")
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    os.replace(tmp, path)
    print(f"# wrote debug artifact {path}")
    return path
