"""Routing-policy benchmark (ours): mixed untagged traffic through the three
shipped routers.

The same traffic — micro-batches of *untagged* requests over a sliding
working set, every request carrying a dense operand so backends really
execute — is served three times through identical stock registries
(``tpu_interpret`` / ``tpu_pallas`` / ``cpu_ref``), differing only in the
engine's routing policy:

* ``static`` — the default ``StaticRouter``: every untagged request lands on
  the default platform (the pre-router engine's behavior; the baseline).
* ``cost_model`` — ``CostModelRouter`` with periodic exploration: untagged
  misses are scored against every candidate backend's config space in one
  batched dispatch per step, and placement follows the argmin effective
  cost as per-platform calibration offsets converge on observed latency.
* ``load_aware`` — ``LoadAwareRouter`` wrapping the static policy with a
  per-backend in-flight cap sized well below the batch, so the default
  backend saturates every step and the overflow demonstrably spills to
  ``cpu_ref`` (asserted — this scenario is the synthetic-saturation proof
  next to the unit test).

Reported per policy: end-to-end requests/sec and step p50/p99, the
per-backend request share, spill count, and the routing-dispatch count
(cost_model must stay at one multi-space dispatch per step with misses).

``python benchmarks/serving_routing.py [--quick] [--json PATH]`` runs it
standalone; ``python -m benchmarks.run routing`` runs it registered.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_routing.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from benchmarks.serving_engine import _make_tuner, _matrices, _values_for
from repro.core.autotune import KernelAutotuner
from repro.serving import (DEFAULT_PLATFORM, CostModelRouter, KernelRequest,
                           LoadAwareRouter, SparseKernelEngine, StaticRouter)


def _router_for(policy: str, batch: int):
    if policy == "static":
        return StaticRouter()
    if policy == "cost_model":
        # explore keeps calibration fresh for the knob-free cpu_ref backend
        # the argmin would otherwise never measure
        return CostModelRouter(explore_every=16)
    if policy == "load_aware":
        # cap far below the batch: with leases outstanding across steps the
        # default backend saturates immediately and overflow sheds to cpu_ref
        return LoadAwareRouter(StaticRouter(), max_inflight=batch // 3)
    raise ValueError(policy)


def _bench_policy(rows, policy: str, tuner, n_steps: int, batch: int, pool,
                  rhs):
    router = _router_for(policy, batch)
    engine = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256),
                                router=router)
    values = _values_for(pool)
    t0 = time.perf_counter()
    for step in range(n_steps):
        base = (step * 4) % (len(pool) - batch)     # sliding working set
        engine.step([KernelRequest(pool[base + j], values[base + j],
                                   "spmm", rhs) for j in range(batch)])
    elapsed = time.perf_counter() - t0
    engine.release_stream()
    s = engine.stats()

    routing = s["routing"]
    total = max(s["requests"], 1)
    share = {plat: n / total
             for plat, n in sorted(routing["by_platform"].items())}
    step_h = s["stages"]["step"]
    dispatches = getattr(router, "dispatches", None)
    if dispatches is None and hasattr(router, "inner"):
        dispatches = getattr(router.inner, "dispatches", None)
    share_txt = " ".join(f"{p}={f:.2f}" for p, f in share.items())
    rows.append((
        f"routing/{policy}/requests_per_s", f"{s['requests'] / elapsed:.0f}",
        "",
        f"p50={step_h['p50_ms']:.2f}ms p99={step_h['p99_ms']:.2f}ms "
        f"share[{share_txt}] spills={routing['spills']} "
        f"decisions={routing['decisions']}"
        + (f" route_dispatches={dispatches}" if dispatches is not None
           else ""),
        {"req_per_s": s["requests"] / elapsed,
         "p50_ms": step_h["p50_ms"], "p99_ms": step_h["p99_ms"],
         "spills": routing["spills"],
         **{f"share_{p}": f for p, f in share.items()}}))
    return s, router


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    batch = 18
    n_steps = 6 if quick else 24
    tuner = _make_tuner()
    pool = _matrices(n_steps * 4 + batch, seed0=0)
    rhs = np.random.default_rng(3).normal(size=(pool[0].n_cols, 64)) \
        .astype(np.float32)
    # warm process-global jit caches so the timed loops compare policies,
    # not first-call compilation
    warm = SparseKernelEngine(KernelAutotuner(tuner, cache_size=256),
                              router=CostModelRouter(explore_every=4))
    for step in range(2):
        warm.step([KernelRequest(pool[j], None, "spmm", rhs)
                   for j in range(batch)])
    warm.release_stream()

    stats = {}
    for policy in ("static", "cost_model", "load_aware"):
        stats[policy], router = _bench_policy(rows, policy, tuner, n_steps,
                                              batch, pool, rhs)
        if policy == "cost_model":
            cal = stats[policy]["routing"]["calibration"]
            cal_txt = " ".join(
                f"{p}:{c['observed_ms']:.2f}ms" for p, c in sorted(cal.items()))
            rows.append((
                "routing/cost_model/route_dispatches",
                f"{router.dispatches}", "",
                f"one multi-space dispatch per step with unseen patterns; "
                f"scored_patterns={router.scored_patterns} "
                f"calibrated[{cal_txt}]",
                {"dispatches": float(router.dispatches),
                 "scored_patterns": float(router.scored_patterns)}))

    # acceptance: the saturated default backend demonstrably spilled
    spills = stats["load_aware"]["routing"]["spills"]
    assert spills > 0, "load_aware scenario produced no spills"
    assert stats["load_aware"]["routing"]["by_platform"].get("cpu_ref", 0) \
        > 0, "spilled traffic never reached cpu_ref"
    # static baseline keeps everything on the default platform
    assert set(stats["static"]["routing"]["by_platform"]) \
        == {DEFAULT_PLATFORM}
    common.emit(rows)


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("routing")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
