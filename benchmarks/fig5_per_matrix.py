"""Fig. 5/13/14/15: per-matrix speedup distributions of COGNATE on SPADE.

Reuses Fig. 4 artifacts; prints distribution summaries (the paper's scatter
plots) for SpMM/SDDMM x top-1/top-5.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import evaluate


def run():
    rows = []
    for op, fig in (("spmm", "fig5"), ("sddmm", "fig14")):
        model = common.get_finetuned("spade", op, "cognate")
        ev = common.eval_dataset("spade", op)
        m = common.cached(f"eval_fig4_cognate_spade_{op}",
                          lambda: evaluate(model, ev))
        for k in (1, 5):
            sp = m[f"top{k}_speedup"]
            rows.append((f"{fig}/{op}/top{k}/geomean", f"{np.exp(np.log(sp).mean()):.3f}",
                         {("spmm", 1): 1.40}.get((op, k), ""), ""))
            rows.append((f"{fig}/{op}/top{k}/max", f"{sp.max():.2f}",
                         {("spmm", 1): 5.46}.get((op, k), ""), "paper max 5.46 (spmm)"))
            rows.append((f"{fig}/{op}/top{k}/frac_below_1",
                         f"{(sp < 1.0).mean():.3f}", "",
                         "matrices where baseline wins"))
            qs = np.percentile(sp, [10, 50, 90])
            rows.append((f"{fig}/{op}/top{k}/p10_p50_p90",
                         f"{qs[0]:.2f}/{qs[1]:.2f}/{qs[2]:.2f}", "", ""))
    common.emit(rows)


if __name__ == "__main__":
    run()
