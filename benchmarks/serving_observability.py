"""Serving observability benchmark (ours): tracing overhead + exporters.

Three scenarios over stock registries:

* **overhead** — identical steady-state cache-hit traffic served through
  two engines differing ONLY in ``trace_sample_rate`` (0.0 vs 0.1), timed
  in short interleaved alternating segments with each mode's best kept
  (the same machine-load-drift-robust protocol as the device-build
  scenario in ``serving_engine.py``).  Emits ``overhead_pct`` — the req/s
  cost of sampled tracing — which ``scripts/smoke.sh`` gates at <= 5%:
  the hot path collects six (name, t0, dur) tuples per step and defers
  all Span/Trace materialization to sampled or degraded steps, so the
  regression should be near the noise floor.

* **error ring** — a hard-failing default backend (deterministic
  ``FaultPlan``, breaker trips, requests fail over) served at
  ``trace_sample_rate=0.0``.  Head sampling is OFF and the failure
  strikes *mid-warm-lane* (steady-state repeat traffic rides the fused
  fast path), yet tail retention must still capture every incident:
  asserts in-process that every degraded response's ``trace_id`` is
  present in ``engine.traces(errors=True)`` with the complete span tree
  (the fused ``warm`` stage — or route -> partition -> score -> build
  on the staged path — then execute -> retry with the retry
  sub-stages), and emits ``error_ring_complete`` for the smoke gate.

* **exports** — renders the sampled engine's state through every
  exporter and validates in-process: ``prometheus_text`` round-trips
  ``parse_prometheus_text`` with histogram bucket counts matching
  ``LatencyHistogram.buckets()``; ``chrome_trace`` (spans + generation
  windows) JSON-serializes with the documented event schema;
  ``engine.stats_delta()`` reports a positive windowed req/s.  The
  rendered artifacts land in ``benchmarks/artifacts/obs_prometheus.txt``
  and ``obs_chrome_trace.json`` — uploaded by CI next to the
  ``BENCH_*.json`` so every run leaves an inspectable scrape + timeline.

``python benchmarks/serving_observability.py [--quick] [--json PATH]``
runs it standalone; ``python -m benchmarks.run observability`` registered.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_observability.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.data import generate_matrix
from repro.serving import (DEFAULT_PLATFORM, FaultPlan, HealthConfig,
                           HealthRegistry, KernelRequest, SparseKernelEngine,
                           chrome_trace, default_registry, inject_faults,
                           parse_prometheus_text, prom_get, prometheus_text)

FAMILIES = ("uniform", "banded", "powerlaw", "blockdiag")
BATCH = 8
SAMPLE_RATE = 0.1


def _pool(n=BATCH, seed0=70_000, n_rows=256, nnz=1200):
    return [generate_matrix(FAMILIES[i % len(FAMILIES)], seed=seed0 + i,
                            n_rows=n_rows, n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _reqs(pool, values, rhs):
    return [KernelRequest(m, v, "spmm", rhs) for m, v in zip(pool, values)]


def _warm(engine, pool, values, rhs):
    engine.step(_reqs(pool, values, rhs))   # untimed: tune + compile
    engine.drain()


def _bench_overhead(rows, pool, values, rhs, n_segments, seg_steps):
    engines = {
        0.0: SparseKernelEngine(backends=default_registry()),
        SAMPLE_RATE: SparseKernelEngine(backends=default_registry(),
                                        trace_sample_rate=SAMPLE_RATE),
    }
    for e in engines.values():
        _warm(e, pool, values, rhs)
    best = {rate: 0.0 for rate in engines}
    reqs_per_seg = seg_steps * BATCH
    for seg in range(n_segments):
        # alternate modes so machine-load drift hits both equally
        rate = 0.0 if seg % 2 == 0 else SAMPLE_RATE
        engine = engines[rate]
        t0 = time.perf_counter()
        for _ in range(seg_steps):
            engine.step(_reqs(pool, values, rhs))
        engine.drain()
        best[rate] = max(best[rate],
                         reqs_per_seg / (time.perf_counter() - t0))
    off, on = best[0.0], best[SAMPLE_RATE]
    overhead_pct = max(0.0, (off - on) / off * 100.0)

    tr = engines[SAMPLE_RATE].stats()["tracing"]
    # the deterministic counter sampler kept exactly floor(steps * rate)
    assert tr["sampled_steps"] == int(tr["steps"] * SAMPLE_RATE), tr
    assert engines[SAMPLE_RATE].traces(), "sampled ring is empty"
    assert not engines[0.0].traces(), "rate-0 engine recorded traces"

    rows.append((
        "observability/tracing_off/requests_per_s", f"{off:.0f}", "",
        f"trace_sample_rate=0.0, steady-state cache hits, "
        f"best of {n_segments // 2} interleaved segments",
        {"req_per_s": off}))
    rows.append((
        "observability/tracing_sampled/requests_per_s", f"{on:.0f}", "",
        f"trace_sample_rate={SAMPLE_RATE}: overhead={overhead_pct:.2f}% "
        f"vs tracing-off (smoke gates <=5%); "
        f"{tr['sampled_steps']}/{tr['steps']} steps materialized",
        {"req_per_s": on, "sample_rate": SAMPLE_RATE,
         "overhead_pct": overhead_pct,
         "sampled_steps": float(tr["sampled_steps"]),
         "steps": float(tr["steps"])}))
    return engines[SAMPLE_RATE]


def _bench_error_ring(rows, pool, values, rhs):
    reg = default_registry()
    engine = SparseKernelEngine(
        backends=reg, trace_sample_rate=0.0,   # head sampling OFF
        health=HealthRegistry(HealthConfig(consecutive_errors=3,
                                           backoff_s=60.0)))
    _warm(engine, pool, values, rhs)
    inject_faults(reg, DEFAULT_PLATFORM, "spmm", FaultPlan.fail_calls(0))
    resps = engine.step(_reqs(pool, values, rhs))
    engine.drain()

    degraded = [r for r in resps if r.degraded]
    assert len(degraded) == BATCH, len(degraded)
    # the failing step is steady-state repeat traffic, so with the default
    # warm_lane=True it strikes *mid-warm-lane*: the probe ran against a
    # still-closed breaker, the fused lane dispatched, and the failure
    # degrades through the shared retry lane — the exact scenario where
    # tail retention must not be sampled away.  Assert the lane really
    # was taken, then accept either span shape per trace (fused
    # warm->execute->retry, or the staged route->...->retry).
    assert engine.stats()["warm_lane"]["steps"] >= 1, "failing step cold"
    ring = {t.trace_id: t for t in engine.traces(errors=True)}
    staged = ["route", "partition", "score", "build", "execute", "retry"]
    fused = ["warm", "execute", "retry"]
    complete = True
    for r in degraded:
        t = ring.get(r.trace_id)
        if t is None:
            complete = False
            break
        names = t.span_names()
        if names[:6] != staged and names[:3] != fused:
            complete = False
            break
        retry = t.root.find("retry")
        sub = [c.name for c in retry.children]
        if sub != ["retry.partition", "retry.score", "retry.build",
                   "retry.execute"]:
            complete = False
            break
        if retry.attrs.get("failed_over_from") != DEFAULT_PLATFORM:
            complete = False
            break
    assert complete, "error ring missing a degraded trace or span"
    assert not engine.traces(), "rate-0 engine head-sampled a trace"
    kinds = engine.events.snapshot()["by_kind"]
    assert kinds.get("breaker_transition", 0) >= 1, kinds
    assert kinds.get("failover", 0) >= 1, kinds

    rows.append((
        "observability/error_ring/complete", "1", "",
        f"sample_rate=0.0 + hard-failing {DEFAULT_PLATFORM} striking "
        f"mid-warm-lane: all {len(degraded)} degraded requests "
        f"tail-retained with full (warm|route->...)->execute->retry span "
        f"trees; events: {dict(sorted(kinds.items()))}",
        {"error_ring_complete": 1.0, "error_traces": float(len(ring)),
         "degraded_responses": float(len(degraded))}))
    return engine


def _bench_exports(rows, engine, err_engine):
    txt = prometheus_text(engine)
    samples = parse_prometheus_text(txt)
    s = engine.stats()
    assert prom_get(samples, "repro_serving_requests_total") == s["requests"]
    # histogram buckets in the exposition == LatencyHistogram.buckets()
    hist = engine.telemetry.stage_histograms()["step"]
    for edge, cum in hist.buckets()[-4:]:
        le = "+Inf" if edge == float("inf") else format(edge, ".10g")
        got = prom_get(samples, "repro_serving_stage_duration_seconds_bucket",
                       stage="step", le=le)
        assert got == cum, (le, got, cum)
    drift = [x for x in samples if x[0] == "repro_serving_calibration_drift_ms"]
    assert drift, "calibration drift gauge missing from exposition"

    traces = engine.traces()
    ct = chrome_trace(traces, engine.generation_log())
    blob = json.dumps(ct)
    loaded = json.loads(blob)
    assert loaded["traceEvents"], "empty chrome trace"
    complete = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    assert complete and all(
        k in e for e in complete for k in ("ts", "dur", "pid", "tid"))
    gen_rows = {e["tid"] for e in complete if "in-flight" in e["name"]}
    assert len(gen_rows) >= 2, "generation windows missing from timeline"

    prom_path = common.ARTIFACT_DIR / "obs_prometheus.txt"
    prom_path.write_text(txt)
    trace_path = common.ARTIFACT_DIR / "obs_chrome_trace.json"
    trace_path.write_text(blob)

    d = engine.stats_delta()    # window: construction -> now
    assert d["requests_per_s"] > 0 and d["requests"] == s["requests"]

    rows.append((
        "observability/export/prometheus_samples", f"{len(samples)}", "",
        f"full exposition parses; {len(complete)} chrome-trace events over "
        f"{len(gen_rows)} generation rows; artifacts: {prom_path.name}, "
        f"{trace_path.name}",
        {"prom_samples": float(len(samples)),
         "chrome_events": float(len(complete)),
         "generation_rows": float(len(gen_rows))}))
    rows.append((
        "observability/stats_delta/requests_per_s",
        f"{d['requests_per_s']:.0f}", "",
        f"windowed view over {d['interval_s']:.2f}s: "
        f"hit_rate={d['hit_rate']:.2f} batches/s={d['batches_per_s']:.1f}",
        {"req_per_s_window": d["requests_per_s"],
         "hit_rate_window": d["hit_rate"]}))

    common.dump_debug("observability", {
        "sampled_stats": s,
        "sampled_delta": d,
        "error_stats": err_engine.stats(),
        "error_traces": [t.to_dict()
                         for t in err_engine.traces(errors=True)],
        "error_events": err_engine.events.events(),
    })


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    n_segments = 8 if quick else 12
    seg_steps = 6 if quick else 10
    pool = _pool()
    rng = np.random.default_rng(7)
    values = [rng.normal(size=m.nnz).astype(np.float32) for m in pool]
    rhs = rng.normal(size=(pool[0].n_cols, 64)).astype(np.float32)

    sampled = _bench_overhead(rows, pool, values, rhs, n_segments, seg_steps)
    err_engine = _bench_error_ring(rows, pool, values, rhs)
    _bench_exports(rows, sampled, err_engine)
    common.emit(rows)


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("observability")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
