"""Open-loop admission benchmark: sustained overload and replica failure
through the bounded queue — the scenario every other serving benchmark
avoids by being closed-loop.

* **Overload, 2x sustained** — service capacity ``mu`` is measured
  closed-loop first (with a fixed injected per-call executor latency, so
  the service rate is stable across machines), then a Poisson arrival
  process at ``2*mu`` submits open-loop through an ``AdmissionQueue``
  with per-request deadlines and mixed priorities.  The point under
  test: **bounded, observable degradation** — every submit resolves
  (``lost == 0``), overload shows up as counted ``shed`` +
  ``deadline_exceeded`` outcomes instead of unbounded queueing, and the
  *served*-request p99 stays near the deadline budget.  Gates in
  ``scripts/smoke.sh``.
* **Overload, unbounded baseline** — the identical arrival schedule into
  an effectively unbounded queue with no deadlines: nothing sheds, so
  every request is eventually served and the tail latency diverges with
  the backlog.  The bounded/baseline p99 ratio is the emitted evidence
  that admission control, not luck, bounds the tail.
* **Replica failure under admission** — a 2-replica ``ShardedEngine``
  behind the queue; mid-run one replica's executor hangs (deterministic
  ``FaultPlan.hang_calls`` window).  The dispatch timeout quarantines
  it, its sub-batch re-dispatches to the survivor, open-loop traffic
  keeps resolving (``lost == 0``), and after the hang releases a
  probation probe re-admits the replica.  Gate: zero lost, exactly one
  quarantine, exactly one re-admission.

``python benchmarks/serving_admission.py --quick`` runs the reduced
protocol (``REPRO_BENCH_QUICK=1`` selects it through ``benchmarks.run``);
``--json PATH`` (standalone) writes the rows machine-readably.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_admission.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.data import generate_matrix
from repro.serving import (AdmissionQueue, FaultPlan, KernelRequest,
                           ShardedEngine, SparseKernelEngine, inject_faults)

FAMILIES = ("uniform", "banded", "powerlaw", "blockdiag")
SERVICE_LATENCY_S = 0.005       # injected per-call cost: stabilizes mu
DEADLINE_MS = 150.0


def _matrices(n, seed0=0, n_rows=128, nnz=600):
    return [generate_matrix(FAMILIES[i % len(FAMILIES)], seed=seed0 + i,
                            n_rows=n_rows, n_cols=n_rows, target_nnz=nnz)
            for i in range(n)]


def _reqs(pool, values, rhs, idxs):
    return [KernelRequest(pool[i % len(pool)], values[i % len(pool)],
                          "spmm", rhs) for i in idxs]


def _pool(n=12, seed0=10_000):
    pool = _matrices(n, seed0=seed0)
    rng = np.random.default_rng(3)
    values = [rng.normal(size=m.nnz).astype(np.float32) for m in pool]
    rhs = rng.normal(size=(pool[0].n_cols, 16)).astype(np.float32)
    return pool, values, rhs


def _measure_mu(engine, pool, values, rhs, *, batch=8, seconds=0.5):
    """Closed-loop warm service rate (requests/sec) — the denominator the
    overload factor is defined against."""
    n = served = 0
    for warm in range(3):                       # warm caches + warm lane
        engine.step(_reqs(pool, values, rhs, range(batch)))
    engine.drain()
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        engine.step(_reqs(pool, values, rhs,
                          range(n * batch, n * batch + batch)))
        n += 1
        served += batch
    engine.drain()
    return served / (time.perf_counter() - t0)


def _open_loop(queue, pool, values, rhs, *, n_requests, rate, seed,
               deadline_ms):
    """Submit ``n_requests`` with exponential inter-arrivals at ``rate``
    req/s; returns the resolved tickets (queue closed = all resolved)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    tickets = []
    for i in range(n_requests):
        r = _reqs(pool, values, rhs, [i])[0]
        tickets.append(queue.submit(r, deadline_ms=deadline_ms,
                                    priority=i % 3))
        time.sleep(gaps[i])
    queue.close()                                # drains; every ticket ends
    return tickets


def _latencies_ms(tickets, outcome="served"):
    return np.array([(t.resolved_ts - t.submitted_ts) * 1e3
                     for t in tickets if t.outcome == outcome]) \
        if any(t.outcome == outcome for t in tickets) else np.array([0.0])


def _bench_overload(rows, quick):
    n_requests = 160 if quick else 400
    pool, values, rhs = _pool()
    engine = SparseKernelEngine()
    fx = inject_faults(engine.backends, engine.default_platform, "spmm",
                       FaultPlan.latency_calls(0, None, SERVICE_LATENCY_S))
    try:
        mu = _measure_mu(engine, pool, values, rhs,
                         seconds=0.25 if quick else 0.5)
        rate = 2.0 * mu

        # high watermark sits below the depth the deadline alone would
        # allow (deadline_ms * mu), so sustained overload exercises both
        # shedding and deadline expiry rather than one masking the other
        q = AdmissionQueue(engine, capacity=48, high_watermark=24,
                           max_batch=8)
        tickets = _open_loop(q, pool, values, rhs, n_requests=n_requests,
                             rate=rate, seed=7, deadline_ms=DEADLINE_MS)
        s = q.snapshot()
        lost = sum(t.outcome is None for t in tickets)
        unaccounted = s["submitted"] - (s["served"] + s["shed"]
                                        + s["deadline_exceeded"]
                                        + s["failed"])
        p99 = float(np.percentile(_latencies_ms(tickets), 99))

        base = AdmissionQueue(engine, capacity=10**6,
                              high_watermark=10**6, max_batch=8)
        base_tickets = _open_loop(base, pool, values, rhs,
                                  n_requests=n_requests, rate=rate,
                                  seed=7, deadline_ms=None)
        base_p99 = float(np.percentile(_latencies_ms(base_tickets), 99))
    finally:
        fx.restore()

    ratio = base_p99 / max(p99, 1e-9)
    rows.append((
        "admission/overload/bounded_p99_ms", f"{p99:.1f}", "",
        f"2x overload ({rate:.0f} req/s vs mu={mu:.0f}): "
        f"served={s['served']} shed={s['shed']} "
        f"deadline_exceeded={s['deadline_exceeded']} failed={s['failed']} "
        f"lost={lost} unaccounted={unaccounted} peak_depth={s['peak_depth']} "
        f"(gates: lost==0, shed>0, p99 bounded)",
        {"p99_ms": p99, "lost": float(lost),
         "unaccounted": float(unaccounted),
         "served": float(s["served"]), "shed": float(s["shed"]),
         "deadline_exceeded": float(s["deadline_exceeded"]),
         "failed": float(s["failed"]),
         "peak_depth": float(s["peak_depth"]),
         "deadline_ms": DEADLINE_MS, "mu_req_per_s": mu}))
    rows.append((
        "admission/overload/unbounded_baseline_p99_ms", f"{base_p99:.1f}",
        "", f"same arrivals, no bound, no deadlines: every request "
        f"eventually served, tail diverges with the backlog — "
        f"{ratio:.1f}x the bounded p99",
        {"p99_ms": base_p99, "p99_ratio": ratio,
         "served": float(sum(t.outcome == 'served'
                             for t in base_tickets))}))
    if lost or unaccounted:
        raise AssertionError(
            f"admission overload lost {lost} / unaccounted {unaccounted}")
    if not s["shed"]:
        raise AssertionError("2x overload shed nothing — queue not bounded?")
    if base_p99 <= p99:
        print(f"# WARNING: unbounded baseline p99 {base_p99:.1f}ms did not "
              f"exceed bounded {p99:.1f}ms")
    return p99


def _bench_supervision(rows, quick):
    n_requests = 60 if quick else 150
    pool, values, rhs = _pool(seed0=20_000)
    se = ShardedEngine(n_replicas=2, cache_size=64, step_timeout_s=1.0,
                       hang_timeout_s=0.5, probation_s=0.05)
    try:
        # warm both replicas so quarantine re-homes real cache rows
        se.step(_reqs(pool, values, rhs, range(len(pool))))
        se.drain()
        r0 = se.replica("r0")
        fx = inject_faults(r0.backends, r0.default_platform, "spmm",
                           FaultPlan.hang_calls(0))

        q = AdmissionQueue(se, capacity=256, max_batch=8)
        tickets = []
        for i in range(n_requests):
            tickets.append(q.submit(_reqs(pool, values, rhs, [i])[0],
                                    deadline_ms=30_000))
            time.sleep(0.002)
        q.close()
        lost = sum(t.outcome is None for t in tickets)
        served = sum(t.outcome == "served" for t in tickets)
        s = se.stats()
        quarantines = s["supervisor"]["counters"]["quarantines"]
        moved = s["routing"]["migrated_entries"]

        # release the hang, let the abandoned future finish, re-admit
        fx.release_hangs()
        fx.restore()
        deadline = time.monotonic() + 10
        while (se.stats()["load"]["r0"]["inflight"]
               and time.monotonic() < deadline):
            time.sleep(0.02)
        time.sleep(0.1)                          # probation elapses
        se.supervisor.poll_once()
        s2 = se.stats()
        readmissions = s2["supervisor"]["counters"]["readmissions"]
        back = s2["supervisor"]["replicas"]["r0"]["state"] == "live"
        post = se.step(_reqs(pool, values, rhs, range(8)))
        ok_after = all(r is not None and r.output is not None for r in post)
    finally:
        se.close()
    rows.append((
        "admission/supervision/lost_requests", f"{lost}", "",
        f"one of 2 replicas hung mid-run: served={served} "
        f"quarantines={quarantines} rehomed_entries={moved} "
        f"readmissions={readmissions} back_live={back} "
        f"serves_after={ok_after} (gates: lost==0, quarantined, re-admitted)",
        {"lost": float(lost), "served": float(served),
         "quarantines": float(quarantines),
         "rehomed_entries": float(moved),
         "readmissions": float(readmissions),
         "back_live": float(back), "serves_after": float(ok_after)}))
    if lost:
        raise AssertionError(f"supervision scenario lost {lost} requests")
    if quarantines != 1 or readmissions != 1 or not back:
        raise AssertionError(
            f"supervision cycle broken: quarantines={quarantines} "
            f"readmissions={readmissions} back_live={back}")


def run(quick: bool | None = None):
    if quick is None:       # benchmarks.run path: REPRO_BENCH_QUICK=1
        quick = os.environ.get("REPRO_BENCH_QUICK", "") == "1"
    rows = []
    _bench_overload(rows, quick)
    _bench_supervision(rows, quick)
    common.emit(rows)


if __name__ == "__main__":
    args = sys.argv[1:]
    common.begin_section("admission")
    run(quick="--quick" in args)
    if "--json" in args:
        common.write_json(args[args.index("--json") + 1])
