"""Tiny name->factory registry used for architectures, platforms, benchmarks."""
from __future__ import annotations


class Registry:
    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, object] = {}

    def register(self, name: str, obj=None):
        if obj is not None:
            self._entries[name] = obj
            return obj

        def deco(fn):
            self._entries[name] = fn
            return fn
        return deco

    def get(self, name: str):
        if name not in self._entries:
            raise KeyError(
                f"Unknown {self.kind} '{name}'. Available: {sorted(self._entries)}")
        return self._entries[name]

    def names(self):
        return sorted(self._entries)

    def __contains__(self, name):
        return name in self._entries
