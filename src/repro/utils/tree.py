"""Small pytree utilities used across the framework (no flax dependency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(tree)
                   if hasattr(x, "shape")))


def tree_size_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def map_leaves_with_path(fn, tree):
    """tree_map with the flattened key-path string passed as first arg."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(fn(name, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def cast_floating(tree, dtype):
    """Cast floating-point leaves of a pytree to ``dtype`` (ints untouched)."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)
