from repro.utils.tree import tree_size_bytes, tree_num_params, map_leaves_with_path
from repro.utils.registry import Registry
