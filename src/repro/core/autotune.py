"""End-to-end autotuning API — the paper's technique as a framework feature.

``Autotuner`` wraps a (transfer-)trained cost model for one (platform, op)
pair and answers "which program configuration should this sparsity pattern
run with?". ``KernelAutotuner`` specializes it to the Pallas BSR kernels in
``repro/kernels``: it featurizes a block-sparsity pattern (e.g. an MoE
dispatch mask or a block-sparse attention mask) and returns kernel tile
parameters, falling back to a deterministic heuristic when no trained model
is available — so the LM stack can always call it.

Serving fast path: the query loop (featurize -> score -> build BSR) is
amortized two ways.  ``Autotuner.scores_batch``/``best_configs_batch`` stack
density pyramids and push a whole batch of matrices through the jitted
embed/score in one dispatch.  ``KernelAutotuner.get`` keys an LRU cache on a
digest of (rows, cols, shape): a repeated pattern is served its tuned config
*and* its prebuilt ``BsrPlan`` without re-featurizing, so per-request work
collapses to one O(nnz) value scatter.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cognate import (CostModelConfig, config_first_layer,
                                matrix_embedding, score_configs,
                                score_configs_from_parts,
                                score_configs_multi)
from repro.core.latent import LatentCodec
from repro.core.search import topk_exhaustive
from repro.data.features import density_pyramid, matrix_stats
from repro.data.matrices import SparseMatrix
from repro.hw.platforms import get_platform
from repro.kernels.format import BsrMatrix, BsrPlan, plan_from_coo

__all__ = ["Autotuner", "KernelAutotuner", "AutotuneCache", "TunedKernel",
           "StatsMemo", "pattern_digest", "matrix_digest",
           "cached_matrix_stats"]


# ------------------------------------------------------------ pattern keying

_I32_MIN, _I32_MAX = np.iinfo(np.int32).min, np.iinfo(np.int32).max


def _coord_bytes(a) -> bytes:
    """Canonical byte view of a coordinate array: int32 when the values fit
    (zero-copy for ``SparseMatrix``'s native int32 — no per-request int64
    upcast), int64 only for coordinates that genuinely need it.  Same
    coordinates hash identically whatever dtype the caller passes."""
    a = np.ascontiguousarray(a)
    if a.dtype == np.int32:
        return a.tobytes()
    if a.size == 0 or (_I32_MIN <= a.min() and a.max() <= _I32_MAX):
        return a.astype(np.int32).tobytes()
    return np.asarray(a, np.int64).tobytes()


def pattern_digest(rows, cols, shape) -> str:
    """Stable sha1 digest of a sparsity pattern (coordinates + logical shape).

    Args:
        rows, cols: integer coordinate arrays (any dtype; int32 and int64
            views of the same coordinates digest equal, and the int32 fast
            path hashes the array's own buffer with no copy).
        shape: the ``(n_rows, n_cols)`` logical shape.

    Returns:
        A 40-char hex string — the key every serving-layer cache
        (``AutotuneCache``, ``StatsMemo``, persistence files) uses for this
        pattern.  Pure function of its inputs; safe from any thread."""
    h = hashlib.sha1()
    h.update(np.asarray(shape, np.int64).tobytes())
    h.update(_coord_bytes(rows))
    h.update(_coord_bytes(cols))
    return h.hexdigest()


def matrix_digest(mat: SparseMatrix) -> str:
    return pattern_digest(mat.rows, mat.cols, (mat.n_rows, mat.n_cols))


class StatsMemo:
    """Thread-safe LRU memo of ``matrix_stats`` vectors keyed by pattern
    digest.  ``maxsize`` is adjustable at runtime (shrinking trims oldest
    entries); ``clear()`` drops everything — long-lived serving processes can
    bound or reset the footprint explicitly."""

    def __init__(self, maxsize: int = 256):
        self._maxsize = int(maxsize)
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self):
        with self._lock:
            return len(self._entries)

    @property
    def maxsize(self) -> int:
        return self._maxsize

    @maxsize.setter
    def maxsize(self, n: int) -> None:
        with self._lock:
            self._maxsize = int(n)
            self._trim()

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def _trim(self) -> None:
        while len(self._entries) > max(self._maxsize, 0):
            self._entries.popitem(last=False)

    def get_or_compute(self, mat: SparseMatrix,
                       digest: str | None = None) -> np.ndarray:
        key = digest or matrix_digest(mat)
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._entries.move_to_end(key)
                return hit
        stats = matrix_stats(mat)          # compute outside the lock
        with self._lock:
            self._entries[key] = stats
            self._entries.move_to_end(key)
            self._trim()
        return stats


_STATS_MEMO = StatsMemo(256)


def cached_matrix_stats(mat: SparseMatrix, digest: str | None = None) -> np.ndarray:
    """``matrix_stats`` memoized on the pattern digest — ``Autotuner.tune``
    and ``KernelAutotuner.heuristic`` share one featurization per pattern.
    Pass ``digest`` when already computed to skip re-hashing the pattern.
    The module-global memo is ``_STATS_MEMO`` (a ``StatsMemo``); use its
    ``clear()``/``maxsize`` to manage the footprint."""
    return _STATS_MEMO.get_or_compute(mat, digest)


# ------------------------------------------------------------ learned tuner

@dataclasses.dataclass
class Autotuner:
    platform_name: str
    op: str
    params: object
    model_cfg: CostModelConfig
    codec: LatentCodec
    resolution: int = 64

    def __post_init__(self):
        self.platform = get_platform(self.platform_name)
        self.space = self.platform.space
        self._z = jnp.asarray(self.codec.encode(self.space.heterogeneous()))
        self._hom: OrderedDict = OrderedDict()   # n_cols -> homogeneous enc
        self._cfg_parts: OrderedDict = OrderedDict()  # n_cols -> (G, H0)
        # foreign-space memos are keyed by id(space); every entry also PINS
        # the space object so a dead space's id can never be recycled into
        # serving another space's cached encoding, and eviction (bound 64)
        # drops the pin with the entry
        self._foreign_z: OrderedDict = OrderedDict()  # id -> (space, (G, L))
        self._foreign_hom: OrderedDict = OrderedDict()  # (id, nc) -> (s, hom)
        self._multi_parts: OrderedDict = OrderedDict()  # (id, nc) -> (s, part)
        #: batched featurize+score round-trips issued (``scores_batch`` and
        #: ``scores_multi`` each count one per jitted dispatch) — what
        #: routing tests assert to prove a step scored in ONE dispatch
        self.score_dispatches = 0
        self._emb = jax.jit(
            lambda pyr: matrix_embedding(self.params, self.model_cfg, pyr))
        self._score = jax.jit(
            lambda sm, hom, z: score_configs(self.params, self.model_cfg,
                                             sm, hom, z))
        # serving fast path (MLP predictor): the config-side half of the
        # trunk's first layer is a pure function of n_cols — precompute it
        # once per shape instead of per (matrix, config) per request
        self._fast = self.model_cfg.predictor == "mlp"
        self._cfg_first = jax.jit(
            lambda hom, z: config_first_layer(self.params, self.model_cfg,
                                              hom, z))
        self._score_fast = jax.jit(
            lambda sm, part: score_configs_from_parts(
                self.params, self.model_cfg, sm, part))

    def _homogeneous(self, n_cols: int) -> np.ndarray:
        """``space.homogeneous`` memoized on ``n_cols`` — it re-encodes the
        whole config space per call (~ms) but is a pure function of the
        matrix's column count, which serving traffic repeats endlessly."""
        h = self._hom.get(n_cols)
        if h is None:
            h = self.space.homogeneous(n_cols)
            self._hom[n_cols] = h
            while len(self._hom) > 64:
                self._hom.popitem(last=False)
        return h

    def _config_part(self, n_cols: int):
        """(G, H0) first-layer config contribution, memoized on n_cols."""
        part = self._cfg_parts.get(n_cols)
        if part is None:
            hom = jnp.asarray(self._homogeneous(n_cols))[None]
            part = self._cfg_first(hom, self._z[None])[0]
            self._cfg_parts[n_cols] = part
            while len(self._cfg_parts) > 64:
                self._cfg_parts.popitem(last=False)
        return part

    def scores_batch(self, mats: list[SparseMatrix]) -> np.ndarray:
        """(B, n_configs) predicted costs for a batch of matrices — one
        jitted embed + one jitted score dispatch for the whole batch.

        The batch is padded (by repeating the last matrix) to the next
        power-of-two bucket so a serving loop with varying miss counts
        compiles at most log2(B_max) shapes instead of one per count."""
        if not mats:
            return np.zeros((0, self.space.n_configs), np.float32)
        B = len(mats)
        bucket = 1 << max(B - 1, 0).bit_length()
        pyrs = [density_pyramid(m, self.resolution) for m in mats]
        pyr = np.stack(pyrs + [pyrs[-1]] * (bucket - B))
        sm = self._emb(jnp.asarray(pyr))
        self.score_dispatches += 1
        if self._fast:
            cols = {m.n_cols for m in mats}
            if len(cols) == 1:      # one shape: share a single (G, H0) part
                part = self._config_part(cols.pop())
            else:
                part = jnp.stack([self._config_part(m.n_cols)
                                  for m in mats]
                                 + [self._config_part(mats[-1].n_cols)]
                                 * (bucket - B))
            return np.asarray(self._score_fast(sm, part))[:B]
        hom = jnp.asarray(np.stack([self._homogeneous(m.n_cols)
                                    for m in mats]
                                   + [self._homogeneous(mats[-1].n_cols)]
                                   * (bucket - B)))
        z = jnp.broadcast_to(self._z[None], (bucket,) + self._z.shape)
        return np.asarray(self._score(sm, hom, z))[:B]

    def scores(self, mat: SparseMatrix) -> np.ndarray:
        return self.scores_batch([mat])[0]

    # ------------------------------------------------- multi-space scoring

    def _space_latent(self, space) -> np.ndarray:
        """Latent encoding of a (possibly foreign) config space's
        heterogeneous features.  The codec was trained on *this* tuner's
        platform, so a foreign space whose het width doesn't fit falls back
        to a zero latent — the -LE ablation for that space, which still
        leaves the shared homogeneous encoding to rank its configs."""
        if space is self.space:
            return np.asarray(self._z)
        hit = self._foreign_z.get(id(space))
        if hit is not None:
            return hit[1]
        try:
            z = np.asarray(self.codec.encode(space.heterogeneous()),
                           np.float32)
            if z.shape != (space.n_configs, self.model_cfg.latent_dim):
                raise ValueError(f"latent shape {z.shape}")
        except Exception:
            z = np.zeros((space.n_configs, self.model_cfg.latent_dim),
                         np.float32)
        self._foreign_z[id(space)] = (space, z)
        while len(self._foreign_z) > 64:
            self._foreign_z.popitem(last=False)
        return z

    def _space_hom(self, space, n_cols: int) -> np.ndarray:
        if space is self.space:
            return self._homogeneous(n_cols)
        key = (id(space), n_cols)
        hit = self._foreign_hom.get(key)
        if hit is not None:
            return hit[1]
        h = space.homogeneous(n_cols)
        self._foreign_hom[key] = (space, h)
        while len(self._foreign_hom) > 64:
            self._foreign_hom.popitem(last=False)
        return h

    def _part_for(self, space, n_cols: int):
        """(G, H0) first-layer config contribution for any space (the own
        space reuses ``_config_part``'s memo)."""
        if space is self.space:
            return self._config_part(n_cols)
        key = (id(space), n_cols)
        hit = self._multi_parts.get(key)
        if hit is not None:
            return hit[1]
        hom = jnp.asarray(self._space_hom(space, n_cols))[None]
        z = jnp.asarray(self._space_latent(space))[None]
        part = self._cfg_first(hom, z)[0]
        self._multi_parts[key] = (space, part)
        while len(self._multi_parts) > 64:
            self._multi_parts.popitem(last=False)
        return part

    def scores_multi(self, mats: list[SparseMatrix],
                     spaces: list) -> list[np.ndarray]:
        """One featurization, many config spaces: score a batch of matrices
        against *every* space in ``spaces`` and return per-space
        ``(B, G_s)`` arrays.

        This is the routing primitive: ``CostModelRouter`` compares
        candidate backends by scoring each untagged pattern against each
        backend's config space, and this method does it in a single jitted
        embed + a single jitted score round-trip (the spaces concatenate
        along the config axis — see ``score_configs_multi``).  With the MLP
        predictor the per-(space, n_cols) config contribution is memoized
        exactly like ``scores_batch``'s fast path.  Counts ONE
        ``score_dispatches`` tick however many spaces and matrices are
        passed (non-MLP predictors with heterogeneous ``n_cols`` in one
        batch fall back to one dispatch per distinct ``n_cols``).
        """
        if not mats:
            return [np.zeros((0, s.n_configs), np.float32) for s in spaces]
        B = len(mats)
        bucket = 1 << max(B - 1, 0).bit_length()
        pyrs = [density_pyramid(m, self.resolution) for m in mats]
        pyr = np.stack(pyrs + [pyrs[-1]] * (bucket - B))
        sm = self._emb(jnp.asarray(pyr))
        sizes = [s.n_configs for s in spaces]
        if self._fast:
            self.score_dispatches += 1

            def cat(n_cols):
                return jnp.concatenate(
                    [self._part_for(s, n_cols) for s in spaces], axis=0)

            cols = {m.n_cols for m in mats}
            if len(cols) == 1:          # one shape: share a single part
                part = cat(cols.pop())
            else:
                parts = [cat(m.n_cols) for m in mats]
                part = jnp.stack(parts + [parts[-1]] * (bucket - B))
            scores = np.asarray(self._score_fast(sm, part))[:B]
        else:
            # generic predictors: fused multi-space scoring per distinct
            # n_cols (score_configs_multi broadcasts one hom per batch)
            scores = np.zeros((B, sum(sizes)), np.float32)
            by_cols: OrderedDict = OrderedDict()
            for i, m in enumerate(mats):
                by_cols.setdefault(m.n_cols, []).append(i)
            for n_cols, idx in by_cols.items():
                self.score_dispatches += 1
                per_space = score_configs_multi(
                    self.params, self.model_cfg, sm[np.asarray(idx)],
                    [self._space_hom(s, n_cols) for s in spaces],
                    [self._space_latent(s) for s in spaces])
                row = np.concatenate([np.asarray(a) for a in per_space],
                                     axis=1)
                scores[np.asarray(idx)] = row
        out, off = [], 0
        for g in sizes:
            out.append(scores[:, off:off + g])
            off += g
        return out

    def _configs_from_scores(self, scores: np.ndarray, k: int) -> list[dict]:
        idx = topk_exhaustive(scores, k=k)
        return [{name: self.space.params[name][i].item()
                 for name in self.space.params} | {"index": int(i)}
                for i in idx]

    def best_configs(self, mat: SparseMatrix, k: int = 5) -> list[dict]:
        return self._configs_from_scores(self.scores(mat), k)

    def best_configs_batch(self, mats: list[SparseMatrix],
                           k: int = 5) -> list[list[dict]]:
        return [self._configs_from_scores(s, k) for s in self.scores_batch(mats)]

    def tune(self, mat: SparseMatrix, k: int = 5) -> dict:
        """Top-k predict, then measure the k candidates and keep the best —
        exactly the paper's deployment loop (k target executions)."""
        cands = self.best_configs(mat, k=k)
        stats = cached_matrix_stats(mat)
        rts = self.platform.runtime(stats, self.op, n_cols=mat.n_cols)
        best = min(cands, key=lambda c: rts[c["index"]])
        return best | {"runtime_ms": float(rts[best["index"]])}


# ------------------------------------------------------------- kernel tuner

@dataclasses.dataclass
class TunedKernel:
    """One autotune-cache entry: everything a serving loop needs to launch a
    tuned kernel for a known pattern with fresh values."""
    digest: str
    op: str
    config: dict            # kwargs for repro.kernels.ops.spmm / sddmm
    plan: BsrPlan           # structure-only BSR conversion (reusable)
    hits: int = 0

    def build(self, values, dtype=jnp.float32, reuse: bool = False) -> BsrMatrix:
        """O(nnz) value scatter through the cached plan -> BsrMatrix.

        ``reuse=True`` scatters into plan-owned storage (the result aliases
        it and is valid until the next reusing build) — the per-request cost
        for a cached pattern collapses to one warm fancy-indexed write."""
        return self.plan.build(values, dtype, reuse=reuse)

    def build_device(self, values, dtype=jnp.float32) -> BsrMatrix:
        """Device-resident counterpart of ``build``: one jitted
        gather+scatter, no host numpy — for values already on device (bit-
        identical output; see ``BsrPlan.build_device``)."""
        return self.plan.build_device(values, dtype)


class AutotuneCache:
    """Pattern-keyed LRU of ``TunedKernel`` entries.

    All operations (including the hit/miss/eviction counters and LRU
    reordering) hold an internal lock, so concurrent engine steps from
    multiple threads can't corrupt the ordering or drop entries."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self):
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        """Membership peek that touches neither the LRU order nor the
        hit/miss counters."""
        with self._lock:
            return key in self._entries

    def get(self, key) -> TunedKernel | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            entry.hits += 1
            return entry

    def put(self, key, entry: TunedKernel) -> None:
        with self._lock:
            if self.maxsize <= 0:
                return
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.evictions += 1

    def pop(self, key) -> TunedKernel | None:
        """Remove and return an entry without touching the hit/miss or
        eviction counters — a migration (shard rebalance re-homing a
        digest to its new owner) is neither a miss nor an eviction."""
        with self._lock:
            return self._entries.pop(key, None)

    def items(self) -> list[tuple]:
        """Snapshot of (key, entry) pairs in LRU order (oldest first) —
        what ``repro.serving.persist`` serializes."""
        with self._lock:
            return list(self._entries.items())


class KernelAutotuner:
    """Tile-config selection for the Pallas BSR kernels.

    With a trained Autotuner (platform 'tpu_pallas'), predictions come from
    the transfer-learned cost model; otherwise a deterministic structural
    heuristic keyed on the block-fill curve is used. Returns kwargs for
    ``repro.kernels.ops.spmm`` / ``sddmm``.

    ``get`` is the cached serving entry point; ``featurize_calls`` counts how
    many times a pattern was actually featurized+scored (cache misses).
    """

    def __init__(self, tuner: Autotuner | None = None, cache_size: int = 128):
        self.tuner = tuner
        self.cache = AutotuneCache(cache_size)
        self.featurize_calls = 0

    @property
    def space(self):
        """The learned tuner's config space, or ``None`` when running on the
        structural heuristic (what ``repro.serving.backends`` surfaces as a
        backend's config space)."""
        return self.tuner.space if self.tuner is not None else None

    @staticmethod
    def _kernel_kwargs(cfg: dict) -> dict:
        """Learned-space config row -> kwargs for ``repro.kernels.ops``."""
        return {"block_m": int(cfg["bm"]), "block_n": int(cfg["bn"]),
                "n_major": bool(cfg["n_major"])}

    def select(self, mat: SparseMatrix, op: str = "spmm",
               digest: str | None = None) -> dict:
        self.featurize_calls += 1
        if self.tuner is not None and self.tuner.op == op:
            return self._kernel_kwargs(self.tuner.best_configs(mat, k=1)[0])
        return self.heuristic(mat, digest=digest)

    def _install(self, mat: SparseMatrix, op: str, digest: str,
                 config: dict) -> TunedKernel:
        plan = plan_from_coo(mat.rows, mat.cols,
                             (mat.n_rows, mat.n_cols),
                             block_m=config["block_m"],
                             assume_unique=True)   # SparseMatrix invariant
        entry = TunedKernel(digest, op, config, plan)
        self.cache.put((op, digest), entry)
        return entry

    def install(self, mat: SparseMatrix, op: str, config: dict,
                digest: str | None = None) -> TunedKernel:
        """Install an externally-chosen config as this tuner's cache entry
        for ``mat``'s pattern (building and caching its ``BsrPlan``), without
        featurizing or scoring here.

        This is how routing avoids double work: ``CostModelRouter`` already
        scored the pattern against this backend's config space inside its
        one multi-space routing dispatch, so the engine installs the argmin
        config directly instead of paying a second ``scores_batch`` — the
        entry is indistinguishable from one ``get`` would have produced.
        ``featurize_calls`` does not move (no featurization happened here).
        """
        return self._install(mat, op, digest or matrix_digest(mat), config)

    def get(self, mat: SparseMatrix, op: str = "spmm") -> TunedKernel:
        """Cached pattern -> tuned kernel entry.

        Args:
            mat: the sparsity pattern (``SparseMatrix``) to tune for.
            op: ``"spmm"`` or ``"sddmm"`` — part of the cache key, so one
                tuner can serve both ops without collisions.

        Returns:
            The ``TunedKernel`` (config + prebuilt ``BsrPlan``) for this
            pattern.  A repeated pattern is served without re-featurizing
            or re-sorting its coordinates.

        Thread-safety: safe from concurrent callers — the cache is
        lock-guarded; two racing misses on one pattern may both featurize
        (last insert wins) but never corrupt the cache."""
        digest = matrix_digest(mat)
        entry = self.cache.get((op, digest))
        if entry is None:
            entry = self._install(mat, op, digest,
                                  self.select(mat, op, digest=digest))
        return entry

    def get_batch(self, mats: list[SparseMatrix], op: str = "spmm",
                  digests: list[str] | None = None) -> list[TunedKernel]:
        """Batched ``get``: all cache misses are featurized and scored in a
        single ``Autotuner.scores_batch`` dispatch (one jitted embed + score
        for the whole batch instead of one per miss).

        Args:
            mats: patterns to tune, one per request.
            op: the kernel op (one per call — ``SparseKernelEngine``
                partitions mixed-op batches before calling this).
            digests: precomputed ``matrix_digest`` values aligned with
                ``mats`` (computed here when omitted).

        Returns:
            ``TunedKernel`` entries aligned with ``mats``.  Duplicate
            patterns within the batch are tuned once and share one entry.
            ``featurize_calls`` counts one per *unique* pattern actually
            featurized, so warm-start accounting is unchanged.

        Thread-safety: same guarantees as ``get``."""
        if digests is None:
            digests = [matrix_digest(m) for m in mats]
        out: list[TunedKernel | None] = [None] * len(mats)
        miss: OrderedDict = OrderedDict()   # digest -> first miss index
        for i, d in enumerate(digests):
            entry = self.cache.get((op, d))
            if entry is not None:
                out[i] = entry
            elif d not in miss:
                miss[d] = i
        if miss:
            idx = list(miss.values())
            if self.tuner is not None and self.tuner.op == op:
                rows = self.tuner.best_configs_batch(
                    [mats[i] for i in idx], k=1)
                configs = [self._kernel_kwargs(r[0]) for r in rows]
                self.featurize_calls += len(idx)
            else:
                configs = [self.select(mats[i], op, digest=digests[i])
                           for i in idx]
            fresh = {digests[i]: self._install(mats[i], op, digests[i], cfg)
                     for i, cfg in zip(idx, configs)}
            for i, d in enumerate(digests):
                if out[i] is None:
                    out[i] = fresh[d]
        return out

    @staticmethod
    def heuristic(mat: SparseMatrix, digest: str | None = None) -> dict:
        """Pick the block height whose padded-work x step-count product is
        minimal under the measured fill curve (same physics as the platform
        model; used when no learned model is available)."""
        stats = cached_matrix_stats(mat, digest=digest)
        from repro.data.features import STAT_NAMES
        s = dict(zip(STAT_NAMES, stats))
        fills = {8: s["block8_fill"] * 8, 32: s["block32_fill"] * 32,
                 128: s["block128_fill"] * 128}
        best_bm, best_cost = 32, float("inf")
        for bm in (8, 16, 32, 64, 128):
            lb = np.log2(np.sqrt(bm * 128))
            f = np.interp(lb, [3, 5, 7], [fills[8], fills[32], fills[128]])
            touched = max(mat.nnz / max(f, 1.0), 1.0)
            cost = touched * bm * 128 + touched * 3e3   # padded work + steps
            if cost < best_cost:
                best_bm, best_cost = bm, cost
        return {"block_m": best_bm, "block_n": 128, "n_major": True}
