"""End-to-end autotuning API — the paper's technique as a framework feature.

``Autotuner`` wraps a (transfer-)trained cost model for one (platform, op)
pair and answers "which program configuration should this sparsity pattern
run with?". ``KernelAutotuner`` specializes it to the Pallas BSR kernels in
``repro/kernels``: it featurizes a block-sparsity pattern (e.g. an MoE
dispatch mask or a block-sparse attention mask) and returns kernel tile
parameters, falling back to a deterministic heuristic when no trained model
is available — so the LM stack can always call it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cognate import CostModelConfig, matrix_embedding, score_configs
from repro.core.latent import LatentCodec
from repro.core.search import topk_exhaustive
from repro.data.features import density_pyramid, matrix_stats
from repro.data.matrices import SparseMatrix
from repro.hw.platforms import get_platform


@dataclasses.dataclass
class Autotuner:
    platform_name: str
    op: str
    params: object
    model_cfg: CostModelConfig
    codec: LatentCodec
    resolution: int = 64

    def __post_init__(self):
        self.platform = get_platform(self.platform_name)
        self.space = self.platform.space
        self._z = jnp.asarray(self.codec.encode(self.space.heterogeneous()))
        self._emb = jax.jit(
            lambda pyr: matrix_embedding(self.params, self.model_cfg, pyr))
        self._score = jax.jit(
            lambda sm, hom, z: score_configs(self.params, self.model_cfg,
                                             sm, hom, z))

    def scores(self, mat: SparseMatrix) -> np.ndarray:
        pyr = density_pyramid(mat, self.resolution)[None]
        sm = self._emb(jnp.asarray(pyr))
        hom = jnp.asarray(self.space.homogeneous(mat.n_cols))[None]
        return np.asarray(self._score(sm, hom, self._z[None])[0])

    def best_configs(self, mat: SparseMatrix, k: int = 5) -> list[dict]:
        idx = topk_exhaustive(self.scores(mat), k=k)
        return [{name: self.space.params[name][i].item()
                 for name in self.space.params} | {"index": int(i)}
                for i in idx]

    def tune(self, mat: SparseMatrix, k: int = 5) -> dict:
        """Top-k predict, then measure the k candidates and keep the best —
        exactly the paper's deployment loop (k target executions)."""
        cands = self.best_configs(mat, k=k)
        stats = matrix_stats(mat)
        rts = self.platform.runtime(stats, self.op, n_cols=mat.n_cols)
        best = min(cands, key=lambda c: rts[c["index"]])
        return best | {"runtime_ms": float(rts[best["index"]])}


class KernelAutotuner:
    """Tile-config selection for the Pallas BSR kernels.

    With a trained Autotuner (platform 'tpu_pallas'), predictions come from
    the transfer-learned cost model; otherwise a deterministic structural
    heuristic keyed on the block-fill curve is used. Returns kwargs for
    ``repro.kernels.ops.spmm`` / ``sddmm``.
    """

    def __init__(self, tuner: Autotuner | None = None):
        self.tuner = tuner

    def select(self, mat: SparseMatrix, op: str = "spmm") -> dict:
        if self.tuner is not None and self.tuner.op == op:
            cfg = self.tuner.best_configs(mat, k=1)[0]
            return {"block_m": int(cfg["bm"]), "block_n": int(cfg["bn"]),
                    "n_major": bool(cfg["n_major"])}
        return self.heuristic(mat)

    @staticmethod
    def heuristic(mat: SparseMatrix) -> dict:
        """Pick the block height whose padded-work x step-count product is
        minimal under the measured fill curve (same physics as the platform
        model; used when no learned model is available)."""
        stats = matrix_stats(mat)
        from repro.data.features import STAT_NAMES
        s = dict(zip(STAT_NAMES, stats))
        fills = {8: s["block8_fill"] * 8, 32: s["block32_fill"] * 32,
                 128: s["block128_fill"] * 128}
        best_bm, best_cost = 32, float("inf")
        for bm in (8, 16, 32, 64, 128):
            import numpy as _np
            lb = _np.log2(_np.sqrt(bm * 128))
            f = _np.interp(lb, [3, 5, 7], [fills[8], fills[32], fills[128]])
            touched = max(mat.nnz / max(f, 1.0), 1.0)
            cost = touched * bm * 128 + touched * 3e3   # padded work + steps
            if cost < best_cost:
                best_bm, best_cost = bm, cost
        return {"block_m": best_bm, "block_n": 128, "n_major": True}
