"""End-to-end autotuning API — the paper's technique as a framework feature.

``Autotuner`` wraps a (transfer-)trained cost model for one (platform, op)
pair and answers "which program configuration should this sparsity pattern
run with?". ``KernelAutotuner`` specializes it to the Pallas BSR kernels in
``repro/kernels``: it featurizes a block-sparsity pattern (e.g. an MoE
dispatch mask or a block-sparse attention mask) and returns kernel tile
parameters, falling back to a deterministic heuristic when no trained model
is available — so the LM stack can always call it.

Serving fast path: the query loop (featurize -> score -> build BSR) is
amortized two ways.  ``Autotuner.scores_batch``/``best_configs_batch`` stack
density pyramids and push a whole batch of matrices through the jitted
embed/score in one dispatch.  ``KernelAutotuner.get`` keys an LRU cache on a
digest of (rows, cols, shape): a repeated pattern is served its tuned config
*and* its prebuilt ``BsrPlan`` without re-featurizing, so per-request work
collapses to one O(nnz) value scatter.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cognate import CostModelConfig, matrix_embedding, score_configs
from repro.core.latent import LatentCodec
from repro.core.search import topk_exhaustive
from repro.data.features import density_pyramid, matrix_stats
from repro.data.matrices import SparseMatrix
from repro.hw.platforms import get_platform
from repro.kernels.format import BsrMatrix, BsrPlan, plan_from_coo

__all__ = ["Autotuner", "KernelAutotuner", "AutotuneCache", "TunedKernel",
           "pattern_digest", "matrix_digest", "cached_matrix_stats"]


# ------------------------------------------------------------ pattern keying

def pattern_digest(rows, cols, shape) -> str:
    """Stable digest of a sparsity pattern (coordinates + logical shape)."""
    h = hashlib.sha1()
    h.update(np.asarray(shape, np.int64).tobytes())
    h.update(np.ascontiguousarray(np.asarray(rows, np.int64)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(cols, np.int64)).tobytes())
    return h.hexdigest()


def matrix_digest(mat: SparseMatrix) -> str:
    return pattern_digest(mat.rows, mat.cols, (mat.n_rows, mat.n_cols))


_STATS_MEMO: OrderedDict = OrderedDict()
_STATS_MEMO_MAX = 256


def cached_matrix_stats(mat: SparseMatrix, digest: str | None = None) -> np.ndarray:
    """``matrix_stats`` memoized on the pattern digest — ``Autotuner.tune``
    and ``KernelAutotuner.heuristic`` share one featurization per pattern.
    Pass ``digest`` when already computed to skip re-hashing the pattern."""
    key = digest or matrix_digest(mat)
    hit = _STATS_MEMO.get(key)
    if hit is not None:
        _STATS_MEMO.move_to_end(key)
        return hit
    stats = matrix_stats(mat)
    _STATS_MEMO[key] = stats
    while len(_STATS_MEMO) > _STATS_MEMO_MAX:
        _STATS_MEMO.popitem(last=False)
    return stats


# ------------------------------------------------------------ learned tuner

@dataclasses.dataclass
class Autotuner:
    platform_name: str
    op: str
    params: object
    model_cfg: CostModelConfig
    codec: LatentCodec
    resolution: int = 64

    def __post_init__(self):
        self.platform = get_platform(self.platform_name)
        self.space = self.platform.space
        self._z = jnp.asarray(self.codec.encode(self.space.heterogeneous()))
        self._emb = jax.jit(
            lambda pyr: matrix_embedding(self.params, self.model_cfg, pyr))
        self._score = jax.jit(
            lambda sm, hom, z: score_configs(self.params, self.model_cfg,
                                             sm, hom, z))

    def scores_batch(self, mats: list[SparseMatrix]) -> np.ndarray:
        """(B, n_configs) predicted costs for a batch of matrices — one
        jitted embed + one jitted score dispatch for the whole batch."""
        pyr = np.stack([density_pyramid(m, self.resolution) for m in mats])
        sm = self._emb(jnp.asarray(pyr))
        hom = jnp.asarray(np.stack([self.space.homogeneous(m.n_cols)
                                    for m in mats]))
        z = jnp.broadcast_to(self._z[None], (len(mats),) + self._z.shape)
        return np.asarray(self._score(sm, hom, z))

    def scores(self, mat: SparseMatrix) -> np.ndarray:
        return self.scores_batch([mat])[0]

    def _configs_from_scores(self, scores: np.ndarray, k: int) -> list[dict]:
        idx = topk_exhaustive(scores, k=k)
        return [{name: self.space.params[name][i].item()
                 for name in self.space.params} | {"index": int(i)}
                for i in idx]

    def best_configs(self, mat: SparseMatrix, k: int = 5) -> list[dict]:
        return self._configs_from_scores(self.scores(mat), k)

    def best_configs_batch(self, mats: list[SparseMatrix],
                           k: int = 5) -> list[list[dict]]:
        return [self._configs_from_scores(s, k) for s in self.scores_batch(mats)]

    def tune(self, mat: SparseMatrix, k: int = 5) -> dict:
        """Top-k predict, then measure the k candidates and keep the best —
        exactly the paper's deployment loop (k target executions)."""
        cands = self.best_configs(mat, k=k)
        stats = cached_matrix_stats(mat)
        rts = self.platform.runtime(stats, self.op, n_cols=mat.n_cols)
        best = min(cands, key=lambda c: rts[c["index"]])
        return best | {"runtime_ms": float(rts[best["index"]])}


# ------------------------------------------------------------- kernel tuner

@dataclasses.dataclass
class TunedKernel:
    """One autotune-cache entry: everything a serving loop needs to launch a
    tuned kernel for a known pattern with fresh values."""
    digest: str
    op: str
    config: dict            # kwargs for repro.kernels.ops.spmm / sddmm
    plan: BsrPlan           # structure-only BSR conversion (reusable)
    hits: int = 0

    def build(self, values, dtype=jnp.float32, reuse: bool = False) -> BsrMatrix:
        """O(nnz) value scatter through the cached plan -> BsrMatrix.

        ``reuse=True`` scatters into plan-owned storage (the result aliases
        it and is valid until the next reusing build) — the per-request cost
        for a cached pattern collapses to one warm fancy-indexed write."""
        return self.plan.build(values, dtype, reuse=reuse)


class AutotuneCache:
    """Pattern-keyed LRU of ``TunedKernel`` entries."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._entries)

    def get(self, key) -> TunedKernel | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        entry.hits += 1
        return entry

    def put(self, key, entry: TunedKernel) -> None:
        if self.maxsize <= 0:
            return
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)


class KernelAutotuner:
    """Tile-config selection for the Pallas BSR kernels.

    With a trained Autotuner (platform 'tpu_pallas'), predictions come from
    the transfer-learned cost model; otherwise a deterministic structural
    heuristic keyed on the block-fill curve is used. Returns kwargs for
    ``repro.kernels.ops.spmm`` / ``sddmm``.

    ``get`` is the cached serving entry point; ``featurize_calls`` counts how
    many times a pattern was actually featurized+scored (cache misses).
    """

    def __init__(self, tuner: Autotuner | None = None, cache_size: int = 128):
        self.tuner = tuner
        self.cache = AutotuneCache(cache_size)
        self.featurize_calls = 0

    def select(self, mat: SparseMatrix, op: str = "spmm",
               digest: str | None = None) -> dict:
        self.featurize_calls += 1
        if self.tuner is not None and self.tuner.op == op:
            cfg = self.tuner.best_configs(mat, k=1)[0]
            return {"block_m": int(cfg["bm"]), "block_n": int(cfg["bn"]),
                    "n_major": bool(cfg["n_major"])}
        return self.heuristic(mat, digest=digest)

    def get(self, mat: SparseMatrix, op: str = "spmm") -> TunedKernel:
        """Cached pattern -> (config, BsrPlan). A repeated pattern is served
        without re-featurizing or re-sorting its coordinates."""
        digest = matrix_digest(mat)
        entry = self.cache.get((op, digest))
        if entry is None:
            config = self.select(mat, op, digest=digest)
            plan = plan_from_coo(mat.rows, mat.cols,
                                 (mat.n_rows, mat.n_cols),
                                 block_m=config["block_m"],
                                 assume_unique=True)   # SparseMatrix invariant
            entry = TunedKernel(digest, op, config, plan)
            self.cache.put((op, digest), entry)
        return entry

    @staticmethod
    def heuristic(mat: SparseMatrix, digest: str | None = None) -> dict:
        """Pick the block height whose padded-work x step-count product is
        minimal under the measured fill curve (same physics as the platform
        model; used when no learned model is available)."""
        stats = cached_matrix_stats(mat, digest=digest)
        from repro.data.features import STAT_NAMES
        s = dict(zip(STAT_NAMES, stats))
        fills = {8: s["block8_fill"] * 8, 32: s["block32_fill"] * 32,
                 128: s["block128_fill"] * 128}
        best_bm, best_cost = 32, float("inf")
        for bm in (8, 16, 32, 64, 128):
            lb = np.log2(np.sqrt(bm * 128))
            f = np.interp(lb, [3, 5, 7], [fills[8], fills[32], fills[128]])
            touched = max(mat.nnz / max(f, 1.0), 1.0)
            cost = touched * bm * 128 + touched * 3e3   # padded work + steps
            if cost < best_cost:
                best_bm, best_cost = bm, cost
        return {"block_m": best_bm, "block_n": 128, "n_major": True}
