"""Pre-train -> few-shot fine-tune orchestration (paper §4.1, Fig. 1).

``TransferPipeline`` owns the three-stage recipe:
  1. pre-train the shared model on the cheap source platform (CPU),
  2. train the target platform's latent autoencoder *unsupervised* on its
     enumerated config space (zero simulator samples),
  3. few-shot fine-tune on labels from k target matrices.

It also provides every baseline the paper compares against: zero-shot,
no-transfer, WACO+FA, WACO+FM.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cognate import CostModelConfig
from repro.core.latent import LatentCodec, make_codec
from repro.core.trainer import TrainConfig, evaluate_cost_model, train_cost_model
from repro.data.dataset import CostDataset

# partial fine-tuning: the first two featurizer blocks carry low-level
# statistics that transfer as-is (Neyshabur et al. 2020; Shen et al. 2021)
DEFAULT_FREEZE = ("featurizer/blocks/0", "featurizer/blocks/1")


@dataclasses.dataclass
class TransferResult:
    params: object
    history: dict
    codec: LatentCodec
    model_cfg: CostModelConfig


def pretrain_source(model_cfg: CostModelConfig, source_ds: CostDataset,
                    epochs: int = 100, seed: int = 0, lr: float = 1e-4,
                    val_dataset: CostDataset | None = None,
                    codec: LatentCodec | None = None, latent_kind: str = "ae",
                    ae_epochs: int = 300, verbose=False) -> TransferResult:
    codec = codec or make_codec(latent_kind, source_ds.het, seed=seed,
                                epochs=ae_epochs, fa_platform=source_ds.platform)
    cfg = TrainConfig(epochs=epochs, lr=lr, seed=seed)
    params, hist = train_cost_model(model_cfg, source_ds, codec, cfg,
                                    val_dataset=val_dataset, verbose=verbose)
    return TransferResult(params, hist, codec, model_cfg)


def finetune_target(pre: TransferResult, target_ds: CostDataset,
                    epochs: int = 100, seed: int = 0, lr: float = 1e-4,
                    freeze=DEFAULT_FREEZE, latent_kind: str = "ae",
                    val_dataset: CostDataset | None = None,
                    codec: LatentCodec | None = None,
                    ae_epochs: int = 300, verbose=False) -> TransferResult:
    """Few-shot fine-tuning on the target platform (paper: 5 matrices)."""
    codec = codec or make_codec(latent_kind, target_ds.het, seed=seed,
                                epochs=ae_epochs, fa_platform=target_ds.platform)
    cfg = TrainConfig(epochs=epochs, lr=lr, seed=seed, freeze_prefixes=freeze,
                      batch_matrices=min(8, target_ds.n_matrices))
    params, hist = train_cost_model(pre.model_cfg, target_ds, codec, cfg,
                                    init_params=pre.params,
                                    val_dataset=val_dataset, verbose=verbose)
    return TransferResult(params, hist, codec, pre.model_cfg)


def train_scratch(model_cfg: CostModelConfig, target_ds: CostDataset,
                  epochs: int = 100, seed: int = 0, lr: float = 1e-4,
                  latent_kind: str = "ae", ae_epochs: int = 300,
                  verbose=False) -> TransferResult:
    """'No transfer' baseline: train only on target samples."""
    codec = make_codec(latent_kind, target_ds.het, seed=seed, epochs=ae_epochs,
                       fa_platform=target_ds.platform)
    cfg = TrainConfig(epochs=epochs, lr=lr, seed=seed,
                      batch_matrices=min(8, target_ds.n_matrices))
    params, hist = train_cost_model(model_cfg, target_ds, codec, cfg,
                                    verbose=verbose)
    return TransferResult(params, hist, codec, model_cfg)


def zero_shot(pre: TransferResult, target_ds: CostDataset,
              latent_kind: str = "ae", seed: int = 0,
              ae_epochs: int = 300) -> TransferResult:
    """Source model applied to the target with no fine-tuning. The target's
    latent codec exists (it is unsupervised) but the predictor never saw its
    statistics — the paper's point about why zero-shot underperforms."""
    codec = make_codec(latent_kind, target_ds.het, seed=seed, epochs=ae_epochs,
                       fa_platform=target_ds.platform)
    return TransferResult(pre.params, pre.history, codec, pre.model_cfg)


def evaluate(result: TransferResult, eval_ds: CostDataset, ks=(1, 5)) -> dict:
    return evaluate_cost_model(result.params, result.model_cfg, eval_ds,
                               result.codec, ks=ks)
