"""Configuration search over a cost model's predictions (paper §2.3).

The paper's spaces are small enough for exhaustive scoring (256/270 configs);
``topk_exhaustive`` is the production path. ``simulated_annealing`` is the
auxiliary search used when a space is too large to enumerate (the CPU space
here, and any future accelerator with combinatorial knobs).
"""
from __future__ import annotations

import numpy as np


def topk_exhaustive(scores: np.ndarray, k: int = 5) -> np.ndarray:
    """scores: (n_cfg,) -> indices of the k best (lowest predicted cost)."""
    k = min(k, scores.shape[0])
    idx = np.argpartition(scores, k - 1)[:k]
    return idx[np.argsort(scores[idx])]


def simulated_annealing(score_fn, n_configs: int, neighbors_fn=None,
                        steps: int = 500, t0: float = 1.0, t1: float = 0.01,
                        seed: int = 0, batch: int = 1):
    """Generic SA over config indices.

    score_fn: (indices (m,)) -> scores (m,)   (lower is better)
    neighbors_fn: index -> candidate neighbor indices; default = random jump.
    Returns (best_index, best_score, trace).
    """
    rng = np.random.default_rng(seed)
    cur = int(rng.integers(n_configs))
    cur_s = float(score_fn(np.asarray([cur]))[0])
    best, best_s = cur, cur_s
    trace = [best_s]
    for i in range(steps):
        t = t0 * (t1 / t0) ** (i / max(steps - 1, 1))
        if neighbors_fn is not None:
            cands = np.asarray(neighbors_fn(cur))
            nxt = int(cands[rng.integers(len(cands))])
        else:
            nxt = int(rng.integers(n_configs))
        s = float(score_fn(np.asarray([nxt]))[0])
        if s < cur_s or rng.random() < np.exp(-(s - cur_s) / max(t, 1e-9)):
            cur, cur_s = nxt, s
        if cur_s < best_s:
            best, best_s = cur, cur_s
        trace.append(best_s)
    return best, best_s, trace


def hamming_neighbors(space, index: int) -> list[int]:
    """Configs differing in exactly one parameter (for SA on product spaces)."""
    params = space.params
    names = list(params)
    n = space.n_configs
    current = {k: params[k][index] for k in names}
    out = []
    for k in names:
        for v in space.choices[k]:
            if v == current[k]:
                continue
            match = np.ones(n, bool)
            for k2 in names:
                want = v if k2 == k else current[k2]
                match &= params[k2] == want
            idx = np.flatnonzero(match)
            if idx.size:
                out.append(int(idx[0]))
    return out
