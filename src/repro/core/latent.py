"""Latent encoding of heterogeneous configuration components (paper §3.3).

Per target (platform, primitive) pair we train an unsupervised autoencoder on
the *full enumerated config space* (no runtime labels needed — this is the
point: standardizing heterogeneous knobs costs zero simulator samples). The
encoder half then maps each config's heterogeneous features to a fixed-width
latent z consumed by the predictor.

Ablation variants (paper Fig. 9): PCA, VAE, and raw feature augmentation (FA,
zero-padded union space).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nn
from repro.optim import AdamWConfig, adamw_init, adamw_update

LATENT_DIM = 64  # paper Table 6


@dataclasses.dataclass
class LatentCodec:
    """Picklable encoder: holds parameters, not closures."""
    kind: str                 # ae | vae | pca | fa | none
    latent_dim: int
    payload: dict             # numpy arrays (AE params / PCA basis / offset)
    history: dict

    def encode(self, het: np.ndarray) -> np.ndarray:
        x = jnp.asarray(het, jnp.float32)
        if self.kind in ("ae", "vae"):
            z = _ae_encode(self.payload["params"], x)
            if self.kind == "vae":
                z = jnp.split(z, 2, axis=-1)[0]
            return np.asarray(z)
        if self.kind == "pca":
            return np.asarray((x - self.payload["mu"]) @ self.payload["basis"])
        if self.kind == "fa":
            off = self.payload["offset"]
            d = het.shape[1]
            return np.asarray(jnp.pad(
                x, ((0, 0), (off, self.latent_dim - d - off))))
        if self.kind == "none":
            return np.zeros((het.shape[0], self.latent_dim), np.float32)
        raise ValueError(self.kind)


def _to_numpy(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


def _ae_init(key, din, enc_out, hidden=32, dec_in=None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dec_in = dec_in or enc_out
    return {
        "enc": [nn.dense_init(k1, din, hidden), nn.dense_init(k2, hidden, enc_out)],
        "dec": [nn.dense_init(k3, dec_in, hidden), nn.dense_init(k4, hidden, din)],
    }


def _ae_encode(p, x):
    h = jax.nn.relu(nn.dense(p["enc"][0], x))
    return nn.dense(p["enc"][1], h)


def _ae_decode(p, z):
    h = jax.nn.relu(nn.dense(p["dec"][0], z))
    return nn.dense(p["dec"][1], h)


def train_autoencoder(het: np.ndarray, latent_dim: int = LATENT_DIM,
                      epochs: int = 1000, lr: float = 1e-3, batch: int = 32,
                      seed: int = 0, variational: bool = False) -> LatentCodec:
    """Paper Table 4 hyperparameters: Adam, lr 1e-3, bs 32, 1000 epochs, MSE."""
    key = jax.random.PRNGKey(seed)
    din = het.shape[1]
    out_latent = latent_dim * (2 if variational else 1)
    params = _ae_init(key, din, out_latent, dec_in=latent_dim)
    cfg = AdamWConfig(lr=lr, grad_clip_norm=None)
    state = adamw_init(params, cfg)
    x_all = jnp.asarray(het)

    def loss_fn(p, x, key):
        z = _ae_encode(p, x)
        if variational:
            mu, logvar = jnp.split(z, 2, axis=-1)
            eps = jax.random.normal(key, mu.shape)
            zs = mu + jnp.exp(0.5 * logvar) * eps
            recon = _ae_decode(p, zs)
            kl = -0.5 * jnp.mean(1 + logvar - mu ** 2 - jnp.exp(logvar))
            return jnp.mean((recon - x) ** 2) + 1e-3 * kl
        recon = _ae_decode(p, z)
        return jnp.mean((recon - x) ** 2)

    @jax.jit
    def step(p, s, x, key):
        l, g = jax.value_and_grad(loss_fn)(p, x, key)
        p, s, _ = adamw_update(p, g, s, cfg)
        return p, s, l

    n = het.shape[0]
    rng = np.random.default_rng(seed)
    losses = []
    steps_per_epoch = max(n // batch, 1)
    for e in range(epochs):
        perm = rng.permutation(n)
        tot = 0.0
        for i in range(steps_per_epoch):
            idx = perm[i * batch:(i + 1) * batch]
            key, sub = jax.random.split(key)
            params, state, l = step(params, state, x_all[idx], sub)
            tot += float(l)
        losses.append(tot / steps_per_epoch)

    return LatentCodec("vae" if variational else "ae", latent_dim,
                       {"params": _to_numpy(params)}, {"loss": losses})


def pca_codec(het: np.ndarray, latent_dim: int = LATENT_DIM) -> LatentCodec:
    x = het - het.mean(0, keepdims=True)
    _, _, vt = np.linalg.svd(x, full_matrices=False)
    k = min(latent_dim, vt.shape[0])
    basis = np.zeros((het.shape[1], latent_dim), np.float32)
    basis[:, :k] = vt[:k].T
    mu = het.mean(0, keepdims=True).astype(np.float32)
    return LatentCodec("pca", latent_dim, {"basis": basis, "mu": mu}, {})


# Daumé-style union space: each platform occupies a disjoint block, so a
# model trained on one platform's block sees only zeros for another's.
FA_OFFSETS = {"cpu": 0, "spade": 24, "gpu": 37, "tpu_pallas": 0}


def fa_codec(het: np.ndarray, latent_dim: int = LATENT_DIM,
             offset: int = 0) -> LatentCodec:
    """Feature augmentation: raw het features placed at the platform's
    disjoint offset in a fixed-width union space, zero elsewhere.

    This reproduces the sparse union-space representation the paper shows
    transfers poorly (WACO+FA baseline)."""
    d = het.shape[1]
    if offset + d > latent_dim:
        raise ValueError("FA union space too narrow for this platform block")
    return LatentCodec("fa", latent_dim, {"offset": offset}, {})


def zero_codec(latent_dim: int = LATENT_DIM) -> LatentCodec:
    return LatentCodec("none", latent_dim, {}, {})


def make_codec(kind: str, het: np.ndarray, latent_dim: int = LATENT_DIM,
               seed: int = 0, epochs: int = 1000,
               fa_platform: str = "cpu") -> LatentCodec:
    if kind == "ae":
        return train_autoencoder(het, latent_dim, epochs=epochs, seed=seed)
    if kind == "vae":
        return train_autoencoder(het, latent_dim, epochs=epochs, seed=seed,
                                 variational=True)
    if kind == "pca":
        return pca_codec(het, latent_dim)
    if kind == "fa":
        return fa_codec(het, latent_dim, offset=FA_OFFSETS[fa_platform])
    if kind == "none":
        return zero_codec(latent_dim)
    raise ValueError(kind)
