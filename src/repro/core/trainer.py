"""Cost-model training/fine-tuning loop (paper Table 3 hyperparameters).

Training batches pair each sampled matrix with G of its observed
configurations; the pairwise margin ranking loss is computed within each
matrix's group (runtimes across different matrices are not comparable).

Few-shot fine-tuning reuses pre-trained parameters, swaps the latent codec
for the target platform's autoencoder, and optionally freezes the early
featurizer blocks (partial fine-tuning, Shen et al. 2021).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cognate import CostModelConfig, apply_cost_model, init_cost_model
from repro.core.latent import LatentCodec
from repro.core.loss import (geomean, kendall_tau, ordered_pair_accuracy,
                             pairwise_ranking_loss, topk_speedup)
from repro.data.dataset import CostDataset
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 100
    batch_matrices: int = 16
    group: int = 8                 # configs per matrix per step
    lr: float = 1e-4               # paper Table 3
    seed: int = 0
    freeze_prefixes: tuple = ()    # parameter paths with zeroed gradients
    eval_every: int = 5
    min_steps_per_epoch: int = 4


def _freeze_mask(params, prefixes):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    mask = []
    for path, _ in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        mask.append(not any(name.startswith(pre) for pre in prefixes))
    return jax.tree_util.tree_unflatten(treedef, mask)


def _per_matrix_samples(ds: CostDataset):
    by_mat = [[] for _ in range(ds.n_matrices)]
    for mi, ci in zip(ds.sample_matrix, ds.sample_config):
        by_mat[mi].append(ci)
    return [np.asarray(v, np.int64) for v in by_mat]


def train_cost_model(model_cfg: CostModelConfig, dataset: CostDataset,
                     codec: LatentCodec, train_cfg: TrainConfig,
                     init_params=None, val_dataset: CostDataset | None = None,
                     verbose: bool = False):
    """Returns (params, history dict)."""
    key = jax.random.PRNGKey(train_cfg.seed)
    params = init_params if init_params is not None else \
        init_cost_model(key, model_cfg)
    opt_cfg = AdamWConfig(lr=train_cfg.lr, grad_clip_norm=1.0)
    opt_state = adamw_init(params, opt_cfg)
    grad_mask = _freeze_mask(params, train_cfg.freeze_prefixes) \
        if train_cfg.freeze_prefixes else None

    z_table = jnp.asarray(codec.encode(dataset.het))          # (n_cfg, L)
    pyramids = jnp.asarray(dataset.pyramids)
    homog_all = jnp.asarray(dataset.homog)                    # (n_mat, n_cfg, 53)
    runtimes = jnp.asarray(np.log(dataset.runtimes_full + 1e-9))
    by_mat = _per_matrix_samples(dataset)

    def loss_fn(p, pyr, hom, z, rt):
        scores = apply_cost_model(p, model_cfg, pyr, hom, z)
        return pairwise_ranking_loss(scores, rt)

    @jax.jit
    def step(p, s, pyr, hom, z, rt):
        l, g = jax.value_and_grad(loss_fn)(p, pyr, hom, z, rt)
        if grad_mask is not None:
            g = jax.tree_util.tree_map(
                lambda m, gr: gr if m else jnp.zeros_like(gr), grad_mask, g)
        p, s, m = adamw_update(p, g, s, opt_cfg)
        return p, s, l

    rng = np.random.default_rng(train_cfg.seed)
    B = min(train_cfg.batch_matrices, dataset.n_matrices)
    G = train_cfg.group
    steps_per_epoch = max(int(np.ceil(dataset.n_matrices / B)),
                          train_cfg.min_steps_per_epoch)
    history = {"loss": [], "val_loss": [], "val_opa": [], "val_ktau": [],
               "epoch_time": []}

    for epoch in range(train_cfg.epochs):
        t0 = time.time()
        tot = 0.0
        for _ in range(steps_per_epoch):
            mats = rng.choice(dataset.n_matrices, size=B,
                              replace=dataset.n_matrices < B)
            cfg_idx = np.stack([rng.choice(by_mat[m], size=G,
                                           replace=by_mat[m].size < G)
                                for m in mats])              # (B, G)
            pyr = pyramids[mats]
            hom = homog_all[jnp.asarray(mats)[:, None], cfg_idx]
            z = z_table[cfg_idx]
            rt = runtimes[jnp.asarray(mats)[:, None], cfg_idx]
            params, opt_state, l = step(params, opt_state, pyr, hom, z, rt)
            tot += float(l)
        history["loss"].append(tot / steps_per_epoch)
        history["epoch_time"].append(time.time() - t0)
        if val_dataset is not None and (epoch % train_cfg.eval_every == 0 or
                                        epoch == train_cfg.epochs - 1):
            m = evaluate_cost_model(params, model_cfg, val_dataset, codec,
                                    ks=(1,), observed_only=True)
            history["val_loss"].append(m["prl"])
            history["val_opa"].append(m["opa"])
            history["val_ktau"].append(m["ktau"])
        if verbose:
            print(f"  epoch {epoch:3d} loss {history['loss'][-1]:.4f} "
                  f"({history['epoch_time'][-1]:.1f}s)")
    return params, history


# --------------------------------------------------------------- evaluation

def score_full_space(params, model_cfg: CostModelConfig, dataset: CostDataset,
                     codec: LatentCodec, chunk: int = 256) -> np.ndarray:
    """Score every config of the space for every matrix -> (n_mat, n_cfg)."""
    from repro.core.cognate import matrix_embedding, score_configs
    z_table = jnp.asarray(codec.encode(dataset.het))
    n_cfg = z_table.shape[0]
    pad = (-n_cfg) % chunk
    z_pad = jnp.pad(z_table, ((0, pad), (0, 0)))

    emb_fn = jax.jit(lambda pyr: matrix_embedding(params, model_cfg, pyr))
    score_fn = jax.jit(lambda sm, hom, z: score_configs(params, model_cfg,
                                                        sm, hom, z))
    out = np.zeros((dataset.n_matrices, n_cfg), np.float32)
    for mi in range(dataset.n_matrices):
        sm = emb_fn(jnp.asarray(dataset.pyramids[mi:mi + 1]))
        hom = jnp.pad(jnp.asarray(dataset.homog[mi]), ((0, pad), (0, 0)))
        scores = []
        for c0 in range(0, n_cfg + pad, chunk):
            s = score_fn(sm, hom[None, c0:c0 + chunk], z_pad[None, c0:c0 + chunk])
            scores.append(np.asarray(s[0]))
        out[mi] = np.concatenate(scores)[:n_cfg]
    return out


def evaluate_cost_model(params, model_cfg: CostModelConfig,
                        dataset: CostDataset, codec: LatentCodec,
                        ks=(1, 5), observed_only: bool = False) -> dict:
    """Paper evaluation: rank metrics + top-k speedups vs the default config."""
    scores = score_full_space(params, model_cfg, dataset, codec)
    rts = dataset.runtimes_full
    if observed_only:
        # rank metrics restricted to the observed sample subset (validation)
        opa_s, opa_t = [], []
        for mi in range(dataset.n_matrices):
            sel = dataset.sample_config[dataset.sample_matrix == mi]
            if sel.size >= 2:
                opa_s.append(scores[mi, sel])
                opa_t.append(rts[mi, sel])
        opa = np.mean([ordered_pair_accuracy(s[None], t[None])
                       for s, t in zip(opa_s, opa_t)]) if opa_s else 0.0
        ktau = np.mean([kendall_tau(s[None], t[None])
                        for s, t in zip(opa_s, opa_t)]) if opa_s else 0.0
        prl = float(np.mean([
            np.mean(np.maximum(0, 1 - (s[:, None] - s[None, :]) *
                               np.sign(t[:, None] - t[None, :])) *
                    (np.sign(t[:, None] - t[None, :]) != 0))
            for s, t in zip(opa_s, opa_t)])) if opa_s else 0.0
    else:
        opa = ordered_pair_accuracy(scores, rts)
        ktau = kendall_tau(scores, rts)
        prl = 0.0
    if observed_only and not opa_s:
        # validation set carries full labels, no sampled subset: fall back
        # to full-space rank metrics
        opa = ordered_pair_accuracy(scores, rts)
        ktau = kendall_tau(scores, rts)
    result = {"opa": float(opa), "ktau": float(ktau), "prl": prl}
    for k in ks:
        sp, ape = topk_speedup(scores, rts, dataset.default_index, k=k)
        result[f"top{k}_speedup"] = sp
        result[f"top{k}_geomean"] = geomean(sp)
        result[f"top{k}_ape"] = float(ape.mean())
    # oracle: score == true runtime (lower is better) -> picks the optimum
    opt_sp, _ = topk_speedup(rts, rts, dataset.default_index, k=1)
    result["optimal_speedup"] = opt_sp
    result["optimal_geomean"] = geomean(opt_sp)
    return result
