"""Learning objective and ranking metrics (paper App. A.4, §4.4 Fig. 6).

Pairwise margin ranking loss over all config pairs of the same matrix:
    L = sum max(0, 1 - (r1 - r2)) * delta,  delta = sign(t1 - t2)
Metrics: OPA (ordered pair accuracy), Kendall's tau, APE of the selected
configuration, and top-k speedup over the platform default.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pairwise_ranking_loss(scores, runtimes, valid=None):
    """scores/runtimes: (B, G). Margin ranking loss over within-row pairs."""
    s1 = scores[:, :, None]
    s2 = scores[:, None, :]
    t1 = runtimes[:, :, None]
    t2 = runtimes[:, None, :]
    delta = jnp.sign(t1 - t2)
    # hinge on the signed score difference; delta==0 pairs contribute 0
    raw = jnp.maximum(0.0, 1.0 - (s1 - s2) * delta) * jnp.abs(delta)
    mask = jnp.abs(delta) > 0
    if valid is not None:
        pair_valid = valid[:, :, None] & valid[:, None, :]
        mask = mask & pair_valid
    return jnp.sum(raw * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def ordered_pair_accuracy(scores: np.ndarray, runtimes: np.ndarray) -> float:
    """Fraction of config pairs whose predicted order matches the true order."""
    total, correct = 0, 0
    for s, t in zip(np.atleast_2d(scores), np.atleast_2d(runtimes)):
        ds = np.sign(s[:, None] - s[None, :])
        dt = np.sign(t[:, None] - t[None, :])
        m = dt != 0
        total += int(m.sum())
        correct += int(((ds == dt) & m).sum())
    return correct / max(total, 1)


def kendall_tau(scores: np.ndarray, runtimes: np.ndarray) -> float:
    """Mean Kendall's tau-b across matrices (rows)."""
    from scipy.stats import kendalltau
    taus = []
    for s, t in zip(np.atleast_2d(scores), np.atleast_2d(runtimes)):
        tau, _ = kendalltau(s, t)
        if np.isfinite(tau):
            taus.append(tau)
    return float(np.mean(taus)) if taus else 0.0


def topk_speedup(scores: np.ndarray, runtimes_full: np.ndarray,
                 default_index: int, k: int = 1):
    """Per-matrix speedup of the best of the model's top-k picks vs default.

    Mirrors the paper's evaluation: run the k predicted-best configs on the
    target, keep the fastest, compare against the default configuration.
    Returns (speedups (n,), ape (n,)).
    """
    scores = np.atleast_2d(scores)
    runtimes_full = np.atleast_2d(runtimes_full)
    n = scores.shape[0]
    sp = np.zeros(n)
    ape = np.zeros(n)
    for i in range(n):
        pick = np.argsort(scores[i])[:k]
        t_model = runtimes_full[i, pick].min()
        t_default = runtimes_full[i, default_index]
        t_opt = runtimes_full[i].min()
        sp[i] = t_default / t_model
        ape[i] = abs(t_model - t_opt) / t_opt * 100.0
    return sp, ape


def geomean(x) -> float:
    x = np.asarray(x, np.float64)
    return float(np.exp(np.log(np.maximum(x, 1e-12)).mean()))
