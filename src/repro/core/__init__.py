# The paper's primary contribution: the COGNATE transfer-learned cost-model
# stack (featurizer, config mapper, latent encoder, predictor, ranking
# trainer, pretrain->few-shot-finetune pipeline, search, autotune API).
from repro.core.cognate import CostModelConfig, init_cost_model, apply_cost_model
from repro.core.latent import LatentCodec, make_codec, LATENT_DIM
from repro.core.loss import (pairwise_ranking_loss, ordered_pair_accuracy,
                             kendall_tau, topk_speedup, geomean)
from repro.core.trainer import (TrainConfig, train_cost_model,
                                evaluate_cost_model, score_full_space)
from repro.core.transfer import (pretrain_source, finetune_target, train_scratch,
                                 zero_shot, evaluate, TransferResult)
from repro.core.autotune import Autotuner, KernelAutotuner
