"""Minimal functional NN substrate (no flax): params are plain dict pytrees.

Conventions: every layer is an (init, apply) pair. Images are NCHW to match
the density pyramid layout (C, R, R).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)


# ----------------------------------------------------------------- dense

def dense_init(key, din, dout):
    kw, _ = jax.random.split(key)
    return {"w": _he(kw, (din, dout), din), "b": jnp.zeros((dout,))}


def dense(p, x):
    return x @ p["w"] + p["b"]


def mlp_init(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, a, b) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp(layers, x, final_act=False):
    for i, p in enumerate(layers):
        x = dense(p, x)
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ------------------------------------------------------------------ conv

def conv_init(key, cin, cout, ksize):
    kw, _ = jax.random.split(key)
    w = _he(kw, (cout, cin, ksize, ksize), cin * ksize * ksize)
    return {"w": w, "b": jnp.zeros((cout,))}


def conv(p, x, stride=1):
    """x: (B, C, H, W) -> (B, Cout, H', W'), SAME padding."""
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + p["b"][None, :, None, None]


def max_pool(x, window=2):
    # identity once the spatial extent is below the window: pooling a
    # (.., 1, 1) map to (.., 0, 0) would feed NaNs (mean of empty) into
    # every downstream tap — bites low-resolution density pyramids, where
    # the featurizer has more pool stages than the input has octaves
    if x.shape[2] < window or x.shape[3] < window:
        return x
    return lax.reduce_window(x, -jnp.inf, lax.max,
                             (1, 1, window, window), (1, 1, window, window),
                             "VALID")


def global_avg_pool(x):
    return x.mean(axis=(2, 3))


# ------------------------------------------------------- layer norm (1d)

def layernorm_init(dim):
    return {"g": jnp.ones((dim,)), "b": jnp.zeros((dim,))}


def layernorm(p, x, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


# ------------------------------------------------------ recurrent cells
# Used only by the Fig. 8 predictor ablation (LSTM/GRU alternatives).

def lstm_init(key, din, dh):
    k1, k2 = jax.random.split(key)
    return {"wx": _he(k1, (din, 4 * dh), din), "wh": _he(k2, (dh, 4 * dh), dh),
            "b": jnp.zeros((4 * dh,))}


def lstm_apply(p, xs):
    """xs: (B, T, D) -> final hidden (B, H)."""
    dh = p["wh"].shape[0]
    B = xs.shape[0]

    def step(carry, x):
        h, c = carry
        z = x @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((B, dh)), jnp.zeros((B, dh)))
    (h, _), _ = lax.scan(step, init, jnp.swapaxes(xs, 0, 1))
    return h


def gru_init(key, din, dh):
    k1, k2 = jax.random.split(key)
    return {"wx": _he(k1, (din, 3 * dh), din), "wh": _he(k2, (dh, 3 * dh), dh),
            "b": jnp.zeros((3 * dh,))}


def gru_apply(p, xs):
    dh = p["wh"].shape[0]
    B = xs.shape[0]

    def step(h, x):
        zx = x @ p["wx"] + p["b"]
        zh = h @ p["wh"]
        r = jax.nn.sigmoid(zx[..., :dh] + zh[..., :dh])
        u = jax.nn.sigmoid(zx[..., dh:2 * dh] + zh[..., dh:2 * dh])
        n = jnp.tanh(zx[..., 2 * dh:] + r * zh[..., 2 * dh:])
        h = (1 - u) * n + u * h
        return h, None

    h, _ = lax.scan(step, jnp.zeros((B, dh)), jnp.swapaxes(xs, 0, 1))
    return h
