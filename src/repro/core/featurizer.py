"""Input featurizers (paper §3.1 IFE, Table 5; WACO baseline).

``cognate`` — 12 conv layers in 4 blocks of 3, channels 32→64→128→256,
max-pool after each block, multi-scale taps (global-pooled features of every
block concatenated) feeding a 128-d matrix embedding. This is the TPU-native
dense-CNN adaptation of the paper's submanifold sparse CNN (DESIGN.md §4).

``waco`` — WACO's original macro-shape: 14 conv layers at a fixed 32
channels, single final tap. Used by the WACO+FA / WACO+FM baselines and the
over-parameterization comparison.

``ch_scale`` scales channel widths for the container-scale benchmark runs
(disclosed next to every reported number).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import nn

MATRIX_EMBED_DIM = 128


def _c(v, scale):
    return max(8, int(v * scale))


def _block_specs(in_ch, ch_scale):
    """Exactly Table 5: 4 blocks x 3 convs, pool after each block."""
    c32, c64, c128, c256 = (_c(32, ch_scale), _c(64, ch_scale),
                            _c(128, ch_scale), _c(256, ch_scale))
    return [
        [(in_ch, c32, 5), (c32, c32, 3), (c32, c64, 3)],
        [(c64, c64, 3), (c64, c64, 3), (c64, c128, 3)],
        [(c128, c128, 3), (c128, c128, 3), (c128, c256, 3)],
        [(c256, c256, 3), (c256, c256, 3), (c256, c256, 3)],
    ]


def cognate_featurizer_init(key, in_ch: int = 4, ch_scale: float = 1.0):
    specs = _block_specs(in_ch, ch_scale)
    keys = jax.random.split(key, 13)
    p = {"blocks": []}
    ki = 0
    for block in specs:
        layers = []
        for cin, cout, ksize in block:
            layers.append(nn.conv_init(keys[ki], cin, cout, ksize)); ki += 1
        p["blocks"].append(layers)
    tap_dim = sum(block[-1][1] for block in specs)  # multi-scale taps
    p["proj"] = nn.dense_init(keys[ki], tap_dim, MATRIX_EMBED_DIM)
    return p


def cognate_featurizer_apply(p, pyramid):
    """pyramid: (B, C, R, R) -> (B, 128)."""
    x = pyramid
    taps = []
    for layers in p["blocks"]:
        for conv_p in layers:
            x = jax.nn.relu(nn.conv(conv_p, x))
        x = nn.max_pool(x, 2)
        taps.append(nn.global_avg_pool(x))
    feat = jnp.concatenate(taps, axis=-1)
    return nn.dense(p["proj"], feat)


def waco_featurizer_init(key, in_ch: int = 4, ch_scale: float = 1.0):
    c = _c(32, ch_scale)
    keys = jax.random.split(key, 15)
    convs = [nn.conv_init(keys[0], in_ch, c, 5)]
    convs += [nn.conv_init(keys[i], c, c, 3) for i in range(1, 14)]
    return {"convs": convs, "proj": nn.dense_init(keys[14], c, MATRIX_EMBED_DIM)}


def waco_featurizer_apply(p, pyramid):
    x = pyramid
    for i, conv_p in enumerate(p["convs"]):
        x = jax.nn.relu(nn.conv(conv_p, x))
        # pool every ~3rd layer to keep spatial cost comparable
        if i in (2, 5, 8, 11):
            x = nn.max_pool(x, 2)
    return nn.dense(p["proj"], nn.global_avg_pool(x))


FEATURIZERS = {
    "cognate": (cognate_featurizer_init, cognate_featurizer_apply),
    "waco": (waco_featurizer_init, waco_featurizer_apply),
}
