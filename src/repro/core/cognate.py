"""The assembled COGNATE cost model (paper Fig. 3(b)) and WACO baselines.

Score = P( IFE(pyramid) || FM(homog) || LE(het) )  — predicted *rank score*
(higher = slower), trained with pairwise margin ranking loss.

Model variants (selected by ``CostModelConfig``):
  * cognate            — full model (featurizer=cognate, mapper, latent=ae)
  * waco_fa            — WacoNet + feature augmentation (latent=fa, no mapper;
                         raw het features fill the config path)
  * waco_fm            — WacoNet + feature mapping (mapper only, latent=none)
  * ablations          — any component zeroed out (paper Fig. 7)
  * predictor variants — mlp | lstm | gru | tf (paper Fig. 8)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core.featurizer import FEATURIZERS, MATRIX_EMBED_DIM
from repro.core.latent import LATENT_DIM
from repro.hw.mapping import UNIFIED_DIM

CONFIG_EMBED_DIM = 64   # paper Table 6


@dataclasses.dataclass(frozen=True)
class CostModelConfig:
    featurizer: str = "cognate"       # cognate | waco
    use_featurizer: bool = True       # Fig. 7: -IFE
    use_mapper: bool = True           # Fig. 7: -FM
    use_latent: bool = True           # Fig. 7: -LE
    latent_dim: int = LATENT_DIM
    predictor: str = "mlp"            # mlp | lstm | gru | tf
    ch_scale: float = 1.0
    in_ch: int = 4

    @property
    def trunk_dim(self) -> int:
        return MATRIX_EMBED_DIM + CONFIG_EMBED_DIM + self.latent_dim


def init_cost_model(key, cfg: CostModelConfig):
    kf, km, kp, kt = jax.random.split(key, 4)
    feat_init, _ = FEATURIZERS[cfg.featurizer]
    p = {"featurizer": feat_init(kf, in_ch=cfg.in_ch, ch_scale=cfg.ch_scale)}
    p["mapper"] = nn.mlp_init(km, [UNIFIED_DIM, 64, CONFIG_EMBED_DIM])
    # predictor trunk (Table 6): concat 256 -> 192 -> 128 -> 64 -> 1
    if cfg.predictor == "mlp":
        p["predictor"] = nn.mlp_init(kp, [cfg.trunk_dim, 192, 128, 64, 1])
    elif cfg.predictor in ("lstm", "gru"):
        init = nn.lstm_init if cfg.predictor == "lstm" else nn.gru_init
        p["predictor"] = {"cell": init(kp, 64, 128),
                          "head": nn.mlp_init(kt, [128, 64, 1])}
    elif cfg.predictor == "tf":
        k1, k2, k3, k4 = jax.random.split(kp, 4)
        dm = 64
        p["predictor"] = {
            "qkv": nn.dense_init(k1, dm, 3 * dm),
            "out": nn.dense_init(k2, dm, dm),
            "ln1": nn.layernorm_init(dm), "ln2": nn.layernorm_init(dm),
            "ff": nn.mlp_init(k3, [dm, 128, dm]),
            "head": nn.mlp_init(k4, [dm, 64, 1]),
        }
    else:
        raise ValueError(cfg.predictor)
    return p


def _tokens(x, dm=64):
    """Split the trunk vector into dm-wide tokens for seq predictors."""
    B, D = x.shape
    pad = (-D) % dm
    x = jnp.pad(x, ((0, 0), (0, pad)))
    return x.reshape(B, (D + pad) // dm, dm)


def _predict(p, cfg: CostModelConfig, trunk):
    if cfg.predictor == "mlp":
        return nn.mlp(p["predictor"], trunk)[..., 0]
    if cfg.predictor in ("lstm", "gru"):
        apply = nn.lstm_apply if cfg.predictor == "lstm" else nn.gru_apply
        h = apply(p["predictor"]["cell"], _tokens(trunk))
        return nn.mlp(p["predictor"]["head"], h)[..., 0]
    # single-block transformer encoder over trunk tokens
    pp = p["predictor"]
    t = _tokens(trunk)
    x = nn.layernorm(pp["ln1"], t)
    qkv = nn.dense(pp["qkv"], x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    att = jax.nn.softmax(q @ jnp.swapaxes(k, 1, 2) / jnp.sqrt(q.shape[-1]), -1)
    t = t + nn.dense(pp["out"], att @ v)
    t = t + nn.mlp(pp["ff"], nn.layernorm(pp["ln2"], t), final_act=False)
    return nn.mlp(pp["head"], t.mean(axis=1))[..., 0]


def matrix_embedding(p, cfg: CostModelConfig, pyramid):
    """(B, C, R, R) -> (B, 128). Computed once per matrix, reused per config."""
    if not cfg.use_featurizer:
        return jnp.zeros((pyramid.shape[0], MATRIX_EMBED_DIM))
    _, feat_apply = FEATURIZERS[cfg.featurizer]
    return feat_apply(p["featurizer"], pyramid)


def score_configs(p, cfg: CostModelConfig, s_m, homog, z):
    """s_m: (B, 128); homog: (B, G, 53); z: (B, G, L) -> scores (B, G)."""
    B, G, _ = homog.shape
    if cfg.use_mapper:
        pj = nn.mlp(p["mapper"], homog.reshape(B * G, -1)).reshape(B, G, -1)
    else:
        pj = jnp.zeros((B, G, CONFIG_EMBED_DIM))
    if not cfg.use_latent:
        z = jnp.zeros((B, G, cfg.latent_dim))
    sm = jnp.broadcast_to(s_m[:, None, :], (B, G, s_m.shape[-1]))
    trunk = jnp.concatenate([sm, pj, z], axis=-1).reshape(B * G, -1)
    return _predict(p, cfg, trunk).reshape(B, G)


def config_first_layer(p, cfg: CostModelConfig, homog, z):
    """Config-side contribution to the MLP predictor's first layer.

    The trunk is ``concat([s_m, pj, z])``, so the first dense layer splits
    algebraically into a matrix part (``s_m @ W[:128]``) and a config part
    (``concat([pj, z]) @ W[128:] + b``).  The config part is a pure function
    of the config space and ``n_cols`` — serving caches it per shape and
    shares it across every matrix in every batch.  MLP predictor only.

    homog: (B, G, 53); z: (B, G, L) -> (B, G, H0).
    """
    B, G, _ = homog.shape
    if cfg.use_mapper:
        pj = nn.mlp(p["mapper"], homog.reshape(B * G, -1)).reshape(B, G, -1)
    else:
        pj = jnp.zeros((B, G, CONFIG_EMBED_DIM))
    if not cfg.use_latent:
        z = jnp.zeros((B, G, cfg.latent_dim))
    first = p["predictor"][0]
    return jnp.concatenate([pj, z], axis=-1) @ first["w"][MATRIX_EMBED_DIM:] \
        + first["b"]


def score_configs_from_parts(p, cfg: CostModelConfig, s_m, cfg_first):
    """``score_configs`` with the config-side first-layer contribution
    precomputed (``config_first_layer``).  Same math up to floating-point
    reassociation; skips the per-(matrix, config) mapper and most of the
    widest dense layer.  s_m: (B, 128); cfg_first: (B, G, H0), or (G, H0)
    broadcast across the batch when every matrix shares n_cols -> (B, G)."""
    first = p["predictor"][0]
    h = jax.nn.relu(
        (s_m @ first["w"][:MATRIX_EMBED_DIM])[:, None, :] + cfg_first)
    B, G, H = h.shape
    return nn.mlp(p["predictor"][1:], h.reshape(B * G, H))[..., 0] \
        .reshape(B, G)


def score_configs_multi(p, cfg: CostModelConfig, s_m, homogs, zs):
    """Score one batch of matrix embeddings against *several* config spaces
    in a single fused pass — the mechanism behind cost-model-guided backend
    routing (one featurization feeds every candidate backend's space).

    The trunk treats configs as an opaque G axis, so distinct spaces simply
    concatenate along it: ``homogs``/``zs`` are per-space ``(G_i, 53)`` /
    ``(G_i, L)`` arrays, scored as one ``(B, sum(G_i))`` dispatch and split
    back per space.  Returns a list of ``(B, G_i)`` score arrays aligned
    with the inputs.
    """
    sizes = [h.shape[0] for h in homogs]
    B = s_m.shape[0]
    hom = jnp.broadcast_to(jnp.concatenate([jnp.asarray(h) for h in homogs],
                                           axis=0)[None],
                           (B, sum(sizes), homogs[0].shape[-1]))
    z = jnp.broadcast_to(jnp.concatenate([jnp.asarray(a) for a in zs],
                                         axis=0)[None],
                         (B, sum(sizes), zs[0].shape[-1]))
    scores = score_configs(p, cfg, s_m, hom, z)
    out, off = [], 0
    for g in sizes:
        out.append(scores[:, off:off + g])
        off += g
    return out


def apply_cost_model(p, cfg: CostModelConfig, pyramid, homog, z):
    """End-to-end scoring: pyramid (B,C,R,R), homog (B,G,53), z (B,G,L)."""
    return score_configs(p, cfg, matrix_embedding(p, cfg, pyramid), homog, z)
