"""End-to-end training driver.

Runs real steps on the locally available devices (CPU here; the same code
path jits onto a TPU slice — the mesh and shardings are the only knobs).
Demonstrates the full production loop: deterministic step-keyed synthetic
data sharding (restart-safe), jit with explicit shardings, activation pins,
rolling atomic checkpoints, elastic restore, and failure-recovery semantics
(see repro/checkpoint/manager.py).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 20 --batch 4 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_by_name, settings
from repro.optim import AdamWConfig
from repro.parallel.sharding import (batch_shardings, param_shardings,
                                     tree_shardings)
from repro.train.steps import TrainStepConfig, init_optimizer, make_train_step


def synthetic_batch(model, shape: ShapeConfig, step: int, seed: int = 0):
    """Deterministic batch keyed by (seed, step): any host can regenerate any
    step's data after an elastic restart — no data-loader state to recover."""
    specs = model.input_specs(shape)
    rng = np.random.default_rng(hash((seed, step)) & 0x7FFFFFFF)
    vocab = model.arch.vocab
    # Zipf-distributed next-token data: non-uniform unigram + bigram
    # structure, so the loss has headroom below the uniform entropy ln(V)
    probs = 1.0 / np.arange(1, vocab + 1) ** 1.2
    probs /= probs.sum()
    batch = {}
    for k, v in specs.items():
        if v.dtype != jnp.int32:
            batch[k] = jnp.asarray(rng.normal(size=v.shape), v.dtype)
    tok_spec = specs["tokens"]
    seq = rng.choice(vocab, size=tok_spec.shape, p=probs)
    seq[..., 1::2] = (seq[..., 0::2] * 7 + 13) % vocab   # learnable bigrams
    batch["tokens"] = jnp.asarray(seq, jnp.int32)
    if "targets" in specs:
        tgt = np.roll(seq, -1, axis=-1)
        tgt[..., -1] = 0
        batch["targets"] = jnp.asarray(tgt, jnp.int32)
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    arch, model = build_by_name(args.arch, reduced=args.reduced)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_host_mesh(model_axis=args.model_axis)
    cfg = TrainStepConfig(optimizer=AdamWConfig(lr=args.lr, weight_decay=0.1),
                          remat=args.remat, accum_steps=args.accum,
                          total_steps=args.steps)
    train_step = make_train_step(model, cfg)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_optimizer(params, cfg)
    ps = param_shardings(mesh, params, arch)
    os_ = tree_shardings(mesh, opt_state, n_experts=arch.n_experts)
    bs = batch_shardings(mesh, model.input_specs(shape))
    params = jax.device_put(params, ps)
    opt_state = jax.device_put(opt_state, os_)

    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        state = mgr.restore({"params": params, "opt": opt_state},
                            shardings={"params": ps, "opt": os_})
        params, opt_state = state["params"], state["opt"]
        start = mgr.latest_step()
        print(f"resumed from step {start}")

    jitted = jax.jit(train_step, in_shardings=(ps, os_, bs),
                     out_shardings=(ps, os_, None))
    with mesh, settings.activation_mesh(mesh):
        for step in range(start, args.steps):
            t0 = time.time()
            batch = synthetic_batch(model, shape, step)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                path = mgr.save(step + 1, {"params": params, "opt": opt_state})
                print(f"  checkpoint -> {path}")
    return loss


if __name__ == "__main__":
    main()
