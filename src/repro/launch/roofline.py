"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:

  compute term    = hlo_flops_per_chip / peak_flops          [s]
  memory term     = hlo_traffic_per_chip / hbm_bw            [s]
  collective term = wire_bytes_per_chip / ici_bw             [s]

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPS (remat/padding/redundancy waste shows up here).

Hardware constants (TPU v5e class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Caveats carried from the estimator (documented, applied consistently):
  * hlo_flops is trip-count-aware and matches analytic expectations within a
    few % (validated on yi-9b).
  * hlo_traffic counts operand+result bytes at fusion boundaries — an upper
    bound (producer/consumer edges counted twice; CPU-backend f32 dots
    inflate activation widths 2x vs a TPU bf16 build). We report raw and a
    /2 bf16-corrected value; bottleneck classification uses the corrected one.
  * collective wire bytes use ring-algorithm estimates with the bf16
    round-trip correction (hloparse._feeds_bf16).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.models import build_by_name
from repro.utils.tree import tree_num_params

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
# hlo_traffic counts materialized RESULT bytes once. HBM traffic = write +
# ~one downstream read = 2x; the CPU backend's f32-widened dots overstate
# widths vs a TPU bf16 build by ~2x. Net factor: 2 * 0.5 = 1.0.
TRAFFIC_FACTOR = 1.0

RESULT_DIR = Path(__file__).resolve().parents[3] / "launch_results" / "dryrun"


def model_flops(arch_name: str, shape_name: str) -> tuple[float, float]:
    """(MODEL_FLOPS global, params N) — 6*N*D train, 2*N*D per token serve."""
    arch, model = build_by_name(arch_name)
    import jax
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_params = tree_num_params(params_s)
    n_active = n_params
    if arch.n_experts:
        # active params: experts contribute k/E of their weight
        e_frac = arch.experts_per_token / arch.n_experts
        # expert weights = moe wi/wg/wo
        expert = 3 * arch.n_layers * arch.n_experts * arch.d_model * arch.d_ff
        n_active = n_params - expert + expert * e_frac
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens, n_params
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens, n_params
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * n_active * tokens, n_params


def load_cell(mesh: str, arch: str, shape: str) -> dict | None:
    p = RESULT_DIR / f"{mesh}__{arch}__{shape}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_row(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    t_compute = r["hlo_flops"] / PEAK_FLOPS
    traffic = r["hlo_traffic_bytes"] * TRAFFIC_FACTOR
    t_memory = traffic / HBM_BW
    wire = sum(v["wire_bytes"] for v in r["collectives"].values())
    t_coll = wire / ICI_BW
    mf, n_params = model_flops(r["arch"], r["shape"])
    mf_per_chip = mf / r["n_devices"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf_per_chip,
        "hlo_flops_per_chip": r["hlo_flops"],
        "useful_ratio": mf_per_chip / max(r["hlo_flops"], 1.0),
        "n_params": n_params,
        "roofline_fraction": (mf_per_chip / PEAK_FLOPS) / max(bound, 1e-12),
        "argument_gib": r["memory"].get("argument_size_in_bytes", 0) / 2**30,
        "temp_gib": r["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    rows = []
    for p in sorted(RESULT_DIR.glob(f"{args.mesh}__*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skip": r["reason"]})
            continue
        row = roofline_row(r)
        if row:
            rows.append(row)
        elif r.get("status") == "error":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skip": "ERROR " + r.get("error", "?")[:60]})
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for row in rows:
        if "skip" in row:
            print(f"{row['arch']:24s} {row['shape']:12s} -- {row['skip']}")
            continue
        print(f"{row['arch']:24s} {row['shape']:12s} "
              f"{row['t_compute_s']:9.3f} {row['t_memory_s']:9.3f} "
              f"{row['t_collective_s']:9.3f} {row['dominant']:>10s} "
              f"{row['useful_ratio']:7.2f} {row['roofline_fraction']*100:6.1f}%")


if __name__ == "__main__":
    main()
