"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers model under-reports flops/bytes/collectives by ~n_layers.
This module walks the HLO call graph (while bodies x known trip_count,
fusions, calls, conditionals) and accumulates:

  * flops            — dot/convolution contractions (2 * result * contract)
  * traffic_bytes    — materialization-boundary traffic: RESULT bytes of
                       top-level fusions, dots, gathers, dynamic-(update-)
                       slices and collectives. Values inside a fusion are
                       free (register/VMEM-resident, the TPU memory model);
                       each materialized result is written once and read
                       ~once downstream, so HBM traffic ~ 2x this number
                       (the x2 is applied by the roofline constants). CPU
                       while-carry copies are excluded (aliased on TPU).
  * collectives      — per-kind counts and per-chip ring wire bytes.

Shapes in optimized HLO are per-device (SPMD), so every number is per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'trip_count"?\s*:\s*\{?"?n"?\s*:\s*"?(\d+)')
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute", "ragged-all-to-all")
TRAFFIC_OPS = set(("fusion", "dot", "convolution", "copy", "gather", "scatter",
                   "dynamic-slice", "dynamic-update-slice", "transpose",
                   "reduce", "concatenate", "slice", "pad", "reverse",
                   "custom-call", "cholesky", "triangular-solve")
                  + COLLECTIVE_KINDS)


def _dims(s: str):
    return [int(d) for d in s.split(",") if d]


def _shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in _dims(m.group(2)):
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _first_shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    return _dims(m.group(2)) if m else []


@dataclass
class Cost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def _slot(self, k):
        return self.collectives.setdefault(
            k, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})

    def add(self, other: "Cost", mult: float = 1.0, traffic: bool = True):
        self.flops += other.flops * mult
        if traffic:
            self.traffic_bytes += other.traffic_bytes * mult
        for k, v in other.collectives.items():
            slot = self._slot(k)
            for f in slot:
                slot[f] += v[f] * mult


@dataclass
class _Op:
    name: str
    op: str
    result_text: str
    operand_names: list
    line: str


class HloModule:
    def __init__(self, text: str, default_group: int = 16):
        self.default_group = default_group
        self.computations: dict[str, list[_Op]] = {}
        self.symtab: dict[str, dict[str, str]] = {}   # comp -> name -> result
        self.entry: str | None = None
        cur = None
        for raw in text.splitlines():
            s = raw.rstrip()
            hm = _HEADER_RE.match(s)
            if hm:
                cur = hm.group(2)
                self.computations[cur] = []
                self.symtab[cur] = {}
                if hm.group(1):
                    self.entry = cur
                continue
            if cur is None or "=" not in s:
                continue
            lm = _LINE_RE.match(s)
            if not lm:
                continue
            name, rhs = lm.group(1), lm.group(2)
            om = _OPNAME_RE.search(rhs)
            if not om:
                continue
            op = om.group(1)
            op_idx = om.start()
            result_text = rhs[:op_idx]
            close = rhs.find(")", om.end())
            operand_text = rhs[om.end():close if close > 0 else len(rhs)]
            operands = _OPERAND_RE.findall(operand_text)
            self.computations[cur].append(
                _Op(name, op, result_text, operands, rhs))
            self.symtab[cur][name] = result_text
        if self.entry is None and self.computations:
            mains = [c for c in self.computations if c.startswith("main")]
            self.entry = mains[0] if mains else list(self.computations)[-1]
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------ helpers
    def _operand_bytes(self, comp: str, op: _Op) -> int:
        tab = self.symtab[comp]
        return sum(_shapes_bytes(tab.get(o, "")) for o in op.operand_names)

    def _param_traffic(self, called: str):
        """Per-parameter-index traffic inside a fused computation.

        A parameter consumed only through dynamic-slice / gather reads just
        the slice (scan-over-layers reads one layer of the stacked params per
        iteration, not the whole stack); anything else reads it fully
        (None = full)."""
        out = {}
        ops = self.computations.get(called, [])
        passthrough = ("bitcast", "copy", "convert", "reshape", "transpose")

        def consumers_of(name, depth=0):
            """Transitive consumers, looking through pass-through ops."""
            direct = [c for c in ops if name in c.operand_names]
            res = []
            for c in direct:
                if c.op in passthrough and depth < 4:
                    res.extend(consumers_of(c.name, depth + 1))
                else:
                    res.append((c, name))
            return res

        for o in ops:
            if o.op != "parameter":
                continue
            m = re.search(r"parameter\((\d+)\)", o.line)
            if not m:
                continue
            idx = int(m.group(1))
            cons = consumers_of(o.name)
            def _sliced(c, via):
                if c.op in ("dynamic-slice", "gather"):
                    return True
                return (c.op == "dynamic-update-slice" and c.operand_names
                        and c.operand_names[0] == via)
            if cons and all(_sliced(c, via) for c, via in cons):
                b = 0
                for c, _ in cons:
                    if c.op == "dynamic-update-slice":
                        # in-place update: writes only the update region
                        upd = c.operand_names[1] if len(c.operand_names) > 1 else None
                        b += _shapes_bytes(self.symtab[called].get(upd, ""))
                    else:
                        b += _shapes_bytes(c.result_text)
                out[idx] = b
            else:
                out[idx] = None
        return out

    def _fusion_traffic(self, comp: str, op: _Op, called: str | None) -> int:
        """Boundary traffic of a fusion/call op with slice-aware operands."""
        total = _shapes_bytes(op.result_text)
        tab = self.symtab[comp]
        ptraf = self._param_traffic(called) if called else {}
        for i, name in enumerate(op.operand_names):
            full = _shapes_bytes(tab.get(name, ""))
            sliced = ptraf.get(i, None)
            total += full if sliced is None else min(sliced, full)
        return total

    def _dot_flops(self, comp: str, op: _Op) -> float:
        result_dims = _first_shape_dims(op.result_text)
        lhs_text = self.symtab[comp].get(
            op.operand_names[0], "") if op.operand_names else ""
        lhs_dims = _first_shape_dims(lhs_text)
        cm = _CONTRACT_RE.search(op.line)
        contract = 1
        if cm and lhs_dims:
            for d in _dims(cm.group(1)):
                if d < len(lhs_dims):
                    contract *= lhs_dims[d]
        n = 1
        for d in result_dims:
            n *= d
        return 2.0 * n * contract

    def _conv_flops(self, comp: str, op: _Op) -> float:
        result_dims = _first_shape_dims(op.result_text)
        if len(op.operand_names) < 2:
            return 0.0
        k_dims = _first_shape_dims(self.symtab[comp].get(op.operand_names[1], ""))
        n = 1
        for d in result_dims:
            n *= d
        k = 1
        for d in k_dims[:-1]:
            k *= d
        return 2.0 * n * k

    def _feeds_bf16(self, comp: str, op: _Op) -> bool:
        """True if every operand of this collective is (a fusion containing)
        a value converted from bf16 — i.e. the reduction is bf16-precise."""
        ops_by_name = {o.name: o for o in self.computations[comp]}
        for name in op.operand_names:
            producer = ops_by_name.get(name)
            if producer is None:
                return False
            if "bf16" in producer.line:
                continue
            cm = _CALL_RE.search(producer.line)
            if cm and cm.group(1) in self.computations:
                body = self.computations[cm.group(1)]
                if any("bf16[" in o.result_text for o in body):
                    continue
            return False
        return True

    def _group_size(self, line: str) -> int:
        m = _GROUP_RE.search(line)
        if m:
            return max(int(m.group(2)), 2)
        m = _GROUP_BRACE_RE.search(line)
        if m:
            return max(len(m.group(1).split(",")), 2)
        return max(self.default_group, 2)

    # ----------------------------------------------------------- analyze
    def analyze(self, comp: str | None = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total
        for op in self.computations.get(comp, []):
            base = op.op[:-6] if op.op.endswith("-start") else op.op
            if op.op.endswith("-done") or op.op in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "iota", "broadcast", "reshape", "compare",
                    "add", "multiply", "subtract", "divide", "select"):
                continue

            if op.op == "while":
                bm = _BODY_RE.search(op.line)
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                if bm and bm.group(1) in self.computations:
                    total.add(self.analyze(bm.group(1)), mult=trip)
                continue
            if op.op == "conditional":
                bm = _BRANCH_RE.search(op.line)
                if bm:
                    costs = [self.analyze(b.strip().lstrip("%"))
                             for b in bm.group(1).split(",")
                             if b.strip().lstrip("%") in self.computations]
                    if costs:
                        total.add(max(costs, key=lambda c: c.flops))
                continue

            if op.op in ("fusion", "call", "map", "reduce", "reduce-window",
                         "scatter", "select-and-scatter", "sort",
                         "async-start"):
                cm = _CALL_RE.search(op.line)
                if cm and cm.group(1) in self.computations:
                    # flops/collectives from inside; traffic at the boundary
                    total.add(self.analyze(cm.group(1)), traffic=False)
            elif op.op == "dot":
                total.flops += self._dot_flops(comp, op)
            elif op.op == "convolution":
                total.flops += self._conv_flops(comp, op)

            if base in COLLECTIVE_KINDS:
                rb = _shapes_bytes(op.result_text)
                if op.op.endswith("-start") and rb:
                    rb //= 2
                # CPU-backend dots emit f32 (bf16 emulated); a TPU build
                # reduces the bf16 value. Detect the bf16 round-trip in the
                # operand fusion and halve — keeps wire bytes TPU-faithful.
                if "f32[" in op.result_text and self._feeds_bf16(comp, op):
                    rb //= 2
                n = self._group_size(op.line)
                if base == "all-reduce":
                    wire = 2.0 * (n - 1) / n * rb
                elif base == "all-gather":
                    wire = (n - 1) / n * rb
                elif base == "reduce-scatter":
                    wire = (n - 1) * rb
                elif base in ("all-to-all", "ragged-all-to-all"):
                    wire = (n - 1) / n * rb
                else:
                    wire = float(rb)
                slot = total._slot(base)
                slot["count"] += 1
                slot["bytes"] += float(rb)
                slot["wire_bytes"] += wire

            if base in TRAFFIC_OPS and op.op != "copy":
                # count RESULT bytes only: each materialized value is written
                # once and (roughly) read once downstream, so total HBM
                # traffic ~ 2 x sum(results) — the x2 lives in the roofline
                # constant, avoiding producer/consumer double counting here.
                # copies are CPU-backend while-carry artifacts (aliased away
                # on TPU) and are excluded.
                if op.op == "dynamic-update-slice":
                    upd = op.operand_names[1] if len(op.operand_names) > 1 else None
                    total.traffic_bytes += _shapes_bytes(
                        self.symtab[comp].get(upd, ""))
                else:
                    total.traffic_bytes += _shapes_bytes(op.result_text)
        return total


def analyze_hlo(text: str, default_group: int = 16) -> dict:
    mod = HloModule(text, default_group)
    c = mod.analyze()
    return {"flops": c.flops, "traffic_bytes": c.traffic_bytes,
            "collectives": c.collectives}
