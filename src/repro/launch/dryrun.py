import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the step function (train_step for train shapes,
prefill_step for prefill, serve_step for decode), lowers it with
ShapeDtypeStruct inputs under explicit NamedShardings on the production mesh,
compiles, and extracts:

  * memory_analysis()      — proof the cell fits per-device HBM
  * cost_analysis()        — per-device HLO flops/bytes for the roofline
  * collective inventory   — parsed from the post-SPMD optimized HLO:
                             op counts + per-chip wire bytes (ring estimates)

Results are written incrementally to launch_results/dryrun/<cell>.json so an
interrupted sweep resumes where it stopped. Nothing here allocates real
buffers — the 512 host devices are compile-time placeholders.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import SHAPES, valid_cells
from repro.launch.mesh import make_production_mesh
from repro.models import ARCH_IDS, build_by_name
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     param_shardings, tree_shardings)
from repro.train.steps import (TrainStepConfig, init_optimizer,
                               make_prefill_step, make_serve_step,
                               make_train_step)

RESULT_DIR = Path(__file__).resolve().parents[3] / "launch_results" / "dryrun"

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all typed shapes in an HLO result/tuple prefix."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, default_group: int) -> dict:
    """Per-chip wire-byte estimates per collective kind (ring algorithms).

    HLO shapes after SPMD partitioning are per-device, so the result size of
    each op is the per-chip buffer. Ring estimates per chip:
      all-reduce      2 (n-1)/n * bytes
      all-gather      (n-1)/n * result_bytes
      reduce-scatter  (n-1)/n * operand_bytes  (= result * n -> (n-1)*result)
      all-to-all      (n-1)/n * bytes
      collective-permute  bytes
    """
    out = {k: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
           for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        head = ls.split(" = ", 1)
        if len(head) != 2:
            continue
        rhs = head[1]
        kind, prefix, is_start = None, "", False
        for k in COLLECTIVES:
            i = rhs.find(" " + k + "(")
            i_start = rhs.find(" " + k + "-start(")
            if i >= 0:
                kind, prefix = k, rhs[:i]
                break
            if i_start >= 0:       # async pair: count the -start, skip -done
                kind, prefix, is_start = k, rhs[:i_start], True
                break
        if kind is None:
            continue
        result_bytes = _shape_bytes(prefix)
        if is_start and result_bytes:
            result_bytes //= 2     # start result tuple = (operand, result)
        n = max(_group_size(ls, default_group), 2)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * result_bytes
        elif kind == "all-gather":
            wire = (n - 1) / n * result_bytes
        elif kind == "reduce-scatter":
            wire = (n - 1) * result_bytes
        elif kind == "all-to-all":
            wire = (n - 1) / n * result_bytes
        else:
            wire = float(result_bytes)
        out[kind]["count"] += 1
        out[kind]["bytes"] += float(result_bytes)
        out[kind]["wire_bytes"] += wire
    return out


def build_step(arch_name: str, shape_name: str, expert_split: int = 1):
    """Returns (step_fn, example_args (ShapeDtypeStructs), in_shardings,
    out_shardings_builder, meta)."""
    arch, model = build_by_name(arch_name)
    if expert_split > 1 and arch.n_experts:
        import dataclasses
        from repro.models import build_model
        arch = dataclasses.replace(arch, moe_expert_split=expert_split)
        model = build_model(arch)
    shape = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(model.init, key)

    def shardings(mesh, serving=False):
        return param_shardings(mesh, params_s, arch, serving=serving)

    if shape.kind == "train":
        cfg = TrainStepConfig(remat=True)
        step = make_train_step(model, cfg)
        opt_s = jax.eval_shape(lambda p: init_optimizer(p, cfg), params_s)
        batch_s = model.input_specs(shape)

        def make(mesh):
            ps = shardings(mesh)
            os_ = tree_shardings(mesh, opt_s, n_experts=arch.n_experts)
            bs = batch_shardings(mesh, batch_s)
            return (step, (params_s, opt_s, batch_s), (ps, os_, bs),
                    (ps, os_, None))
        return make, {"arch": arch, "model": model, "kind": "train"}

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        batch_s = model.input_specs(shape)

        def make(mesh):
            ps = shardings(mesh)
            bs = batch_shardings(mesh, batch_s)
            return step, (params_s, batch_s), (ps, bs), None
        return make, {"arch": arch, "model": model, "kind": "prefill"}

    # decode: one new token against a seq_len-deep cache
    step = make_serve_step(model)
    B = shape.global_batch
    cache_s = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    tok_s = jax.ShapeDtypeStruct((B,), np.int32)

    def make(mesh):
        ps = shardings(mesh, serving=True)
        cs = cache_shardings(mesh, cache_s, B)
        ts = batch_shardings(mesh, {"tokens": tok_s})["tokens"]
        return step, (params_s, cache_s, tok_s), (ps, cs, ts), (None, cs)
    return make, {"arch": arch, "model": model, "kind": "decode"}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             force: bool = False, optimized: bool = False) -> dict:
    """optimized=True enables the EXPERIMENTS.md §Perf layout knobs
    (ATTN_GROUP_PAD + moe_expert_split) and writes *__opt.json artifacts —
    machine evidence for the hillclimb numbers, kept separate from the
    paper-faithful baseline sweep."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = "__opt" if optimized else ""
    out_path = RESULT_DIR / f"{mesh_name}__{arch_name}__{shape_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    RESULT_DIR.mkdir(parents=True, exist_ok=True)

    arch = build_by_name(arch_name)[0]
    if shape_name == "long_500k" and not arch.subquadratic:
        result = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped", "reason": "full-attn-quadratic"}
        out_path.write_text(json.dumps(result, indent=1))
        return result

    t0 = time.time()
    result = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
              "optimized": optimized}
    try:
        from repro.models import settings
        if optimized:
            settings.ATTN_GROUP_PAD = True
        mesh = make_production_mesh(multi_pod=multi_pod)
        make, meta = build_step(arch_name, shape_name,
                                expert_split=2 if optimized else 1)
        step, args, in_sh, out_sh = make(mesh)
        with mesh, settings.activation_mesh(mesh):
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_d = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if mem is not None and hasattr(mem, attr):
                mem_d[attr] = int(getattr(mem, attr))
        cost = compiled.cost_analysis() or {}
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and (
                      "flops" in k or "bytes" in k or k in ("transcendentals",))}

        hlo = compiled.as_text()
        default_group = 16  # model-axis size (most collectives are TP)
        from repro.launch.hloparse import analyze_hlo
        analyzed = analyze_hlo(hlo, default_group)
        n_devices = int(np.prod(list(mesh.shape.values())))

        # persist the optimized HLO so estimators can be improved without
        # recompiling (gzip ~10:1)
        import gzip
        hlo_dir = RESULT_DIR.parent / "hlo"
        hlo_dir.mkdir(parents=True, exist_ok=True)
        with gzip.open(hlo_dir / (out_path.stem + ".hlo.gz"), "wt") as f:
            f.write(hlo)

        result.update({
            "status": "ok",
            "kind": meta["kind"],
            "n_devices": n_devices,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": mem_d,
            "cost": cost_d,                      # raw XLA (loop bodies x1)
            "hlo_flops": analyzed["flops"],      # trip-count-aware, per chip
            "hlo_traffic_bytes": analyzed["traffic_bytes"],
            "collectives": analyzed["collectives"],
            "hlo_bytes": len(hlo),
        })
    except Exception as e:  # record failures — they are bugs to fix
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
    result["wall_s"] = round(time.time() - t0, 1)
    out_path.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="enable §Perf layout knobs; writes *__opt.json")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        arch = build_by_name(a)[0]
        shapes = valid_cells(arch) + (
            ["long_500k"] if not arch.subquadratic else [])
        if args.shape:
            shapes = [args.shape]
        for s in shapes:
            cells.append((a, s))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    for mp in meshes:
        for a, s in cells:
            r = run_cell(a, s, mp, force=args.force,
                         optimized=args.optimized)
            status = r["status"]
            extra = ""
            if status == "ok":
                flops = r["cost"].get("flops", 0)
                extra = (f"compile={r.get('compile_s', 0):.0f}s "
                         f"flops/dev={flops:.3e} "
                         f"args/dev={r['memory'].get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
            elif status == "error":
                extra = r["error"][:120]
            elif status == "skipped":
                extra = r["reason"]
            print(f"[{'2x16x16' if mp else '16x16'}] {a} x {s}: {status} {extra}",
                  flush=True)


if __name__ == "__main__":
    main()
