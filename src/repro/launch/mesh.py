"""Production meshes (the multi-pod dry-run targets).

Defined as functions, never module-level constants, so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the actually-available local devices (used by the
    CPU examples/tests; on a real slice this is the per-host debug mesh)."""
    n = len(jax.devices())
    if not 1 <= model_axis <= n:
        raise ValueError(
            f"model_axis={model_axis} is outside [1, {n}]: the host mesh "
            f"has only len(jax.devices())={n} devices, so the data axis "
            f"would have zero extent")
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
