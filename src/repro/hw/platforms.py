"""Analytical runtime models for CPU / SPADE / GPU / TPU-Pallas.

These replace the paper's three label sources (real Xeon+TACO runs, the SPADE
cycle simulator, real A100+SparseTIR runs) — see DESIGN.md §2.  Each platform
shares one physically-grounded *tile-reuse core* (traffic as a function of
strip-mining tile sizes x the matrix's clustering/skew statistics) and adds
platform-specific terms for its heterogeneous knobs.  The shared core is what
makes CPU→accelerator transfer learnable; the platform terms are what makes
naive transfer (zero-shot, feature augmentation) fail — mirroring the paper's
problem structure.

Runtimes are milliseconds, deterministic per (platform, matrix, config) up to
a seeded log-normal noise term (sigma=3%), vectorized over whole config spaces.
"""
from __future__ import annotations

import numpy as np

from repro.data.features import STAT_NAMES
from repro.hw import configspace as cs
from repro.hw.mapping import I1, J1, K2, J2, phi_spade

__all__ = ["Platform", "CpuPlatform", "SpadePlatform", "GpuPlatform",
           "TpuPallasPlatform", "get_platform", "PLATFORMS", "DENSE_N",
           "DENSE_K"]

DENSE_N = 128   # dense-operand columns for SpMM (paper uses a fixed feature dim)
DENSE_K = 128   # inner dense dim for SDDMM

_SIDX = {n: i for i, n in enumerate(STAT_NAMES)}


def _s(stats, name):
    return float(stats[_SIDX[name]])


def _order_features(order: np.ndarray):
    """Positions of key loops in the 7-slot unified order. order: (n,7)."""
    pos_k2 = np.argmax(order == K2, axis=1)
    pos_i1 = np.argmax(order == I1, axis=1)
    pos_j1 = np.argmax(order == J1, axis=1)
    pos_j2 = np.argmax(order == J2, axis=1)
    k_inner = pos_k2 >= 4                    # dense-col loop innermost-ish
    j_outer = pos_j1 < pos_i1                # contraction panel outer of rows
    j_innermost = pos_j2 == 6                # gather-style innermost
    return k_inner, j_outer, j_innermost, pos_i1


class Platform:
    """Base: shared tile-reuse core with platform constants."""
    name: str
    beta: float          # DCE cost per sample (paper App. A: CPU=1, SPADE=1000)
    peak_flops: float    # flop/s (effective)
    mem_bw: float        # bytes/s
    cache_bytes: float   # per-worker fast-memory capacity
    n_workers: int
    task_overhead: float # seconds per scheduled tile/task
    worker_bw_frac: float = 0.125  # fraction of peak BW one worker can draw
    noise_sigma: float = 0.03

    def __init__(self, space: cs.ConfigSpace):
        self.space = space

    # ---------------------------------------------------------------- core
    def _core(self, stats, op, I, J, K, order, g_mult=1.0):
        """Shared traffic/compute model. All config args are (n,) arrays.

        Returns dict of component times in seconds, each (n,).
        """
        M = 2.0 ** _s(stats, "log_rows")
        Kc = 2.0 ** _s(stats, "log_cols")
        nnz = 2.0 ** _s(stats, "log_nnz")
        row_cv = _s(stats, "row_cv")
        block32 = _s(stats, "block32_fill")
        I = np.minimum(np.maximum(I, 1.0), M)
        J = np.minimum(np.maximum(J, 1.0), Kc)
        dense_inner = DENSE_N if op == "spmm" else DENSE_K
        K = np.minimum(np.maximum(K, 1.0), dense_inner)
        k_inner, j_outer, j_innermost, _ = _order_features(order)

        n_row_tiles = np.ceil(M / I)
        n_panels = np.ceil(Kc / J)
        n_ktiles = np.ceil(dense_inner / K)

        # clustering: mean nnz per touched 32-block; >1 means column reuse
        g = (1.0 + 4.0 * block32) * g_mult

        # distinct contraction columns touched by one (row-tile x panel)
        nnz_tile_panel = nnz * (I / M) * (J / Kc)
        u = J * (1.0 - np.exp(-nnz_tile_panel / np.maximum(J * g, 1e-9)))
        u = np.maximum(u, np.minimum(nnz_tile_panel, 1.0))

        flops = 2.0 * nnz * dense_inner
        if op == "spmm":
            # A: values+indices, one pass (j_outer re-streams row metadata)
            a_pass = np.where(j_outer, 1.0 + 0.3 * (n_panels > 1), 1.0)
            bytes_a = nnz * 8.0 * a_pass
            # B: gathered rows of the dense operand
            bytes_b_tiled = n_row_tiles * n_panels * u * DENSE_N * 4.0
            bytes_b_resident = Kc * DENSE_N * 4.0   # each B row fetched once
            panel_ws = u * K * 4.0 + I * K * 4.0
            fits = panel_ws <= self.cache_bytes
            spill = np.where(fits, 1.0, np.sqrt(panel_ws / self.cache_bytes))
            bytes_b = np.where(j_outer & fits, np.minimum(bytes_b_tiled, bytes_b_resident),
                               bytes_b_tiled) * spill
            # D: streamed once if k kept inner, else revisited per panel
            d_revisit = np.where(k_inner, 1.0, np.minimum(n_panels, 8.0))
            bytes_d = M * DENSE_N * 4.0 * d_revisit
        else:  # sddmm
            # A pattern revisited once per K-chunk of the inner dense dim
            bytes_a = nnz * 8.0 * n_ktiles
            # B rows resident per row tile; streamed once per panel pass
            b_pass = np.where(j_outer, np.minimum(n_panels, 8.0), 1.0)
            bytes_b = M * DENSE_K * 4.0 * b_pass
            bytes_c = n_row_tiles * n_panels * u * DENSE_K * 4.0
            panel_ws = u * K * 4.0 + I * K * 4.0
            fits = panel_ws <= self.cache_bytes
            spill = np.where(fits, 1.0, np.sqrt(panel_ws / self.cache_bytes))
            bytes_b = bytes_b + bytes_c * spill
            bytes_d = nnz * 8.0

        # k-outer orders re-stream the sparse operand once per dense-col tile
        pos_k1 = np.argmax(order == 4, axis=1)  # K1 == 4
        k_outer = pos_k1 == 0
        bytes_a = bytes_a * np.where(k_outer, n_ktiles, 1.0)

        bytes_total = bytes_a + bytes_b + bytes_d

        # utilization: fewer tasks than workers leaves compute units idle, and
        # a single worker cannot saturate aggregate memory bandwidth either
        n_tasks = np.maximum(n_row_tiles * np.where(j_outer, n_panels, 1.0), 1.0)
        util = np.minimum(n_tasks / self.n_workers, 1.0)
        bw_frac = np.minimum(n_tasks * self.worker_bw_frac, 1.0)
        t_compute = flops / (self.peak_flops * util)
        t_mem = bytes_total / (self.mem_bw * bw_frac)

        # load imbalance across workers. Heavy rows cluster in real matrices
        # (power-law/arrow), so block aggregation attenuates variance slower
        # than iid (exponent 0.3, not 0.5).
        rows_per_tile = np.maximum(I, 1.0)
        cv_tile = row_cv / rows_per_tile ** 0.3
        per_worker = np.maximum(n_tasks / self.n_workers, 1.0)
        imb = 1.0 + cv_tile / np.sqrt(per_worker) * np.sqrt(
            2.0 * np.log(max(self.n_workers, 2)))
        t_sched = n_tasks * self.task_overhead / self.n_workers

        return dict(t_compute=t_compute, t_mem=t_mem, imb=imb, t_sched=t_sched,
                    flops=flops, bytes_total=bytes_total, n_tasks=n_tasks,
                    u=u, n_panels=n_panels, k_inner=k_inner, j_outer=j_outer,
                    nnz=nnz, M=M, Kc=Kc, row_cv=row_cv)

    def _finish(self, comp, matrix_key, noise):
        t = (np.maximum(comp["t_compute"], comp["t_mem"]) * comp["imb"]
             + comp["t_sched"] + comp.get("t_extra", 0.0))
        t_ms = t * 1e3
        if noise:
            rng = np.random.default_rng(
                (hash((self.name, int(matrix_key))) & 0x7FFFFFFF))
            t_ms = t_ms * np.exp(rng.normal(0.0, self.noise_sigma, t_ms.shape))
        return t_ms

    def runtime(self, stats, op: str, matrix_key: int = 0,
                n_cols: int | None = None, noise: bool = True) -> np.ndarray:
        raise NotImplementedError

    def speedup_stats(self, runtimes: np.ndarray):
        """(best, default, optimal-speedup) over a (n_configs,) runtime vector."""
        d = runtimes[self.space.default_index]
        return float(runtimes.min()), float(d), float(d / runtimes.min())


# ------------------------------------------------------------------- CPU

class CpuPlatform(Platform):
    """Intel Xeon Gold 6348-class CPU running TACO-generated SpMM/SDDMM."""
    name = "cpu"
    beta = 1.0
    peak_flops = 1.6e12
    mem_bw = 1.9e11
    cache_bytes = 2.5e6      # per-core L2 + L3 share
    n_workers = 28
    task_overhead = 2.0e-6

    def runtime(self, stats, op, matrix_key=0, n_cols=None, noise=True):
        sp: cs.CpuSpace = self.space
        n_cols = int(n_cols or 2.0 ** _s(stats, "log_cols"))
        I, J, K, order, flag = sp.unified(n_cols)
        fmt = sp.params["format_reorder"].astype(np.float64)
        comp = self._core(stats, op, I, J, K, order)
        # format reordering: better locality (apply to memory term), amortized cost
        comp["t_mem"] = comp["t_mem"] * np.where(fmt == 1, 1.0 / (0.6 + 0.4 /
                        (1.0 + _s(stats, "seg_locality") * 4.0)), 1.0)
        comp["t_extra"] = fmt * comp["nnz"] * 16.0 / self.mem_bw * 0.25
        # SIMD efficiency: gather-style innermost j halves vector width
        k_inner, _, j_innermost, _ = _order_features(order)
        simd = np.where(j_innermost, 2.8, np.where(k_inner, 1.0, 1.6))
        comp["t_compute"] = comp["t_compute"] * simd
        return self._finish(comp, matrix_key, noise)


# ------------------------------------------------------------------ SPADE

class SpadePlatform(Platform):
    """SPADE (ISCA'23): 32 tile-based PEs @ 0.8 GHz, software-managed buffers."""
    name = "spade"
    beta = 1000.0            # paper App. A.3 sets beta_SPADE = 1000
    peak_flops = 4.1e11      # 32 PEs x 8-wide MAC x 0.8 GHz x 2 flop
    mem_bw = 2.56e11
    cache_bytes = 1.3e5      # per-PE scratch buffer
    n_workers = 32
    task_overhead = 1.0e-6

    def runtime(self, stats, op, matrix_key=0, n_cols=None, noise=True):
        sp: cs.SpadeSpace = self.space
        n_cols = int(n_cols or 2.0 ** _s(stats, "log_cols"))
        I, J, K, order = phi_spade(
            sp.params["row_panels"], sp.params["col_panels"], sp.params["split"],
            sp.params["barrier"], n_cols)
        barrier = sp.params["barrier"].astype(np.float64)
        bypass = sp.params["bypass"].astype(np.float64)
        reorder = sp.params["reorder"].astype(np.float64)

        comp = self._core(stats, op, I, J, K, order)
        row_cv = comp["row_cv"]

        # matrix reordering: collapses row skew; one-time cost amortized
        cv_eff = np.where(reorder == 1, row_cv * 0.25, row_cv)
        rows_per_tile = np.maximum(I, 1.0)
        per_worker = np.maximum(comp["n_tasks"] / self.n_workers, 1.0)
        comp["imb"] = 1.0 + (cv_eff / rows_per_tile ** 0.3) / np.sqrt(per_worker) \
            * np.sqrt(2.0 * np.log(self.n_workers))
        comp["t_extra"] = reorder * comp["nnz"] * 40.0 / self.mem_bw

        # barrier: wave-synchronous execution shares the dense panel across
        # PEs (less traffic) but serializes waves (sync overhead). The traffic
        # win is largest for *scattered* patterns, whose tiles would otherwise
        # re-fetch the panel independently; clustered patterns already reuse.
        g = 1.0 + 4.0 * _s(stats, "block32_fill")
        wave_share = np.clip(0.42 + 0.11 * (g - 1.0), 0.42, 0.9)
        n_waves = np.maximum(comp["n_tasks"] / self.n_workers, 1.0)
        comp["t_mem"] = comp["t_mem"] * np.where(barrier == 1, wave_share, 1.0)
        comp["t_extra"] = comp["t_extra"] + barrier * n_waves * 4.0e-6
        # barrier makes imbalance per-wave (worse for skewed matrices)
        comp["imb"] = comp["imb"] * (1.0 + barrier * 0.9 * cv_eff /
                                     rows_per_tile ** 0.3)

        # cache bypassing: streamed dense operand frees the scratchpad for the
        # sparse operand — wins when the panel working set overflows, loses
        # reuse when it would have fit
        panel_ws = comp["u"] * np.minimum(K, DENSE_N) * 4.0
        overflow = panel_ws > self.cache_bytes
        comp["t_mem"] = comp["t_mem"] * np.where(
            bypass == 1, np.where(overflow, 0.60, 1.80), 1.0)
        return self._finish(comp, matrix_key, noise)


# -------------------------------------------------------------------- GPU

class GpuPlatform(Platform):
    """NVIDIA A100 running SparseTIR-generated SpMM/SDDMM."""
    name = "gpu"
    beta = 1.0
    peak_flops = 1.95e13
    mem_bw = 1.555e12
    cache_bytes = 1.6e5       # shared memory per SM
    n_workers = 108
    task_overhead = 4.0e-7

    def runtime(self, stats, op, matrix_key=0, n_cols=None, noise=True):
        sp: cs.GpuSpace = self.space
        n_cols = int(n_cols or 2.0 ** _s(stats, "log_cols"))
        I, J, K, order, _ = sp.unified(n_cols)
        binding = sp.params["binding"].astype(np.int64)
        unroll = sp.params["unroll"].astype(np.float64)

        comp = self._core(stats, op, I, J, K, order)
        row_mean = _s(stats, "row_mean")

        # binding: 0=(i->blk,k->thr) coalesced; 1=(i->blk,j->thr) gather but
        # wins for very short rows; 2=2D grid -> more parallelism, more tiles
        coalesce = np.where(binding == 0, 1.0,
                    np.where(binding == 1,
                             np.where(row_mean < 6.0, 0.85, 2.2), 1.15))
        comp["t_mem"] = comp["t_mem"] * coalesce
        p_eff = np.where(binding == 2, self.n_workers * 2.0, self.n_workers)
        per_worker = np.maximum(comp["n_tasks"] / p_eff, 1.0)
        comp["imb"] = 1.0 + comp["row_cv"] / np.sqrt(np.maximum(I, 1.0)) \
            / np.sqrt(per_worker) * 3.0
        # unrolling: fewer branches, but register pressure on big row tiles
        instr = comp["nnz"] * 4.0 / 1.0e12
        spillp = np.where((unroll >= 4) & (I >= 128), 1.25, 1.0)
        comp["t_compute"] = (comp["t_compute"] + instr /
                             (1.0 + 0.35 * np.log2(unroll))) * spillp
        return self._finish(comp, matrix_key, noise)


# ------------------------------------------------------------- TPU/Pallas

class TpuPallasPlatform(Platform):
    """Roofline model of the Pallas BSR kernels in repro/kernels (TPU v5e).

    Unlike the CPU/SPADE/GPU models this mirrors the actual kernel structure:
    the sparse operand is stored as (bm x 128) blocks; compute and DMA scale
    with *touched blocks*, so large bm wastes MXU work on padding for
    scattered patterns but amortizes grid-step overheads for clustered ones —
    the central BSR trade-off the autotuner must learn.
    """
    name = "tpu_pallas"
    beta = 50.0               # interpret-mode label cost >> CPU, << SPADE sim
    peak_flops = 1.97e14      # bf16 MXU
    mem_bw = 8.19e11
    cache_bytes = 6.4e7       # usable VMEM budget
    n_workers = 1
    task_overhead = 3.0e-7    # per grid step (pipelined DMA issue)
    worker_bw_frac = 1.0
    BK = 128                  # fixed block width (lane dimension)

    def _fill(self, stats, bm):
        """Interpolate mean nnz-per-touched-block(bm) from measured fills."""
        f8, f32, f128 = (_s(stats, "block8_fill") * 8.0,
                         _s(stats, "block32_fill") * 32.0,
                         _s(stats, "block128_fill") * 128.0)
        lb = np.log2(np.maximum(bm, 1.0))
        # piecewise-linear in log2 block size over anchors (3, 5, 7)
        lo = f8 + (f32 - f8) * np.clip((lb - 3.0) / 2.0, 0.0, 1.0)
        hi = f32 + (f128 - f32) * np.clip((lb - 5.0) / 2.0, 0.0, 1.0)
        return np.maximum(np.where(lb <= 5.0, lo, hi), 1.0)

    def runtime(self, stats, op, matrix_key=0, n_cols=None, noise=True):
        sp: cs.TpuPallasSpace = self.space
        M = 2.0 ** _s(stats, "log_rows")
        Kc = 2.0 ** _s(stats, "log_cols")
        nnz = 2.0 ** _s(stats, "log_nnz")
        n_cols = int(n_cols or Kc)
        bm = sp.params["bm"].astype(np.float64)
        panel = sp.params["panel"].astype(np.float64).copy()
        panel[panel < 0] = float(n_cols)
        panel = np.minimum(panel, Kc)
        bn = sp.params["bn"].astype(np.float64)
        n_major = sp.params["n_major"].astype(np.float64)
        resident = sp.params["resident"].astype(np.float64)
        N = DENSE_N if op == "spmm" else DENSE_K

        # touched (bm x BK) blocks: occupancy = mean nnz per touched block,
        # interpolated from the measured square-block fill curve at the
        # block's effective (geometric-mean) size. The *shape* of this curve
        # is what distinguishes banded/clustered from scattered patterns and
        # decides whether large blocks pay off.
        eff_size = np.sqrt(bm * self.BK)
        occupancy = np.minimum(self._fill(stats, eff_size), bm * self.BK)
        touched = np.clip(nnz / occupancy, 1.0,
                          np.ceil(M / bm) * np.ceil(Kc / self.BK))
        n_rowblocks = np.ceil(M / bm)
        n_ntiles = np.ceil(N / bn)
        n_panels = np.ceil(Kc / panel)

        flops = touched * bm * self.BK * 2.0 * N        # padded MXU work
        bytes_a = touched * bm * self.BK * 2.0 + touched * 4.0
        if op == "spmm":
            gather_b = touched * self.BK * N * 2.0      # per-block B tiles
            resident_b = Kc * N * 2.0 * np.maximum(
                np.where(n_major == 1, 1.0, 1.0), 1.0)  # stream B once
            fits = (np.minimum(panel, Kc) * bn * 2.0) <= self.cache_bytes
            use_res = (resident == 1) & fits
            bytes_b = np.where(use_res, np.minimum(gather_b, resident_b),
                               gather_b * np.where(n_major == 1, 1.0, 1.25))
            bytes_d = M * N * 2.0 * (2.0 * n_panels - 1.0)
        else:  # sddmm: B rows per row-block resident, C gathered per block
            bytes_b = n_rowblocks * bm * DENSE_K * 2.0 * n_panels
            bytes_c = touched * self.BK * DENSE_K * 2.0
            fits = (np.minimum(panel, Kc) * bn * 2.0) <= self.cache_bytes
            bytes_b = bytes_b + bytes_c * np.where((resident == 1) & fits, 0.6, 1.0)
            bytes_d = touched * bm * self.BK * 2.0      # blocked output
        n_steps = touched * n_ntiles
        comp = dict(
            t_compute=flops / self.peak_flops,
            t_mem=(bytes_a + bytes_b + bytes_d) / self.mem_bw,
            imb=np.ones_like(bm),
            t_sched=n_steps * self.task_overhead,
            flops=flops, bytes_total=bytes_a + bytes_b + bytes_d,
            n_tasks=n_steps, nnz=nnz, M=M, Kc=Kc,
            row_cv=_s(stats, "row_cv"), u=occupancy, n_panels=n_panels,
            k_inner=None, j_outer=None)
        return self._finish(comp, matrix_key, noise)


_FACTORIES = {
    "cpu": lambda: CpuPlatform(cs.cpu_space()),
    "spade": lambda: SpadePlatform(cs.spade_space()),
    "gpu": lambda: GpuPlatform(cs.gpu_space()),
    "tpu_pallas": lambda: TpuPallasPlatform(cs.tpu_pallas_space()),
}
PLATFORMS = sorted(_FACTORIES)
_CACHE: dict[str, Platform] = {}


def get_platform(name: str) -> Platform:
    if name not in _CACHE:
        _CACHE[name] = _FACTORIES[name]()
    return _CACHE[name]
