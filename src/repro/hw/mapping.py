"""Approximate mapping of comparable code optimizations (paper §3.2, App. E).

Every platform's native program configuration is projected into one unified,
CPU-canonical space:

    (I, J, K, omega, flag)

where I/J/K strip-mine the i (rows of A), j (contraction), k (dense columns)
loops and omega is a 7-slot loop order over loop ids

    i1=0, i2=1, j1=2, j2=3, k1=4, k2=5, k3=6.

The 7th loop (k3) comes from pi_a1 (CPU: append k3=1 after k2); the GPU's
native 6-loop nest {i1,i2,j,k1,k2,k3} gets j'=1 inserted after j (pi_a3).

SPADE's phi (verbatim from App. E, which supersedes the transposed statement
in §3.2 — see DESIGN.md §8):

    i_split <- row_panels, j_split <- column_panels, k_split <- split
    omega(b=1) = [k2, k3, j2, i2, i1, j1, k1]
    omega(b=0) = [k2, k3, i2, j2, i1, j1, k1]
"""
from __future__ import annotations

import numpy as np

I1, I2, J1, J2, K1, K2, K3 = range(7)
LOOP_NAMES = ["i1", "i2", "j1", "j2", "k1", "k2", "k3"]

#: unified homogeneous feature dimension (paper Table 6: 53)
UNIFIED_DIM = 3 + 7 * 7 + 1

# --- canonical CPU loop orders (6-loop perms; k3 appended by pi_a1) ---------
CPU_ORDERS_6 = [
    [I1, J1, K1, I2, J2, K2],   # 0: row-tiled ijk (TACO default)
    [I1, J1, K1, J2, I2, K2],   # 1
    [I1, K1, J1, I2, J2, K2],   # 2: k-panel outer of j
    [J1, I1, K1, I2, J2, K2],   # 3: contraction-panel outer (B reuse)
    [J1, I1, K1, J2, I2, K2],   # 4
    [I1, J1, K1, I2, K2, J2],   # 5: j innermost (gather)
    [K1, I1, J1, I2, J2, K2],   # 6: dense-col outer
    [J1, K1, I1, I2, J2, K2],   # 7
]


def pi_a1(order6: list[int]) -> list[int]:
    """CPU 6-loop order -> unified 7-loop order: k3=1 appended right after k2."""
    out = []
    for l in order6:
        out.append(l)
        if l == K2:
            out.append(K3)
    assert len(out) == 7
    return out


CPU_ORDERS = [pi_a1(o) for o in CPU_ORDERS_6]

# --- SPADE: phi -------------------------------------------------------------
SPADE_ORDER_B1 = [K2, K3, J2, I2, I1, J1, K1]
SPADE_ORDER_B0 = [K2, K3, I2, J2, I1, J1, K1]


def phi_spade(row_panels, col_panels, split, barrier, n_cols):
    """SPADE (p_row, p_col, s_split, b) -> (I, J, K, omega). Vectorized.

    col_panels == -1 means NUM_MATRIX_COLS (resolved against the input).
    """
    row_panels = np.asarray(row_panels, np.float64)
    col_panels = np.asarray(col_panels, np.float64).copy()
    col_panels[col_panels < 0] = float(n_cols)
    split = np.asarray(split, np.float64)
    barrier = np.asarray(barrier)
    n = row_panels.shape[0]
    order = np.where(barrier[:, None] == 1,
                     np.asarray(SPADE_ORDER_B1)[None, :],
                     np.asarray(SPADE_ORDER_B0)[None, :]).astype(np.int32)
    assert order.shape == (n, 7)
    return row_panels, col_panels, split, order


# --- GPU: pi_a3 -------------------------------------------------------------
# native nest {i1, i2, j, k1, k2, k3}; j'=1 inserted after j. Unified ids:
# j -> j1, j' -> j2. SparseTIR SpMM canonical schedule iterates
# blockIdx(i1) / j / threads(i2, k) -> [i1, j1, j2, i2, k1, k2, k3].
GPU_ORDER = [I1, J1, J2, I2, K1, K2, K3]


def pi_a3(i_tile, k1, k2, n_cols, dense_k=128):
    """GPU (i-tile, k-splits) -> (I, J, K, omega). J is the full contraction
    (not strip-mined on GPU -> J = NUM_MATRIX_COLS), K = k1*k2 thread tile."""
    i_tile = np.asarray(i_tile, np.float64)
    k1 = np.asarray(k1, np.float64)
    k2 = np.asarray(k2, np.float64)
    n = i_tile.shape[0]
    J = np.full(n, float(n_cols))
    K = np.minimum(k1 * k2, dense_k)
    order = np.tile(np.asarray(GPU_ORDER, np.int32), (n, 1))
    return i_tile, J, K, order


# --- TPU Pallas kernels ------------------------------------------------------
# grid = (row-blocks, n-tiles, panel-steps): bm ~ I, panel width ~ J, bn ~ K.
TPU_ORDER_NMAJOR = [I1, K1, J1, I2, J2, K2, K3]   # n-tile outer (B-panel reuse)
TPU_ORDER_KMAJOR = [I1, J1, K1, I2, J2, K2, K3]   # panel outer (A reuse)


def phi_tpu(bm, panel, bn, n_major, n_cols):
    bm = np.asarray(bm, np.float64)
    panel = np.asarray(panel, np.float64).copy()
    panel[panel < 0] = float(n_cols)
    bn = np.asarray(bn, np.float64)
    n_major = np.asarray(n_major)
    order = np.where(n_major[:, None] == 1,
                     np.asarray(TPU_ORDER_NMAJOR)[None, :],
                     np.asarray(TPU_ORDER_KMAJOR)[None, :]).astype(np.int32)
    return bm, panel, bn, order


# --- unified feature encoding ------------------------------------------------

def encode_unified(I, J, K, order, flag) -> np.ndarray:
    """(n,) I/J/K, (n,7) order ids, (n,) flag -> (n, UNIFIED_DIM) float32."""
    I = np.asarray(I, np.float64)
    J = np.asarray(J, np.float64)
    K = np.asarray(K, np.float64)
    n = I.shape[0]
    feats = np.zeros((n, UNIFIED_DIM), np.float32)
    feats[:, 0] = np.log2(np.maximum(I, 1)) / 13.0
    feats[:, 1] = np.log2(np.maximum(J, 1)) / 20.0
    feats[:, 2] = np.log2(np.maximum(K, 1)) / 9.0
    onehot = np.zeros((n, 7, 7), np.float32)
    rows = np.arange(n)[:, None]
    slots = np.arange(7)[None, :]
    onehot[rows, slots, order] = 1.0
    feats[:, 3:52] = onehot.reshape(n, 49)
    feats[:, 52] = np.asarray(flag, np.float32)
    return feats
