"""Program configuration spaces per hardware platform (paper Table 1, §4.1).

Each space enumerates every valid configuration as parallel numpy arrays and
exposes the two feature views the cost model consumes:

* ``homogeneous(n_cols)``  — the unified 53-d strip-mining/loop-order encoding
  produced by the phi/pi mapping functions (``repro.hw.mapping``); shared
  across platforms (feature reuse).
* ``heterogeneous()``      — per-platform one/multi-hot raw parameters that
  cannot be mapped; consumed by the per-target latent autoencoder.

SPADE space is exactly the paper's 256 configurations:
row_panels {4,32,256,2048} x col_panels {1024,16384,65536,NUM_MATRIX_COLS}
x split {32,256} x barrier x bypass x reorder.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.hw import mapping
from repro.hw.mapping import UNIFIED_DIM, encode_unified

__all__ = ["ConfigSpace", "spade_space", "cpu_space", "gpu_space",
           "tpu_pallas_space", "UNIFIED_DIM"]


def _onehot(values: np.ndarray, choices) -> np.ndarray:
    choices = list(choices)
    out = np.zeros((len(values), len(choices)), np.float32)
    for j, c in enumerate(choices):
        out[:, j] = values == c
    return out


@dataclasses.dataclass
class ConfigSpace:
    platform: str
    params: dict[str, np.ndarray]          # raw parameter columns, each (n,)
    choices: dict[str, list]               # value set per parameter
    default_index: int                     # programming-system default config

    @property
    def n_configs(self) -> int:
        return len(next(iter(self.params.values())))

    def param_matrix(self) -> np.ndarray:
        return np.stack([self.params[k] for k in self.params], axis=1)

    # ---- feature views ----
    def unified(self, n_cols: int):
        """Return (I, J, K, order(n,7), flag) in the unified space."""
        raise NotImplementedError

    def homogeneous(self, n_cols: int) -> np.ndarray:
        I, J, K, order, flag = self.unified(n_cols)
        return encode_unified(I, J, K, order, flag)

    def heterogeneous(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def het_dim(self) -> int:
        return self.heterogeneous().shape[1]


def _product_space(**choices):
    keys = list(choices)
    rows = list(itertools.product(*[choices[k] for k in keys]))
    arr = {k: np.asarray([r[i] for r in rows]) for i, k in enumerate(keys)}
    return keys, arr


# --------------------------------------------------------------------- SPADE

class SpadeSpace(ConfigSpace):
    ROW_PANELS = [4, 32, 256, 2048]
    COL_PANELS = [1024, 16384, 65536, -1]   # -1 == NUM_MATRIX_COLS
    SPLITS = [32, 256]

    def unified(self, n_cols: int):
        p = self.params
        I, J, K, order = mapping.phi_spade(p["row_panels"], p["col_panels"],
                                           p["split"], p["barrier"], n_cols)
        # the mapped-flag slot carries the matrix-reorder bit (format-reorder
        # analogue, the only SPADE knob with a CPU-side counterpart)
        return I, J, K, order, p["reorder"].astype(np.float32)

    def heterogeneous(self) -> np.ndarray:
        p = self.params
        return np.concatenate([
            p["barrier"][:, None].astype(np.float32),
            p["bypass"][:, None].astype(np.float32),
            p["reorder"][:, None].astype(np.float32),
            _onehot(p["row_panels"], self.ROW_PANELS),
            _onehot(p["col_panels"], self.COL_PANELS),
            _onehot(p["split"], self.SPLITS),
        ], axis=1)  # 3 + 4 + 4 + 2 = 13


def spade_space() -> SpadeSpace:
    _, params = _product_space(
        row_panels=SpadeSpace.ROW_PANELS, col_panels=SpadeSpace.COL_PANELS,
        split=SpadeSpace.SPLITS, barrier=[0, 1], bypass=[0, 1], reorder=[0, 1])
    # default: moderate row panel, whole-matrix col panel, no extras
    default = int(np.flatnonzero(
        (params["row_panels"] == 32) & (params["col_panels"] == -1) &
        (params["split"] == 32) & (params["barrier"] == 0) &
        (params["bypass"] == 0) & (params["reorder"] == 0))[0])
    choices = {"row_panels": SpadeSpace.ROW_PANELS,
               "col_panels": SpadeSpace.COL_PANELS,
               "split": SpadeSpace.SPLITS,
               "barrier": [0, 1], "bypass": [0, 1], "reorder": [0, 1]}
    return SpadeSpace("spade", params, choices, default)


# ----------------------------------------------------------------------- CPU

class CpuSpace(ConfigSpace):
    I_TILES = [16, 64, 256, 1024, 4096]
    J_TILES = [16, 64, 256, 1024, 4096]
    K_TILES = [16, 32, 64, 128]

    def unified(self, n_cols: int):
        p = self.params
        order6 = [mapping.CPU_ORDERS_6[i] for i in p["order"]]
        order = np.asarray([mapping.pi_a1(o) for o in order6], np.int32)
        return (p["i_tile"].astype(np.float64),
                np.minimum(p["j_tile"], n_cols).astype(np.float64),
                p["k_tile"].astype(np.float64), order,
                p["format_reorder"].astype(np.float32))

    def heterogeneous(self) -> np.ndarray:
        p = self.params
        return np.concatenate([
            _onehot(p["format_reorder"], [0, 1]),
            _onehot(p["i_tile"], self.I_TILES),
            _onehot(p["j_tile"], self.J_TILES),
            _onehot(p["k_tile"], self.K_TILES),
            _onehot(p["order"], list(range(len(mapping.CPU_ORDERS_6)))),
        ], axis=1)  # 2 + 5 + 5 + 4 + 8 = 24


def cpu_space() -> CpuSpace:
    _, params = _product_space(
        i_tile=CpuSpace.I_TILES, j_tile=CpuSpace.J_TILES, k_tile=CpuSpace.K_TILES,
        order=list(range(len(mapping.CPU_ORDERS_6))), format_reorder=[0, 1])
    default = int(np.flatnonzero(
        (params["i_tile"] == 256) & (params["j_tile"] == 1024) &
        (params["k_tile"] == 32) & (params["order"] == 0) &
        (params["format_reorder"] == 0))[0])
    choices = {"i_tile": CpuSpace.I_TILES, "j_tile": CpuSpace.J_TILES,
               "k_tile": CpuSpace.K_TILES,
               "order": list(range(len(mapping.CPU_ORDERS_6))),
               "format_reorder": [0, 1]}
    return CpuSpace("cpu", params, choices, default)


# ----------------------------------------------------------------------- GPU

class GpuSpace(ConfigSpace):
    I_TILES = [16, 32, 64, 128, 256]
    K1 = [2, 4]
    K2 = [4, 8, 16]
    BINDINGS = [0, 1, 2]    # 0: (i->blk, k->thr) 1: (i->blk, j->thr) 2: (ik->blk)
    UNROLLS = [1, 2, 4]

    def unified(self, n_cols: int):
        p = self.params
        I, J, K, order = mapping.pi_a3(p["i_tile"], p["k1"], p["k2"], n_cols)
        return I, J, K, order, np.zeros(self.n_configs, np.float32)

    def heterogeneous(self) -> np.ndarray:
        p = self.params
        return np.concatenate([
            _onehot(p["binding"], self.BINDINGS),
            _onehot(p["unroll"], self.UNROLLS),
            _onehot(p["i_tile"], self.I_TILES),
            _onehot(p["k1"], self.K1),
            _onehot(p["k2"], self.K2),
        ], axis=1)  # 3 + 3 + 5 + 2 + 3 = 16


def gpu_space() -> GpuSpace:
    _, params = _product_space(i_tile=GpuSpace.I_TILES, k1=GpuSpace.K1,
                               k2=GpuSpace.K2, binding=GpuSpace.BINDINGS,
                               unroll=GpuSpace.UNROLLS)
    default = int(np.flatnonzero(
        (params["i_tile"] == 32) & (params["k1"] == 2) & (params["k2"] == 16) &
        (params["binding"] == 0) & (params["unroll"] == 1))[0])
    choices = {"i_tile": GpuSpace.I_TILES, "k1": GpuSpace.K1, "k2": GpuSpace.K2,
               "binding": GpuSpace.BINDINGS, "unroll": GpuSpace.UNROLLS}
    return GpuSpace("gpu", params, choices, default)   # 270 configs


# ---------------------------------------------------------------- TPU/Pallas

class TpuPallasSpace(ConfigSpace):
    """Tile space of the Pallas BSR SpMM/SDDMM kernels in repro/kernels.

    bm: sparse-operand row-block height; panel: contraction panel width
    (-1 = whole); bn: dense-output column tile; n_major: grid iteration order;
    resident: keep the dense operand panel VMEM-resident vs re-stream.
    """
    BM = [8, 16, 32, 64, 128]
    PANEL = [512, 2048, 8192, -1]
    BN = [128, 256, 512]

    def unified(self, n_cols: int):
        p = self.params
        I, J, K, order = mapping.phi_tpu(p["bm"], p["panel"], p["bn"],
                                         p["n_major"], n_cols)
        return I, J, K, order, np.zeros(self.n_configs, np.float32)

    def heterogeneous(self) -> np.ndarray:
        p = self.params
        return np.concatenate([
            _onehot(p["bm"], self.BM),
            _onehot(p["panel"], self.PANEL),
            _onehot(p["bn"], self.BN),
            _onehot(p["n_major"], [0, 1]),
            _onehot(p["resident"], [0, 1]),
        ], axis=1)  # 5 + 4 + 3 + 2 + 2 = 16


def tpu_pallas_space() -> TpuPallasSpace:
    _, params = _product_space(bm=TpuPallasSpace.BM, panel=TpuPallasSpace.PANEL,
                               bn=TpuPallasSpace.BN, n_major=[0, 1],
                               resident=[0, 1])
    default = int(np.flatnonzero(
        (params["bm"] == 32) & (params["panel"] == -1) & (params["bn"] == 128) &
        (params["n_major"] == 1) & (params["resident"] == 1))[0])
    choices = {"bm": TpuPallasSpace.BM, "panel": TpuPallasSpace.PANEL,
               "bn": TpuPallasSpace.BN, "n_major": [0, 1], "resident": [0, 1]}
    return TpuPallasSpace("tpu_pallas", params, choices, default)  # 240
