from repro.hw.configspace import (ConfigSpace, spade_space, cpu_space, gpu_space,
                                  tpu_pallas_space, UNIFIED_DIM)
from repro.hw.platforms import (Platform, CpuPlatform, SpadePlatform, GpuPlatform,
                                TpuPallasPlatform, get_platform, PLATFORMS)
