"""Sharding policy: parameter / batch / cache PartitionSpecs for any mesh.

Logical axes:
  fsdp   -> ('pod','data')   weight shard dim (ZeRO-3-style: params, grads,
                             and optimizer moments are all fully sharded)
  tp     -> 'model'          Megatron tensor parallelism (heads / d_ff / vocab)
  ep     -> 'model'          expert dim, used only when n_experts divides it
  batch  -> ('pod','data')   activation batch dim (DP)
  seq    -> 'data'           sequence dim (SP): long-context KV caches and
                             batch=1 activations shard the sequence instead

Parameter rules are *trailing-aligned* per leaf name (stacked layer params
have a leading L scan axis that is never sharded). Any logical axis whose
mesh extent does not divide the dim is dropped (replicated) rather than
padded, so memory analysis stays honest. Unmatched leaves fall back to a
greedy largest-dim assignment (tp then fsdp).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Perf experiment knob (EXPERIMENTS.md §Perf cell 2): pad-shard 'tp' dims
# that don't divide the model axis (GSPMD pads, e.g. phi3's 40 heads -> 48)
# instead of replicating. Off by default (paper-faithful baseline).
TP_PAD = False

# (regex on leaf path, trailing-aligned logical axes)
SHARDING_OVERRIDES = [
    (r"embed/table$", ("tp", "fsdp")),
    (r"(attn|xattn)/w[qkv]$", ("fsdp", "tp", None)),
    (r"(attn|xattn)/wo$", ("tp", None, "fsdp")),
    (r"mlp/w[ig]$", ("fsdp", "tp")),
    (r"mlp/wo$", ("tp", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w[ig]$", ("ep", "fsdp", "tp")),
    (r"moe/wo$", ("ep", "tp", "fsdp")),
    (r"(w_up|w_gate)$", ("fsdp", "tp")),
    (r"w_down$", ("tp", "fsdp")),
    (r"w_if$", ("fsdp", "tp")),
    (r"\br$", ("tp", None, None)),
    (r"w_gates$", ("fsdp", "tp", None)),
    (r"(w_z|w_x)$", ("fsdp", "tp")),
    (r"(w_B|w_C)$", ("fsdp", None)),
    (r"w_dt$", ("fsdp", "tp")),
    (r"conv_w$", (None, "tp")),
    (r"(A_log|dt_bias|D_skip)$", ("tp",)),
    (r"w_out$", ("tp", "fsdp")),
    (r"prefix_proj/w$", (None, "fsdp")),
    (r"(scale|b_if|bias)$", None),          # norms & biases: replicated
]


def _logical_axes(mesh: Mesh, serving_1d: bool = False):
    names = mesh.axis_names
    fsdp = tuple(n for n in ("pod", "data") if n in names)
    return {
        # serving_1d drops the weight-shard axis: decode regathers fsdp
        # shards every token, so models that fit HBM under TP-only sharding
        # keep weights stationary instead (EXPERIMENTS.md §Perf)
        "fsdp": None if serving_1d else (fsdp if fsdp else None),
        "batch": fsdp if fsdp else None,
        "tp": "model" if "model" in names else None,
        "ep": "model" if "model" in names else None,
        "seq": "data" if "data" in names else None,
    }


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _resolve(mesh: Mesh, shape, logical, n_experts=0, serving_1d=False):
    """Trailing-aligned logical names -> PartitionSpec for a leaf shape."""
    axes = _logical_axes(mesh, serving_1d)
    spec = [None] * len(shape)
    if logical is None:
        return P(*spec)
    offset = len(shape) - len(logical)
    used = set()
    dropped = []
    for i, name in enumerate(logical):
        if name is None:
            continue
        mesh_axis = axes.get(name)
        if mesh_axis is None:
            continue
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if any(a in used for a in flat):
            continue
        if name == "ep" and (n_experts == 0 or
                             n_experts % _axis_size(mesh, mesh_axis) != 0):
            continue
        dim = shape[offset + i]
        if dim % _axis_size(mesh, mesh_axis) != 0:
            # Divisibility-only policy: a dropped axis means REPLICATION of
            # that dim's compute across the axis (e.g. phi3's 40 heads on
            # model=16). Alternatives (pad-sharding heads, head-dim sharding)
            # trade pad waste or score-contraction collectives — evaluated in
            # EXPERIMENTS.md §Perf; the baseline keeps the faithful simple
            # rule and reports the waste in the useful-compute ratio.
            if not (TP_PAD and name == "tp"
                    and dim >= _axis_size(mesh, mesh_axis) // 2):
                dropped.append(name)
                continue
        spec[offset + i] = mesh_axis
        used.update(flat)
    del dropped
    return P(*spec)


def spec_for_leaf(mesh: Mesh, path: str, shape, n_experts=0,
                  serving_1d=False) -> P:
    for pattern, logical in SHARDING_OVERRIDES:
        if re.search(pattern, path):
            if logical is not None and len(logical) > len(shape):
                logical = logical[-len(shape):]
            return _resolve(mesh, shape, logical, n_experts, serving_1d)
    # fallback: greedy — tp on the largest divisible dim, fsdp on the next
    axes = _logical_axes(mesh, serving_1d)
    spec = [None] * len(shape)
    order = np.argsort(shape)[::-1]
    remaining = [a for a in ("tp", "fsdp") if axes.get(a)]
    start = 1 if len(shape) > 1 else 0   # skip a leading stack/scan axis
    for d in order:
        if d < start or not remaining:
            continue
        name = remaining[0]
        if shape[d] % _axis_size(mesh, axes[name]) == 0 and shape[d] > 1:
            spec[d] = axes[name]
            remaining.pop(0)
    return P(*spec)


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    return flat, treedef, names


def tree_shardings(mesh: Mesh, tree, n_experts=0, serving_1d=False):
    """NamedShardings for a pytree of arrays/ShapeDtypeStructs (params or
    optimizer moments — moments inherit the param spec = ZeRO sharding)."""
    flat, treedef, names = _paths(tree)
    out = []
    for name, (path, leaf) in zip(names, flat):
        if not hasattr(leaf, "shape") or len(getattr(leaf, "shape", ())) == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        out.append(NamedSharding(mesh,
                                 spec_for_leaf(mesh, name, leaf.shape,
                                               n_experts, serving_1d)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replica_devices(mesh: Mesh) -> list:
    """One placement target per serving replica: the lead device of each
    'data' slice of the mesh (replicas are data-parallel — each owns a shard
    of the pattern-digest space, not of any one tensor). Falls back to the
    flattened device list for meshes without a 'data' axis."""
    arr = np.asarray(mesh.devices)
    names = list(mesh.axis_names)
    if "data" in names and arr.ndim == len(names):
        arr = np.moveaxis(arr, names.index("data"), 0)
        return list(arr.reshape(arr.shape[0], -1)[:, 0])
    return list(arr.reshape(-1))


HBM_SERVE_BUDGET = 10 * 2**30    # leave headroom for caches + activations


def param_shardings(mesh: Mesh, params, arch=None, serving: bool = False):
    """serving=True: weights stay stationary (TP-only) when the per-chip
    footprint under 1D sharding fits ``HBM_SERVE_BUDGET``; oversized models
    (grok-1, nemotron-340b) fall back to 2D fsdp x tp with per-step gathers.
    """
    n_experts = getattr(arch, "n_experts", 0)
    if serving:
        from repro.utils.tree import tree_size_bytes
        model_sz = mesh.shape.get("model", 1)
        per_chip = tree_size_bytes(params) / max(model_sz, 1)
        if per_chip <= HBM_SERVE_BUDGET:
            return tree_shardings(mesh, params, n_experts, serving_1d=True)
    return tree_shardings(mesh, params, n_experts)


# ------------------------------------------------------------- activations

def _batch_dim_spec(mesh: Mesh, batch: int, seq: int | None):
    """Pick (batch_axes, seq_axes): DP when the batch divides, else SP."""
    axes = _logical_axes(mesh)
    bd = axes["batch"]
    if bd is not None and batch % _axis_size(mesh, bd) == 0:
        return bd, None
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0 \
            and batch > 1:
        return "data", None
    if seq is not None and "data" in mesh.axis_names \
            and seq % mesh.shape["data"] == 0:
        return None, "data"       # sequence sharding (long_500k, batch=1)
    return None, None


def batch_shardings(mesh: Mesh, specs: dict):
    """Shardings for a model input_specs dict (tokens/targets/prefix/frames)."""
    out = {}
    for k, v in specs.items():
        if len(v.shape) == 0:
            out[k] = NamedSharding(mesh, P())
            continue
        B = v.shape[0]
        S = v.shape[1] if len(v.shape) > 1 else None
        bd, sd = _batch_dim_spec(mesh, B, S)
        spec = [bd] + [None] * (len(v.shape) - 1)
        if sd is not None and len(v.shape) > 1:
            spec[1] = sd
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(mesh: Mesh, cache, batch: int):
    """KV/state cache shardings for decode.

    Policy: batch dim over the batch axes; the SEQUENCE dim (the longest
    remaining dim) over 'model' — and additionally over 'data' when the
    batch is too small to use it (long_500k, batch=1). Sequence sharding
    keeps each chip's attention local to its cache slice; the softmax
    statistics and PV partials then reduce with tiny psums (flash-decoding
    dataflow), instead of all-gathering multi-GB caches per token. Head/state
    dims stay unsharded.
    """
    axes = _logical_axes(mesh)
    batch_sz = _axis_size(mesh, axes["batch"]) if axes["batch"] else 1

    def leaf_spec(leaf):
        shape = leaf.shape
        if len(shape) <= 1:
            return P(*([None] * len(shape)))
        spec = [None] * len(shape)
        b_axis = next((i for i in (1, 0) if i < len(shape)
                       and shape[i] == batch), None)
        batch_used = False
        if b_axis is not None and axes["batch"] and batch % batch_sz == 0 \
                and batch > 1:
            spec[b_axis] = axes["batch"]
            batch_used = True
        # sequence dim: longest free dim
        seq_axes = []
        if not batch_used and axes["batch"]:
            seq_axes.extend(axes["batch"])
        if "model" in mesh.axis_names:
            seq_axes.append("model")
        if seq_axes:
            cand = int(np.argmax([s if spec[i] is None and i != b_axis else 0
                                  for i, s in enumerate(shape)]))
            size = int(np.prod([mesh.shape[a] for a in seq_axes]))
            if shape[cand] % size == 0 and shape[cand] > 1:
                spec[cand] = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, leaf_spec(leaf))
        if hasattr(leaf, "shape") else NamedSharding(mesh, P()), cache)
