from repro.parallel.sharding import (param_shardings, batch_shardings,
                                     cache_shardings, spec_for_leaf,
                                     tree_shardings, SHARDING_OVERRIDES)
