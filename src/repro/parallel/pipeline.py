"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The production configs map the ``pod`` axis to data parallelism (DESIGN.md §6
has the napkin math), but at >=4 pods with small global batches the bubble
beats the DCN gradient all-reduce, so the substrate ships a real pipeline:

  * the layer stack is split into S contiguous stages (one per pod),
  * each microbatch flows through stages via lax.ppermute,
  * the schedule is the classic GPipe loop of (S + M - 1) ticks with M
    microbatches — bubble fraction (S-1)/(S+M-1).

``pipeline_apply`` is written against a per-stage layer function so any of
the scanned-layer models can adopt it; tests validate it against the
sequential stack on a 4-device host mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn, params_stacked, x_microbatches, mesh: Mesh,
                   axis: str = "pod"):
    """Run x through n_stages stages living on ``axis``.

    stage_fn(stage_params, x) -> x        (applied by each device group)
    params_stacked: pytree with leading dim = n_stages (sharded on axis)
    x_microbatches: (M, mb, ...) microbatched inputs (replicated)

    Returns (M, mb, ...) outputs. Schedule: GPipe forward, S + M - 1 ticks.
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    T = S + M - 1

    def per_stage(params_local, xs):
        # params_local: this stage's params (leading dim 1); xs: all M inputs
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage = lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])                 # current tick's input
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 feeds microbatch t (if any remain); others use buf
            feed = xs[jnp.minimum(t, M - 1)]
            x_in = jnp.where(stage == 0, feed, buf)
            y = stage_fn(params_local, x_in)
            # forward the activation to the next stage
            perm = [(i, i + 1) for i in range(S - 1)]
            nxt = lax.ppermute(y, axis, perm)
            # last stage emits microbatch (t - (S-1)) at tick t
            mb_idx = t - (S - 1)
            valid = (stage == S - 1) & (mb_idx >= 0) & (mb_idx < M)
            outs = lax.cond(
                valid,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(mb_idx, 0), 0),
                lambda o: o, outs)
            return (nxt, outs), None

        (buf, outs), _ = lax.scan(tick, (buf, outs), jnp.arange(T))
        # only the last stage holds real outputs — broadcast via masked psum
        outs = lax.psum(jnp.where(stage == S - 1, outs, 0.0), axis)
        return outs

    spec_params = jax.tree_util.tree_map(lambda _: P(axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_microbatches)
