"""Architecture and shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact public configs), plus a
``reduced()`` derivation used by the CPU smoke tests. ``ShapeConfig`` are the
assigned input shapes; ``long_500k`` is only valid for sub-quadratic families
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    activation: str = "swiglu"   # swiglu | sq_relu | geglu
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    # virtual-expert (expert-slicing) factor: weights stored as
    # (n_experts*split, d_model, d_ff/split) so the expert axis can divide
    # the model mesh axis -> true expert parallelism (EXPERIMENTS.md §Perf)
    moe_expert_split: int = 1
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    attn_every: int = 0          # zamba: shared attention every k blocks
    slstm_every: int = 0         # xlstm: sLSTM block every k blocks
    # enc-dec (seamless): n_layers = decoder layers, n_enc_layers = encoder
    n_enc_layers: int = 0
    # vlm / audio stub frontends
    n_prefix_tokens: int = 0     # vision patches / audio frames are inputs
    prefix_dim: int = 0          # stub embedding dim (0 -> d_model)
    # misc
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every == 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (see DESIGN.md)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4) if self.attn_every == 0
            else max(self.attn_every, 4),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=max(64, min(self.d_ff, 256)),
            vocab=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            n_prefix_tokens=min(self.n_prefix_tokens, 16),
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    def reduced(self, seq: int = 64, batch: int = 2) -> "ShapeConfig":
        return dataclasses.replace(self, name=self.name + "-smoke",
                                   seq_len=seq, global_batch=batch)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def valid_cells(arch: ArchConfig) -> list[str]:
    """The assigned shape cells this architecture runs (skips per DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.subquadratic:
        cells.append("long_500k")
    return cells
