from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, valid_cells
