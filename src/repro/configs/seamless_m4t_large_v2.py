"""seamless-m4t-v2-large: enc-dec transformer backbone over precomputed audio
frame embeddings (stub frontend), 24 enc + 24 dec, MHA kv=16.
[arXiv:2308.11596]"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206, activation="geglu",
    n_enc_layers=24)
