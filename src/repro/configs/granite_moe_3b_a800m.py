"""granite-3.0 MoE: 32L, 40 experts top-8, tiny per-expert d_ff=512, GQA kv=8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155, activation="swiglu",
    n_experts=40, experts_per_token=8)
