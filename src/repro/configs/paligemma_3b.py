"""paligemma-3b: SigLIP patch stub (256 prefix tokens) + gemma backbone,
18L MQA kv=1, GeGLU. [arXiv:2407.07726]"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048, n_heads=8,
    n_kv_heads=1, d_ff=16384, vocab=257216, activation="geglu",
    n_prefix_tokens=256, prefix_dim=1152, head_dim=256)
