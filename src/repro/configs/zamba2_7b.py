"""zamba2-7b: 81 Mamba2 blocks (ssm_state=64) + 2 alternating shared
full-attention blocks every 6th position. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000, activation="swiglu",
    ssm_state=64, ssm_head_dim=64, attn_every=6)
