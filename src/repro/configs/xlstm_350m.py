"""xlstm-350m: 24 blocks, mLSTM + sLSTM every 8th (xLSTM[7:1]).
[arXiv:2405.04517]"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8, head_dim=256)
