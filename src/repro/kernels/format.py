"""Vectorized BSR construction — the O(nnz) pattern -> kernel-format path.

The seed implementation materialized a dense (M, K) array and assembled
blocks in a Python loop; for a 4096x4096 / 200k-nnz pattern that is 64 MB of
traffic and thousands of interpreter iterations per conversion.  Everything
here works directly on COO coordinates with a constant number of numpy
sort/segment passes:

  sort by (block-row, block-col) key -> segment-reduce to unique blocks ->
  scatter values into the (nnzb, bm, BK) data array.

Semantics match ``ops.bsr_from_dense``/``ops.bsr_from_coo`` exactly
(bit-identical ``data``/``rowids``/``colids``): blocks sorted by
(block-row, block-col), every empty block-row represented by one zero pad
block at block-column 0 (the kernels' flush predicate depends on it),
duplicate COO entries resolve last-write-wins, and entries whose float32
value is exactly zero do not make a block present.

``BsrPlan`` separates the *structure* (sort order, scatter indices — a pure
function of the sparsity pattern) from the *values*, so a serving loop that
sees the same pattern with fresh values (e.g. MoE dispatch: fixed routing,
new activations) pays only one fancy-indexed scatter per batch.  Plans are
what ``repro.core.autotune.KernelAutotuner`` caches per pattern digest.

Two scatter paths share each plan's structure:

* **Host** (``build``/``scatter_into``): numpy fancy-indexed write into a
  host buffer, converted to a device array on ``wrap``.  The cold /
  reference path, and the right one for values that live in host memory.
* **Device** (``build_device``/``device_update``): the same scatter as ONE
  jitted gather+scatter on whatever device JAX runs on.  Values that are
  already device-resident (MoE router outputs, activations straight from a
  preceding kernel) become kernel-ready block data without a host
  round-trip, and the dispatch is asynchronous — the serving engine
  overlaps it with in-flight kernels.  ``device_update`` additionally
  donates the previous block buffer (every build writes the exact same
  positions), so the steady-state rebuild is in place.  Outputs are
  bit-identical to the host path.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm import BK

__all__ = ["BsrMatrix", "BsrPlan", "plan_from_coo", "bsr_from_coo",
           "bsr_from_dense", "bsr_from_blocks"]


@dataclasses.dataclass
class BsrMatrix:
    """Flattened BSR: blocks sorted by (block-row, block-col); every block-row
    is represented (empty rows get one zero pad block), so the kernels' flush
    predicate is exact."""
    data: jnp.ndarray       # (nnzb, bm, BK)
    rowids: jnp.ndarray     # (nnzb,) int32, sorted
    colids: jnp.ndarray     # (nnzb,) int32
    n_blockrows: int
    n_blockcols: int

    @property
    def block_m(self) -> int:
        return self.data.shape[1]

    @property
    def nnzb(self) -> int:
        return self.data.shape[0]

    @property
    def shape(self):
        return (self.n_blockrows * self.block_m, self.n_blockcols * BK)


_I32_MAX = np.iinfo(np.int32).max


@functools.partial(jax.jit, static_argnames=("shape", "dtype"))
def _device_scatter(values, take, flat, *, shape, dtype):
    """values -> (nnzb, bm, BK) block data in one jitted gather + scatter.
    Scatter positions are unique by construction (plans are built from
    deduplicated coordinates), so ``unique_indices`` is safe."""
    v = values.reshape(-1).astype(dtype)[take]
    size = shape[0] * shape[1] * shape[2]
    flatbuf = jnp.zeros((size,), dtype).at[flat].set(v, unique_indices=True)
    return flatbuf.reshape(shape)


@functools.partial(jax.jit, donate_argnums=(0,))
def _device_rescatter(buf, values, take, flat):
    """In-place (donated) rebuild: every build writes the exact same
    positions, so overwriting the previous block data needs no re-zeroing.
    ``buf`` is invalid after this call — callers own the returned array."""
    v = values.reshape(-1).astype(buf.dtype)[take]
    return buf.reshape(-1).at[flat].set(v, unique_indices=True) \
        .reshape(buf.shape)


@dataclasses.dataclass
class BsrPlan:
    """Structure-only half of a BSR conversion, reusable across value sets.

    ``take``/``slot``/``rloc``/``cloc`` scatter the caller's values array
    (aligned with the rows/cols the plan was built from) into block data:
    ``data[slot[i], rloc[i], cloc[i]] = values[take[i]]``.

    The same structure drives two paths: the numpy host scatter
    (``build``/``scatter_into``) and the jitted device scatter
    (``build_device``/``device_update``), which consumes device-resident
    values without a host round-trip and produces bit-identical block data.

    Thread-safety: the scatter arrays are immutable after construction, so
    concurrent ``scatter_into``/``wrap``/``build_device`` calls into
    *caller-owned* buffers are safe (the cached index arrays are built
    idempotently).  ``build(..., reuse=True)`` and ``build_data(reuse=True)``
    share one plan-owned buffer and must be externally serialized — serving
    code uses ``repro.serving.arena.PlanArena`` (per-slot buffers plus
    leases) instead of ``reuse`` for exactly this reason.
    """
    rowids: np.ndarray      # (nnzb,) int32, sorted by (block-row, block-col)
    colids: np.ndarray      # (nnzb,) int32
    n_blockrows: int
    n_blockcols: int
    block_m: int
    take: np.ndarray        # (n_entries,) int32 indices into the source values
    slot: np.ndarray        # (n_entries,) int32 destination block in [0, nnzb)
    rloc: np.ndarray        # (n_entries,) int16 row within block (< bm <= 128)
    cloc: np.ndarray        # (n_entries,) int16 col within block (< BK)
    _buf: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _jids: tuple | None = dataclasses.field(default=None, repr=False)
    _flat: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _dev: tuple | None = dataclasses.field(default=None, repr=False)
    _need: int | None = dataclasses.field(default=None, repr=False)

    @property
    def nnzb(self) -> int:
        return int(self.rowids.shape[0])

    def alloc_buffer(self, buf_dtype=np.float32,
                     align: int | None = None) -> np.ndarray:
        """A zeroed (nnzb, bm, BK) block-data buffer this plan scatters into.
        External holders (e.g. ``repro.serving.arena.PlanArena`` slots) own
        their buffers; ``reuse=True`` builds use a single plan-owned one.

        ``align`` (bytes, power of two, multiple of the itemsize) returns a
        buffer whose data pointer is aligned to that boundary.  JAX's CPU
        backend zero-copies ``jnp.asarray`` only for 64-byte-aligned host
        buffers — an aligned buffer is what lets ``wrap`` alias host storage
        instead of copying the full block data on every build (the fused
        warm-lane path).  Default ``None`` keeps the plain ``np.zeros``
        allocation and therefore the copying (non-aliasing) ``wrap``
        semantics every existing caller relies on."""
        shape = (self.nnzb, self.block_m, BK)
        if align is None:
            return np.zeros(shape, buf_dtype)
        dt = np.dtype(buf_dtype)
        if align % dt.itemsize:
            raise ValueError(f"align={align} is not a multiple of the "
                             f"itemsize ({dt.itemsize})")
        n = int(np.prod(shape))
        raw = np.zeros(n + align // dt.itemsize, dt)
        off_bytes = (-raw.ctypes.data) % align
        off = off_bytes // dt.itemsize
        return raw[off:off + n].reshape(shape)

    def scatter_into(self, values, data: np.ndarray) -> np.ndarray:
        """O(nnz) fancy-indexed write of ``values`` into ``data`` (a buffer
        from ``alloc_buffer``).  Every build writes the exact same positions,
        so a once-zeroed buffer never needs refilling between builds."""
        v = np.asarray(values).reshape(-1)
        data[self.slot, self.rloc, self.cloc] = v[self.take]
        return data

    def wrap(self, data: np.ndarray, dtype=jnp.float32) -> BsrMatrix:
        """Block data -> ``BsrMatrix`` with this plan's structure (rowids /
        colids converted to device arrays once and cached)."""
        if self._jids is None:
            self._jids = (jnp.asarray(self.rowids, jnp.int32),
                          jnp.asarray(self.colids, jnp.int32))
        return BsrMatrix(_as_jax(data, dtype), *self._jids,
                         self.n_blockrows, self.n_blockcols)

    def build_data(self, values, buf_dtype=np.float32,
                   reuse: bool = False) -> np.ndarray:
        """Scatter ``values`` into a (nnzb, bm, BK) block-data array.

        ``reuse=True`` scatters into a plan-owned buffer: every build writes
        the exact same positions, so after the first (zeroed) allocation no
        refill is needed and a rebuild is one O(nnz) fancy-indexed write with
        warm pages — the steady-state serving cost.  The returned array then
        aliases plan storage and is only valid until the next reusing build.
        """
        if reuse and self._buf is not None and self._buf.dtype == buf_dtype:
            data = self._buf
        else:
            data = self.alloc_buffer(buf_dtype)
            if reuse:
                self._buf = data
        return self.scatter_into(values, data)

    def build(self, values, dtype=jnp.float32,
              reuse: bool = False) -> BsrMatrix:
        """Values -> BsrMatrix through the cached structure.  With
        ``reuse=True`` the result aliases plan-owned storage (valid until the
        next reusing ``build`` on this plan) — the serving-loop fast path."""
        return self.wrap(self.build_data(values, reuse=reuse), dtype)

    # --------------------------------------------------- device scatter path

    def flat_index(self) -> np.ndarray:
        """Flattened destination index of every entry in the (nnzb, bm, BK)
        block-data buffer — ``(slot * bm + rloc) * BK + cloc`` — the scatter
        half of the device build.  Computed once and cached (int32 when the
        buffer size fits, so cached plans stay small); ``repro.serving
        .persist`` format v3 carries it so a warm-started pattern's first
        device build pays neither the sort nor this pass."""
        if self._flat is None:
            flat = (self.slot.astype(np.int64) * self.block_m
                    + self.rloc.astype(np.int64)) * BK \
                + self.cloc.astype(np.int64)
            size = self.nnzb * self.block_m * BK
            self._flat = flat.astype(np.int32 if size <= _I32_MAX
                                     else np.int64)
        return self._flat

    def device_indices(self) -> tuple:
        """(take, flat) as device arrays, converted once and cached — the
        gather/scatter pair ``build_device``/``device_update`` consume."""
        if self._dev is None:
            flat = jnp.asarray(self.flat_index())
            if flat.dtype != self.flat_index().dtype:
                # x64-disabled JAX silently wraps an int64 index to int32 —
                # scatter corruption, not an error.  Refuse instead.
                raise ValueError(
                    "plan's block buffer needs int64 scatter indices; "
                    "enable jax_enable_x64 or use the host build path")
            self._dev = (jnp.asarray(self.take, jnp.int32), flat)
        return self._dev

    def _check_values(self, v: jnp.ndarray) -> jnp.ndarray:
        """The device gather clamps out-of-range indices instead of raising
        like the numpy host path — reject short values up front so the two
        paths fail identically.  The bound is computed once per plan (a
        size check per build, no per-build host scan)."""
        if self._need is None:
            self._need = int(self.take.max()) + 1 if self.take.size else 0
        if v.size < self._need:
            raise ValueError(f"values has {v.size} elements; plan scatters "
                             f"from indices up to {self._need - 1}")
        return v

    def device_data(self, values, dtype=jnp.float32) -> jnp.ndarray:
        """Device-resident (nnzb, bm, BK) block data from ``values`` in a
        single jitted gather+scatter — no host numpy in the path, so values
        already on device (MoE router outputs, activations from a previous
        kernel) never round-trip through the host.  Bit-identical to
        ``build_data``.  The dispatch is asynchronous: the returned array is
        a future the next kernel launch can consume immediately."""
        take, flat = self.device_indices()
        return _device_scatter(self._check_values(jnp.asarray(values)),
                               take, flat,
                               shape=(self.nnzb, self.block_m, BK),
                               dtype=np.dtype(dtype).name)

    def device_update(self, buf: jnp.ndarray, values) -> jnp.ndarray:
        """Rebuild device block data in place: ``buf`` (a previous
        ``device_data``/``device_update`` result) is **donated** to the
        jitted scatter, so the steady-state rebuild allocates nothing and
        re-zeroes nothing (every build writes the same positions).  ``buf``
        is invalid afterwards — use only the returned array.  Callers must
        guarantee no in-flight consumer still needs ``buf``'s *alias* (the
        arena's lease protocol exists for exactly this)."""
        take, flat = self.device_indices()
        return _device_rescatter(buf, self._check_values(jnp.asarray(values)),
                                 take, flat)

    def build_device(self, values, dtype=jnp.float32) -> BsrMatrix:
        """Values -> BsrMatrix entirely on device (one jitted scatter; no
        host numpy in the warm path).  The cold/reference counterpart is
        ``build``; outputs are bit-identical."""
        return self.wrap(self.device_data(values, dtype), dtype)


def _as_jax(data: np.ndarray, dtype) -> jnp.ndarray:
    """To-device conversion that keeps the zero-copy path: ``jnp.asarray``
    with an explicit dtype copies even when the dtype already matches, which
    costs a full pass over the block data."""
    if data.dtype == np.dtype(dtype):
        return jnp.asarray(data)
    return jnp.asarray(data, dtype)


def _check_bounds(rows, cols, m, k):
    if rows.size:
        if int(rows.min()) < 0 or int(rows.max()) >= m:
            raise ValueError(f"row index out of range for shape ({m}, {k})")
        if int(cols.min()) < 0 or int(cols.max()) >= k:
            raise ValueError(f"col index out of range for shape ({m}, {k})")


def _dedup_last(rows, cols, n_cols_total) -> np.ndarray:
    """Indices of surviving entries under last-write-wins duplicate
    resolution (the semantics of ``dense[rows, cols] = values``), sorted by
    element key (row-major)."""
    ekey = rows.astype(np.int64) * n_cols_total + cols.astype(np.int64)
    order = np.argsort(ekey, kind="stable")
    sk = ekey[order]
    if sk.size == 0:
        return order
    last = np.concatenate([sk[1:] != sk[:-1], np.ones(1, bool)])
    return order[last]


def _assemble(rows, cols, m, k, block_m, take) -> BsrPlan:
    """Core O(nnz) assembly. ``rows``/``cols`` must be deduplicated; ``take``
    maps each entry back into the caller's values array."""
    nbr = (m + block_m - 1) // block_m
    nbc = (k + BK - 1) // BK
    r64 = rows.astype(np.int64)
    c64 = cols.astype(np.int64)
    br, bc = r64 // block_m, c64 // BK
    bkey = br * nbc + bc
    n_grid = nbr * nbc
    if n_grid <= max(1 << 22, 4 * bkey.size):
        # small block grid: sort-free path — mark touched blocks in a dense
        # presence LUT, add pad blocks for empty rows, and read slots off a
        # cumulative count.  O(nnz + grid) with no O(nnz log nnz) sort.
        presence = np.zeros(n_grid, bool)
        presence[bkey] = True
        row_occupied = presence.reshape(nbr, nbc).any(axis=1)
        presence[np.flatnonzero(~row_occupied) * nbc] = True   # pad blocks
        ids = np.flatnonzero(presence)                         # sorted keys
        lut = np.cumsum(presence, dtype=np.int64) - 1          # key -> slot
        slot = lut[bkey]
    else:
        ublocks, inv = np.unique(bkey, return_inverse=True)
        occupied = np.unique(ublocks // nbc)
        empty = np.setdiff1d(np.arange(nbr, dtype=np.int64), occupied,
                             assume_unique=True)
        allkeys = np.concatenate([ublocks, empty * nbc])  # pad blocks, col 0
        order = np.argsort(allkeys)                       # keys all distinct
        perm = np.empty(order.size, np.int64)
        perm[order] = np.arange(order.size)
        ids = allkeys[order]
        slot = perm[:ublocks.size][inv.reshape(-1)]
    # narrow index dtypes: cached plans hold these per-nnz arrays resident
    return BsrPlan(rowids=(ids // nbc).astype(np.int32),
                   colids=(ids % nbc).astype(np.int32),
                   n_blockrows=nbr, n_blockcols=nbc, block_m=block_m,
                   take=np.asarray(take, np.int32),
                   slot=slot.astype(np.int32),
                   rloc=(r64 - br * block_m).astype(np.int16),
                   cloc=(c64 - bc * BK).astype(np.int16))


def plan_from_coo(rows, cols, shape, block_m: int = 32,
                  assume_unique: bool = False) -> BsrPlan:
    """Structure-only plan from COO coordinates (values supplied at build
    time).  Every listed coordinate is treated as structurally present —
    unlike ``bsr_from_coo``, a zero *value* later scattered through the plan
    does not remove its block (pattern semantics, matching
    ``repro.data.matrices.SparseMatrix`` where values are implicit).

    ``assume_unique=True`` skips the duplicate-resolution sort; use it for
    coordinates already known to be deduplicated (e.g. ``SparseMatrix``).
    """
    m, k = shape
    rows, cols = np.asarray(rows), np.asarray(cols)
    _check_bounds(rows, cols, m, k)
    if assume_unique:
        take = np.arange(rows.size, dtype=np.int64)
        return _assemble(rows, cols, m, k, block_m, take)
    take = _dedup_last(rows, cols, k)
    return _assemble(rows[take], cols[take], m, k, block_m, take)


def bsr_from_coo(rows, cols, values, shape, block_m: int = 32,
                 dtype=jnp.float32) -> BsrMatrix:
    """COO -> flattened BSR without ever materializing a dense (M, K) array.

    Bit-identical to the seed dense-roundtrip implementation: duplicates
    resolve last-write-wins, values cast to float32 before the presence test,
    entries with value exactly 0.0 do not create blocks, and empty block-rows
    get one zero pad block at block-column 0.
    """
    m, k = shape
    rows, cols = np.asarray(rows), np.asarray(cols)
    _check_bounds(rows, cols, m, k)
    take = _dedup_last(rows, cols, k)
    v = np.asarray(values, np.float32)
    v = np.ascontiguousarray(np.broadcast_to(v, rows.shape)).reshape(-1)[take]
    nz = v != 0
    take, v = take[nz], v[nz]
    plan = _assemble(rows[take], cols[take], m, k, block_m,
                     np.arange(v.size))
    return plan.build(v, dtype)


def bsr_from_dense(dense: np.ndarray, block_m: int = 32,
                   dtype=jnp.float32) -> BsrMatrix:
    """Convert a dense (M, K) array (zeros = absent) to flattened BSR.

    M and K are zero-padded up to multiples of (block_m, 128).
    """
    dense = np.asarray(dense)
    m, k = dense.shape
    r, c = np.nonzero(dense)
    plan = _assemble(r, c, m, k, block_m, np.arange(r.size))
    data = plan.build_data(dense[r, c], buf_dtype=dense.dtype)
    return BsrMatrix(_as_jax(data, dtype),
                     jnp.asarray(plan.rowids, jnp.int32),
                     jnp.asarray(plan.colids, jnp.int32),
                     plan.n_blockrows, plan.n_blockcols)


def _dense_roundtrip_reference(dense: np.ndarray, block_m: int = 32):
    """The seed dense-roundtrip construction, retained verbatim as the
    executable specification of BSR semantics.  Tests use it as the
    bit-identity oracle and ``benchmarks/bsr_preproc.py`` as the timed
    baseline; it is the only copy — do not fork it.  Returns numpy
    ``(data, rowids, colids, n_blockrows, n_blockcols)``.
    """
    m, k = dense.shape
    pm, pk = (-m) % block_m, (-k) % BK
    if pm or pk:
        dense = np.pad(dense, ((0, pm), (0, pk)))
    m, k = dense.shape
    nbr, nbc = m // block_m, k // BK
    blocks = dense.reshape(nbr, block_m, nbc, BK).transpose(0, 2, 1, 3)
    nz = np.abs(blocks).sum(axis=(2, 3)) > 0
    rowids, colids, data = [], [], []
    for r in range(nbr):
        cols = np.flatnonzero(nz[r])
        if cols.size == 0:
            cols = np.array([0])          # pad block keeps the row present
        for c in cols:
            rowids.append(r)
            colids.append(c)
            data.append(blocks[r, c])
    return (np.stack(data), np.asarray(rowids, np.int32),
            np.asarray(colids, np.int32), nbr, nbc)


def bsr_from_blocks(block_rows, block_cols, blocks, n_blockrows: int,
                    n_blockcols: int, dtype=jnp.float32) -> BsrMatrix:
    """Flattened BSR directly from block coordinates + block data.

    ``blocks``: (n, bm, 128) data aligned with ``block_rows``/``block_cols``
    (which must be unique pairs).  Blocks are sorted by (block-row,
    block-col) and empty block-rows get a zero pad block — the same invariant
    the COO/dense constructors guarantee.  This is the fast path for callers
    that already know their pattern at block granularity (e.g. MoE dispatch:
    one block per (token-tile, expert)).
    """
    br = np.asarray(block_rows, np.int64)
    bc = np.asarray(block_cols, np.int64)
    blocks = np.asarray(blocks)
    if blocks.ndim != 3 or blocks.shape[0] != br.size or blocks.shape[2] != BK:
        raise ValueError(f"blocks must be (n, bm, {BK}) aligned with coords")
    if br.size and (br.min() < 0 or br.max() >= n_blockrows
                    or bc.min() < 0 or bc.max() >= n_blockcols):
        raise ValueError("block coordinate out of range")
    key = br * n_blockcols + bc
    if np.unique(key).size != key.size:
        raise ValueError("duplicate block coordinates")
    empty = np.setdiff1d(np.arange(n_blockrows, dtype=np.int64),
                         np.unique(br), assume_unique=True)
    allkeys = np.concatenate([key, empty * n_blockcols])
    order = np.argsort(allkeys)
    bm = blocks.shape[1]
    data = np.concatenate(
        [blocks, np.zeros((empty.size, bm, BK), blocks.dtype)])[order]
    return BsrMatrix(_as_jax(data, dtype),
                     jnp.asarray(allkeys[order] // n_blockcols, jnp.int32),
                     jnp.asarray(allkeys[order] % n_blockcols, jnp.int32),
                     n_blockrows, n_blockcols)
