"""Block-sparse SDDMM Pallas TPU kernel: out = (B @ C) sampled at BSR(mask).

Grid = (nnzb, k_tiles): for each nonzero (block_m x 128) pattern block, the
kernel streams (bm x bk) strips of B's rows and (bk x 128) strips of C's
columns, accumulating the dense product in a fp32 VMEM scratch; at the last
k-tile the accumulator is masked by the pattern block and written to the
flattened block output. Block row/col coordinates arrive via scalar prefetch,
so work scales with touched blocks only — the same dataflow SPADE uses for
its sampled products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

BW = 128  # pattern block width (lane dimension)


def _sddmm_kernel(rowids, colids, mask, b, c, out, acc, *, n_ktiles):
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(b[...], c[...], preferred_element_type=jnp.float32)

    @pl.when(kt == n_ktiles - 1)
    def _flush():
        out[...] = (acc[...] * mask[0].astype(jnp.float32)).astype(out.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def sddmm_pallas(mask_data, rowids, colids, b, c, *, block_k: int = 128,
                 interpret: bool = True):
    """mask_data (nnzb, bm, BW) x b (M, K) x c (K, N) -> (nnzb, bm, BW).

    M must be a multiple of bm, K of block_k, N of BW. Output is the sampled
    product in flattened-BSR block layout (same rowids/colids).
    """
    nnzb, bm, bw = mask_data.shape
    assert bw == BW, f"pattern block width must be {BW}, got {bw}"
    m, k = b.shape
    k2, n = c.shape
    assert k == k2 and k % block_k == 0 and m % bm == 0 and n % BW == 0
    n_ktiles = k // block_k

    grid = (nnzb, n_ktiles)
    mask_spec = pl.BlockSpec((1, bm, bw), lambda s, kt, rows, cols: (s, 0, 0))
    b_spec = pl.BlockSpec((bm, block_k), lambda s, kt, rows, cols: (rows[s], kt))
    c_spec = pl.BlockSpec((block_k, bw), lambda s, kt, rows, cols: (kt, cols[s]))
    o_spec = pl.BlockSpec((1, bm, bw), lambda s, kt, rows, cols: (s, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid,
        in_specs=[mask_spec, b_spec, c_spec], out_specs=o_spec,
        scratch_shapes=[pltpu.VMEM((bm, bw), jnp.float32)])
    out_shape = jax.ShapeDtypeStruct((nnzb, bm, bw), b.dtype)
    kernel = functools.partial(_sddmm_kernel, n_ktiles=n_ktiles)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(rowids, colids, mask_data, b, c)
