# Pallas TPU kernels for the sparse tensor programs the paper optimizes
# (SpMM, SDDMM) in block-sparse (BSR) form, validated in interpret mode
# against the pure-jnp oracles in ref.py.
from repro.kernels.ops import (BsrMatrix, bsr_from_dense, bsr_from_coo,
                               spmm, sddmm, spmm_ref, sddmm_ref)
