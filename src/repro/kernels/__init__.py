# Pallas TPU kernels for the sparse tensor programs the paper optimizes
# (SpMM, SDDMM) in block-sparse (BSR) form, validated in interpret mode
# against the pure-jnp oracles in ref.py. Format conversion (vectorized
# O(nnz) COO/dense/block-coordinate -> BSR) lives in format.py.
from repro.kernels.format import (BsrMatrix, BsrPlan, bsr_from_blocks,
                                  bsr_from_coo, bsr_from_dense, plan_from_coo)
from repro.kernels.ops import spmm, sddmm, spmm_ref, sddmm_ref
