"""Public jit'd entry points for the sparse kernels + format conversion.

``spmm`` / ``sddmm`` take a ``BsrMatrix`` (built once per sparsity pattern via
``bsr_from_dense`` / ``bsr_from_coo``) and dispatch to the Pallas kernels,
with tile parameters supplied by the caller — typically from
``repro.core.autotune.KernelAutotuner`` (the paper's technique driving real
kernel configuration).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sddmm import BW, sddmm_pallas
from repro.kernels.spmm import BK, spmm_pallas
from repro.kernels import ref


@dataclasses.dataclass
class BsrMatrix:
    """Flattened BSR: blocks sorted by (block-row, block-col); every block-row
    is represented (empty rows get one zero pad block), so the kernels' flush
    predicate is exact."""
    data: jnp.ndarray       # (nnzb, bm, BK)
    rowids: jnp.ndarray     # (nnzb,) int32, sorted
    colids: jnp.ndarray     # (nnzb,) int32
    n_blockrows: int
    n_blockcols: int

    @property
    def block_m(self) -> int:
        return self.data.shape[1]

    @property
    def nnzb(self) -> int:
        return self.data.shape[0]

    @property
    def shape(self):
        return (self.n_blockrows * self.block_m, self.n_blockcols * BK)


def bsr_from_dense(dense: np.ndarray, block_m: int = 32,
                   dtype=jnp.float32) -> BsrMatrix:
    """Convert a dense (M, K) array (zeros = absent) to flattened BSR.

    M and K are zero-padded up to multiples of (block_m, 128).
    """
    m, k = dense.shape
    pm, pk = (-m) % block_m, (-k) % BK
    if pm or pk:
        dense = np.pad(dense, ((0, pm), (0, pk)))
    m, k = dense.shape
    nbr, nbc = m // block_m, k // BK
    blocks = dense.reshape(nbr, block_m, nbc, BK).transpose(0, 2, 1, 3)
    nz = np.abs(blocks).sum(axis=(2, 3)) > 0
    rowids, colids, data = [], [], []
    for r in range(nbr):
        cols = np.flatnonzero(nz[r])
        if cols.size == 0:
            cols = np.array([0])          # pad block keeps the row present
        for c in cols:
            rowids.append(r)
            colids.append(c)
            data.append(blocks[r, c])
    return BsrMatrix(jnp.asarray(np.stack(data), dtype),
                     jnp.asarray(rowids, jnp.int32),
                     jnp.asarray(colids, jnp.int32), nbr, nbc)


def bsr_from_coo(rows, cols, values, shape, block_m: int = 32,
                 dtype=jnp.float32) -> BsrMatrix:
    m, k = shape
    dense = np.zeros((m, k), np.float32)
    dense[rows, cols] = values
    return bsr_from_dense(dense, block_m, dtype)


def spmm(a: BsrMatrix, b, *, block_n: int = 128, n_major: bool = True,
         interpret: bool = True):
    """BSR(A) @ B. b: (K, N) with K == a.shape[1] (padding applied if short).

    Returns (a.shape[0], N) in b.dtype (fp32 accumulation inside).
    """
    k_needed = a.shape[1]
    if b.shape[0] < k_needed:
        b = jnp.pad(b, ((0, k_needed - b.shape[0]), (0, 0)))
    pad_n = (-b.shape[1]) % block_n
    if pad_n:
        b = jnp.pad(b, ((0, 0), (0, pad_n)))
    out = spmm_pallas(a.data, a.rowids, a.colids, b,
                      n_blockrows=a.n_blockrows, block_n=block_n,
                      n_major=n_major, interpret=interpret)
    return out[:, :out.shape[1] - pad_n] if pad_n else out


def sddmm(mask: BsrMatrix, b, c, *, block_k: int = 128, interpret: bool = True):
    """(B @ C) sampled at BSR(mask) -> block data aligned with mask blocks."""
    m_needed, n_needed = mask.shape
    if b.shape[0] < m_needed:
        b = jnp.pad(b, ((0, m_needed - b.shape[0]), (0, 0)))
    if c.shape[1] < n_needed:
        c = jnp.pad(c, ((0, 0), (0, n_needed - c.shape[1])))
    pad_k = (-b.shape[1]) % block_k
    if pad_k:
        b = jnp.pad(b, ((0, 0), (0, pad_k)))
        c = jnp.pad(c, ((0, pad_k), (0, 0)))
    return sddmm_pallas(mask.data, mask.rowids, mask.colids, b, c,
                        block_k=block_k, interpret=interpret)


# Reference entry points operating on the same BsrMatrix (for tests/benches).

def spmm_ref(a: BsrMatrix, b):
    k_needed = a.shape[1]
    if b.shape[0] < k_needed:
        b = jnp.pad(b, ((0, k_needed - b.shape[0]), (0, 0)))
    return ref.spmm_ref(a.data, a.rowids, a.colids, b, a.n_blockrows)


def sddmm_ref(mask: BsrMatrix, b, c):
    m_needed, n_needed = mask.shape
    if b.shape[0] < m_needed:
        b = jnp.pad(b, ((0, m_needed - b.shape[0]), (0, 0)))
    if c.shape[1] < n_needed:
        c = jnp.pad(c, ((0, 0), (0, n_needed - c.shape[1])))
    return ref.sddmm_ref(mask.data, mask.rowids, mask.colids, b, c)
