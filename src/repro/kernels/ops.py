"""Public jit'd entry points for the sparse kernels + format conversion.

``spmm`` / ``sddmm`` take a ``BsrMatrix`` (built once per sparsity pattern via
``bsr_from_dense`` / ``bsr_from_coo``) and dispatch to the Pallas kernels,
with tile parameters supplied by the caller — typically from
``repro.core.autotune.KernelAutotuner`` (the paper's technique driving real
kernel configuration).

``BsrMatrix`` and the constructors live in ``repro.kernels.format`` (the
vectorized O(nnz) path); they are re-exported here for compatibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.format import (BsrMatrix, BsrPlan, bsr_from_blocks,
                                  bsr_from_coo, bsr_from_dense, plan_from_coo)
from repro.kernels.sddmm import BW, sddmm_pallas
from repro.kernels.spmm import BK, spmm_pallas
from repro.kernels import ref


def resolve_interpret(interpret: bool = True) -> bool:
    """Resolve a requested Pallas execution mode against the actual device.

    ``interpret=False`` (compiled Mosaic) is only honoured when JAX is
    backed by a TPU; everywhere else the kernels run in Pallas interpreter
    mode, which executes the same dataflow on any backend.  Callers that
    want "compiled where possible" pass ``False`` and let this helper
    degrade gracefully on CPU-only hosts (e.g. CI containers).
    """
    if interpret:
        return True
    return jax.default_backend() != "tpu"


def spmm(a: BsrMatrix, b, *, block_n: int = 128, n_major: bool = True,
         interpret: bool = True):
    """BSR(A) @ B. b: (K, N) with K == a.shape[1] (padding applied if short).

    Returns (a.shape[0], N) in b.dtype (fp32 accumulation inside).
    """
    k_needed = a.shape[1]
    if b.shape[0] < k_needed:
        b = jnp.pad(b, ((0, k_needed - b.shape[0]), (0, 0)))
    pad_n = (-b.shape[1]) % block_n
    if pad_n:
        b = jnp.pad(b, ((0, 0), (0, pad_n)))
    out = spmm_pallas(a.data, a.rowids, a.colids, b,
                      n_blockrows=a.n_blockrows, block_n=block_n,
                      n_major=n_major, interpret=interpret)
    return out[:, :out.shape[1] - pad_n] if pad_n else out


def sddmm(mask: BsrMatrix, b, c, *, block_k: int = 128, interpret: bool = True):
    """(B @ C) sampled at BSR(mask) -> block data aligned with mask blocks."""
    m_needed, n_needed = mask.shape
    if b.shape[0] < m_needed:
        b = jnp.pad(b, ((0, m_needed - b.shape[0]), (0, 0)))
    if c.shape[1] < n_needed:
        c = jnp.pad(c, ((0, 0), (0, n_needed - c.shape[1])))
    pad_k = (-b.shape[1]) % block_k
    if pad_k:
        b = jnp.pad(b, ((0, 0), (0, pad_k)))
        c = jnp.pad(c, ((0, pad_k), (0, 0)))
    return sddmm_pallas(mask.data, mask.rowids, mask.colids, b, c,
                        block_k=block_k, interpret=interpret)


# Reference entry points operating on the same BsrMatrix (for tests/benches).

def spmm_ref(a: BsrMatrix, b):
    k_needed = a.shape[1]
    if b.shape[0] < k_needed:
        b = jnp.pad(b, ((0, k_needed - b.shape[0]), (0, 0)))
    return ref.spmm_ref(a.data, a.rowids, a.colids, b, a.n_blockrows)


def sddmm_ref(mask: BsrMatrix, b, c):
    m_needed, n_needed = mask.shape
    if b.shape[0] < m_needed:
        b = jnp.pad(b, ((0, m_needed - b.shape[0]), (0, 0)))
    if c.shape[1] < n_needed:
        c = jnp.pad(c, ((0, 0), (0, n_needed - c.shape[1])))
    return ref.sddmm_ref(mask.data, mask.rowids, mask.colids, b, c)
