"""Block-sparse SpMM Pallas TPU kernel (BSR x dense -> dense).

TPU adaptation of SPADE's tile-based SpMM dataflow (DESIGN.md §4): the sparse
operand is a flattened list of (block_m x 128) tiles sorted by block-row; the
grid walks (dense-column tile, sparse block) with the block-column indices
delivered by scalar prefetch, so only *touched* blocks are ever fetched. A
fp32 VMEM scratch accumulates each block-row's partial product and is flushed
to the output exactly once per (block-row, n-tile) — the "barrier"-like
serialization lives in the grid's arbitrary dimension semantics.

Two grid orders mirror the config-space knob tuned by the COGNATE autotuner:
  n_major=True :  grid = (n_tiles, nnzb)  — B tile reuse across a block-row
  n_major=False:  grid = (nnzb, n_tiles)  — A block fetched once, full-width
                  fp32 accumulator strip in VMEM (needs bm x N x 4 bytes)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

BK = 128  # fixed sparse-block width (TPU lane dimension)


def _spmm_kernel_nmajor(rowids, colids, a, b, out, acc, *, nnzb):
    """grid = (n_tiles, nnzb); acc: (bm, bn) fp32 scratch."""
    step = pl.program_id(1)
    row = rowids[step]
    prev_row = rowids[jnp.maximum(step - 1, 0)]
    next_row = rowids[jnp.minimum(step + 1, nnzb - 1)]
    is_first = jnp.logical_or(step == 0, prev_row != row)
    is_last = jnp.logical_or(step == nnzb - 1, next_row != row)

    @pl.when(is_first)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(a[0], b[...], preferred_element_type=jnp.float32)

    @pl.when(is_last)
    def _flush():
        out[...] = acc[...].astype(out.dtype)


def _spmm_kernel_kmajor(rowids, colids, a, b, out, acc, *, nnzb, n_tiles):
    """grid = (nnzb, n_tiles); acc: (bm, n_tiles*bn) full-width fp32 strip."""
    step = pl.program_id(0)
    ntile = pl.program_id(1)
    bn = out.shape[-1]
    row = rowids[step]
    prev_row = rowids[jnp.maximum(step - 1, 0)]
    next_row = rowids[jnp.minimum(step + 1, nnzb - 1)]
    is_first = jnp.logical_or(step == 0, prev_row != row)
    is_last = jnp.logical_or(step == nnzb - 1, next_row != row)

    sl = pl.ds(ntile * bn, bn)

    @pl.when(is_first)
    def _init():
        acc[:, sl] = jnp.zeros((acc.shape[0], bn), jnp.float32)

    partial = jnp.dot(a[0], b[...], preferred_element_type=jnp.float32)
    acc[:, sl] += partial

    @pl.when(is_last)
    def _flush():
        out[...] = acc[:, sl].astype(out.dtype)


@functools.partial(jax.jit, static_argnames=("n_blockrows", "block_n",
                                              "n_major", "interpret"))
def spmm_pallas(data, rowids, colids, b, *, n_blockrows: int,
                block_n: int = 128, n_major: bool = True,
                interpret: bool = True):
    """data (nnzb, bm, BK) x b (K, N) -> (n_blockrows*bm, N).

    rowids must be sorted ascending with every block-row represented
    (``repro.kernels.ops.bsr_from_dense`` guarantees this via pad blocks).
    ``interpret=True`` runs the kernel body on CPU (this container); on real
    TPU pass interpret=False.
    """
    nnzb, bm, bk = data.shape
    assert bk == BK, f"sparse block width must be {BK}, got {bk}"
    k, n = b.shape
    assert k % BK == 0 and n % block_n == 0, (k, n, block_n)
    n_tiles = n // block_n
    out_shape = jax.ShapeDtypeStruct((n_blockrows * bm, n), b.dtype)

    if n_major:
        grid = (n_tiles, nnzb)
        a_spec = pl.BlockSpec((1, bm, bk), lambda j, s, rows, cols: (s, 0, 0))
        b_spec = pl.BlockSpec((bk, block_n),
                              lambda j, s, rows, cols: (cols[s], j))
        o_spec = pl.BlockSpec((bm, block_n),
                              lambda j, s, rows, cols: (rows[s], j))
        kernel = functools.partial(_spmm_kernel_nmajor, nnzb=nnzb)
        scratch = [pltpu.VMEM((bm, block_n), jnp.float32)]
    else:
        grid = (nnzb, n_tiles)
        a_spec = pl.BlockSpec((1, bm, bk), lambda s, j, rows, cols: (s, 0, 0))
        b_spec = pl.BlockSpec((bk, block_n),
                              lambda s, j, rows, cols: (cols[s], j))
        o_spec = pl.BlockSpec((bm, block_n),
                              lambda s, j, rows, cols: (rows[s], j))
        kernel = functools.partial(_spmm_kernel_kmajor, nnzb=nnzb,
                                   n_tiles=n_tiles)
        scratch = [pltpu.VMEM((bm, n), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid,
        in_specs=[a_spec, b_spec], out_specs=o_spec,
        scratch_shapes=scratch)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(rowids, colids, data, b)
