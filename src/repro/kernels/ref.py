"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests).

All oracles operate on the same flattened BSR representation the kernels use:
  data    (nnzb, bm, bk)  — block values (zero-padded)
  rowids  (nnzb,)         — block-row index of each block (sorted)
  colids  (nnzb,)         — block-col index of each block
Every block-row has at least one entry (empty rows carry a zero pad block).

Beyond testing, these are also a *serving backend*: ``repro.serving.backends.
cpu_ref_backend`` registers them under the ``cpu_ref`` platform tag, so a
``SparseKernelEngine`` can route requests to a tile-parameter-free reference
path — e.g. for shadow-verifying accelerator outputs in production, or for
serving on hosts with no Pallas support at all.  They take no tile
parameters: the only structural knob is the plan's ``block_m``, fixed when
the BSR plan is built.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmm_ref(data, rowids, colids, b, n_blockrows):
    """BSR(A) @ B -> (n_blockrows*bm, N), accumulation in fp32."""
    nnzb, bm, bk = data.shape
    n = b.shape[1]
    # gather B tiles per block and contract
    b_tiles = b.reshape(-1, bk, n)[colids]                     # (nnzb, bk, N)
    partial = jnp.einsum("zik,zkn->zin", data.astype(jnp.float32),
                         b_tiles.astype(jnp.float32))          # (nnzb, bm, N)
    out = jnp.zeros((n_blockrows, bm, n), jnp.float32)
    out = out.at[rowids].add(partial)
    return out.reshape(n_blockrows * bm, n)


def sddmm_ref(mask_data, rowids, colids, b, c):
    """(B @ C) sampled at BSR(mask) -> block data (nnzb, bm, bw), fp32 accum.

    mask_data: (nnzb, bm, bw) 0/1 pattern blocks; b: (M, K); c: (K, N).
    """
    nnzb, bm, bw = mask_data.shape
    b_rows = b.reshape(-1, bm, b.shape[1])[rowids]             # (nnzb, bm, K)
    c_cols = c.reshape(c.shape[0], -1, bw)                     # (K, ncb, bw)
    c_cols = jnp.moveaxis(c_cols, 1, 0)[colids]                # (nnzb, K, bw)
    prod = jnp.einsum("zmk,zkn->zmn", b_rows.astype(jnp.float32),
                      c_cols.astype(jnp.float32))
    return prod * mask_data.astype(jnp.float32)


def bsr_to_dense(data, rowids, colids, n_blockrows, n_blockcols):
    """Debug helper: reconstruct the dense matrix from flattened BSR."""
    nnzb, bm, bk = data.shape
    dense = np.zeros((n_blockrows * bm, n_blockcols * bk), np.float32)
    for z in range(nnzb):
        r, c = int(rowids[z]), int(colids[z])
        dense[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] += np.asarray(data[z])
    return dense
