"""Fault-tolerant checkpointing for multi-pod training.

Design (per-feature rationale for 1000+ node deployments):

* **Atomic commits** — each checkpoint is staged under ``step_N.tmp`` and
  ``os.replace``d into place only after every shard file and the manifest are
  fsynced; a preempted save can never produce a torn checkpoint (restore
  simply ignores ``*.tmp``).
* **Per-host shard files** — each host writes only the leaves (or leaf
  shards) it owns (``addressable_shards``), so save bandwidth scales with
  host count and no host needs global memory. In this single-process
  container that degenerates to one file, but the layout (``shard_<i>.npz``
  + manifest) is the multi-host one.
* **Elastic restore** — the manifest stores leaf paths/shapes/dtypes, not
  device layouts. On restore, leaves are device_put against the *current*
  mesh's NamedShardings, so a job can come back on a different pod count
  (e.g. 2 pods -> 1 pod after a failure) without conversion.
* **Rolling retention** — keep the newest ``keep`` checkpoints; deletion
  happens only after a newer checkpoint is durable (crash between delete
  and commit can't lose the latest state).
* **Straggler/failure protocol** (documented contract for the launcher):
  synchronous data-parallel training restarts from the newest durable
  checkpoint on any worker loss; the deterministic, step-keyed data sharding
  in ``launch/train.py`` guarantees bit-identical batch assignment after an
  elastic restart, and hot-spare hosts can adopt a failed host's shard by
  reading the same manifest.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------- save
    def save(self, step: int, state: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        names, leaves, _ = _flatten(state)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        arrays = {}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(leaf)
            dtype = str(arr.dtype)
            if dtype == "bfloat16":      # npz has no bf16: store raw bits
                arr = arr.view(np.uint16)
            arrays[f"a{i}"] = arr
            manifest["leaves"].append(
                {"name": name, "key": f"a{i}", "shape": list(arr.shape),
                 "dtype": dtype})
        # single-process: one shard file; multi-host would write
        # shard_<process_index>.npz with only addressable leaves
        with open(tmp / "shard_0.npz", "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None) -> dict:
        """Restore into the structure of ``state_like``; if ``shardings`` is
        given (same pytree structure), leaves are placed onto the current
        mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "shard_0.npz")
        by_name = {l["name"]: data[l["key"]] for l in manifest["leaves"]}

        names, leaves, treedef = _flatten(state_like)
        shard_leaves = None
        if shardings is not None:
            _, shard_leaves, _ = _flatten(shardings)
        dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
        out = []
        for i, (name, like) in enumerate(zip(names, leaves)):
            arr = by_name[name]
            if dtypes[name] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            want_dtype = getattr(like, "dtype", arr.dtype)
            arr = np.asarray(arr).astype(want_dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
