"""Learning-rate schedules (scalar step -> lr multiplier, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return f
