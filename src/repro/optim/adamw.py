"""AdamW in pure JAX, shared by the cost-model trainer and the LM trainer.

State is a pytree mirroring the parameters: {m, v, step}. The LM trainer
shards m/v with the ZeRO-1 specs produced in ``repro.parallel.sharding``;
this module is sharding-agnostic (everything is elementwise).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float | None = 1.0
    # master dtype for moments; bf16 params keep fp32 moments
    moment_dtype: str = "float32"


def adamw_init(params, config: AdamWConfig):
    md = jnp.dtype(config.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, config: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if config.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, config.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = config.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(m.dtype)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + config.eps)
        if config.weight_decay:
            delta = delta + config.weight_decay * p.astype(m.dtype)
        p_new = (p.astype(m.dtype) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
