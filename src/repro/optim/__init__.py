from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedules import warmup_cosine, constant
