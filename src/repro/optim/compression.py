"""Int8 error-feedback gradient compression for DCN-crossing reductions.

At multi-pod scale the pod-axis gradient all-reduce crosses the data-center
network (25-100x less bandwidth than ICI). Compressing gradients to int8 with
error feedback (residual carried to the next step) cuts DCN bytes 4x with no
asymptotic convergence penalty (Seide et al. 2014; Karimireddy et al. 2019).

Usage inside a train step (pod axis only):

    comp, new_resid = compress(grads, residual)
    comp = jax.lax.psum(comp, 'pod')            # int8 wire traffic
    grads = decompress(comp, scale)             # back to fp

The quantizer is per-tensor symmetric: q = round(g / s * 127), s = max|g|.
``make_compressed_psum`` wires it for shard_map-based pod reductions; under
plain GSPMD jit the compression is applied pre/post the automatic all-reduce
(bytes saving is then advisory — recorded for the roofline, since GSPMD
chooses the reduction dtype). Round-trip error is bounded by s/127 per step
and carried forward by the residual, which tests verify decays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(g, resid):
    g32 = g.astype(jnp.float32) + (resid if resid is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
    q = jnp.clip(jnp.round(g32 / scale * 127.0), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * (scale / 127.0)
    return q, scale, g32 - deq


def compress(grads, residuals=None):
    """pytree of grads (+ optional residuals) -> (int8 tree, scales tree,
    new residuals tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    rleaves = (treedef.flatten_up_to(residuals) if residuals is not None
               else [None] * len(leaves))
    qs, scales, resids = [], [], []
    for g, r in zip(leaves, rleaves):
        q, s, res = _q(g, r)
        qs.append(q)
        scales.append(s)
        resids.append(res)
    un = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    return un(qs), un(scales), un(resids)


def decompress(qtree, scales, n_workers: int = 1):
    """int8 sums back to fp32 means. After psum of int8 (promoted to int32 by
    the reduction), divide by worker count for the gradient mean."""
    def deq(q, s):
        return q.astype(jnp.float32) * (s / 127.0) / n_workers
    return jax.tree_util.tree_map(deq, qtree, scales)


def make_compressed_psum(axis_name: str):
    """Returns psum_compressed(grads, residuals) for use under shard_map:
    int8 wire traffic on ``axis_name``, error feedback maintained.

    All workers must quantize against the SAME scale for the int8 sum to be
    meaningful, so the per-tensor absmax is pmax'd first (a scalar per tensor
    — negligible wire cost) before quantization."""
    def psum_compressed(grads, residuals):
        n = jax.lax.psum(1, axis_name)

        def leaf(g, r):
            g32 = g.astype(jnp.float32) + r.astype(jnp.float32)
            scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12),
                                 axis_name)
            q = jnp.clip(jnp.round(g32 / scale * 127.0), -127, 127
                         ).astype(jnp.int8)
            resid = g32 - q.astype(jnp.float32) * (scale / 127.0)
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            mean = total.astype(jnp.float32) * (scale / 127.0) / n
            return mean, resid

        pairs = jax.tree_util.tree_map(leaf, grads, residuals)
        means = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        resids = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))
        return means, resids
    return psum_compressed
