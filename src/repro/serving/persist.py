"""Autotune-cache persistence — warm-start serving across restarts.

Serializes a populated ``AutotuneCache`` (digest -> tuned config + the
``BsrPlan`` block structure) to a single ``.npz`` next to model checkpoints,
using the same atomic-commit discipline as ``repro.checkpoint.manager``:
write to ``<path>.tmp``, flush + fsync, then ``os.replace`` into place — a
preempted save can never produce a torn cache file, and ``os.replace`` over
an existing file makes repeated saves safe.

Restore is strictly best-effort: any defect (missing file, truncated/garbled
npz, version mismatch, inconsistent arrays) logs and returns ``None`` so the
caller starts cold instead of crashing — a serving process must come up even
when its cache file was torn by the failure that restarted it.

Storing the plan's scatter arrays (not just the config) means a warm-started
pattern pays *neither* featurization *nor* the coordinate sort: first request
after restart is already the steady-state O(nnz) value scatter.
"""
from __future__ import annotations

import json
import os
import warnings
from pathlib import Path

import numpy as np

from repro.core.autotune import AutotuneCache, KernelAutotuner, TunedKernel
from repro.kernels.format import BsrPlan
from repro.kernels.spmm import BK

__all__ = ["CACHE_FORMAT_VERSION", "save_cache", "load_cache", "warm_start"]

CACHE_FORMAT_VERSION = 1

_PLAN_ARRAYS = ("rowids", "colids", "take", "slot", "rloc", "cloc")


def save_cache(cache: AutotuneCache, path: str | os.PathLike) -> Path:
    """Atomically write ``cache`` to ``path`` (a ``.npz`` file)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    entries = cache.items()
    manifest = {"version": CACHE_FORMAT_VERSION, "entries": []}
    arrays = {}
    for i, ((op, digest), e) in enumerate(entries):
        plan = e.plan
        manifest["entries"].append({
            "op": op, "digest": digest, "config": e.config,
            "n_blockrows": plan.n_blockrows, "n_blockcols": plan.n_blockcols,
            "block_m": plan.block_m,
        })
        for name in _PLAN_ARRAYS:
            arrays[f"e{i}_{name}"] = getattr(plan, name)
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), np.uint8)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)               # atomic commit
    return path


def load_cache(path: str | os.PathLike) -> list[tuple[tuple, TunedKernel]] | None:
    """Read a persisted cache -> [(key, entry), ...] in saved (LRU) order.

    Returns ``None`` on *any* failure — absent file, torn/corrupted bytes,
    unknown format version, internally inconsistent arrays — so callers fall
    back to a cold cache."""
    path = Path(path)
    try:
        with np.load(path) as data:
            manifest = json.loads(bytes(data["manifest"]).decode())
            if manifest.get("version") != CACHE_FORMAT_VERSION:
                raise ValueError(
                    f"unsupported cache version {manifest.get('version')}")
            out = []
            for i, m in enumerate(manifest["entries"]):
                arrs = {name: data[f"e{i}_{name}"] for name in _PLAN_ARRAYS}
                n_entries = arrs["take"].shape[0]
                for name in _PLAN_ARRAYS[2:]:
                    if arrs[name].shape[0] != n_entries:
                        raise ValueError(f"entry {i}: ragged plan arrays")
                if arrs["rowids"].shape != arrs["colids"].shape:
                    raise ValueError(f"entry {i}: ragged block ids")
                nnzb = arrs["rowids"].shape[0]
                if n_entries and (
                        arrs["slot"].min() < 0
                        or arrs["slot"].max() >= nnzb
                        or arrs["take"].min() < 0
                        or arrs["rloc"].min() < 0
                        or arrs["rloc"].max() >= int(m["block_m"])
                        or arrs["cloc"].min() < 0
                        or arrs["cloc"].max() >= BK):
                    raise ValueError(f"entry {i}: scatter index out of range")
                plan = BsrPlan(n_blockrows=int(m["n_blockrows"]),
                               n_blockcols=int(m["n_blockcols"]),
                               block_m=int(m["block_m"]), **arrs)
                entry = TunedKernel(m["digest"], m["op"],
                                    dict(m["config"]), plan)
                out.append(((m["op"], m["digest"]), entry))
            return out
    except FileNotFoundError:
        return None
    except Exception as e:             # torn file, bad json, bad zip, ...
        warnings.warn(f"autotune cache at {path} unreadable "
                      f"({type(e).__name__}: {e}); starting cold")
        return None


def warm_start(tuner: KernelAutotuner, path: str | os.PathLike) -> int:
    """Populate ``tuner``'s cache from a persisted file.  Returns the number
    of entries restored (0 on a cold/corrupted file).  Restored entries do
    not count as featurizations or cache misses."""
    loaded = load_cache(path)
    if not loaded:
        return 0
    for key, entry in loaded:
        tuner.cache.put(key, entry)
    return len(loaded)
