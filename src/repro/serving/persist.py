"""Autotune-cache persistence — warm-start serving across restarts.

Serializes populated ``AutotuneCache``s (digest -> tuned config + the
``BsrPlan`` block structure) to a single ``.npz`` next to model checkpoints,
using the same atomic-commit discipline as ``repro.checkpoint.manager``:
write to ``<path>.tmp``, flush + fsync, then ``os.replace`` into place — a
preempted save can never produce a torn cache file, and ``os.replace`` over
an existing file makes repeated saves safe.

**Backend namespacing (format version 2).**  One file holds the caches of
*every* backend an engine fronts: each manifest entry carries the backend's
platform tag (``"tpu_pallas"``, ``"cpu_ref"``, ...) alongside its
``(op, digest)`` key, so a multi-backend engine restores each backend's
entries into that backend's own cache with one load.  Entries without a tag
— version-1 files (the pre-registry single-backend format) and tag-less
``save_cache`` output — surface under the ``LEGACY_NAMESPACE`` key and the
restoring engine maps them to its *own* default backend.
Entries whose tag no backend claims, or whose arrays fail validation
(shape, index range, or a scatter-array dtype that doesn't match the plan
layout — a defect that would otherwise surface only at first scatter), are
*individually* skipped (counted in ``GroupedCacheLoad.skipped``) — one bad
or orphaned entry never costs the rest of the file.

**Per-entry checksums + quarantine (format version 4).**  Each manifest
entry carries a CRC32 over the entry's plan arrays (scatter arrays +
device index), verified at load: the zip layer's member CRCs catch rot
*within* one stored array, but only an entry-level checksum catches
arrays swapped between entries or a manifest re-pointed at the wrong
member — corruption the structural checks can miss.  A file that fails
to load — wholesale, or any individual entry — can be **quarantined**
(``load_grouped(..., quarantine=True)``, what the engine's warm-start
passes): an unreadable file is renamed to ``<path>.corrupt`` and a file
with bad entries is copied there (the good entries keep serving), so
corruption is preserved as evidence and counted
(``stats()["persist_quarantined"]``), never silently dropped.  Saves
additionally fsync the parent directory after the atomic rename, so the
commit itself survives power loss.  Version 3/2/1 files still restore
(no CRC to check).

**Device index arrays (format version 3).**  Each entry additionally
carries the plan's flattened device-scatter index (``BsrPlan.flat_index``
— the scatter half of the jitted device build path).  At load it is
checked for consistency against the scatter arrays it derives from (an
in-range but *wrong* index would mis-scatter silently, and only on the
device path), so the flatten cost is folded into load-time validation —
the restored plan is device-ready, and its first device build on the
serving path is already the steady-state single jitted dispatch.
Version-2 and version-1 files still restore (the index is recomputed
lazily on first device build).

Restore is strictly best-effort: a structurally unreadable file (missing,
truncated/garbled npz, unknown version) logs and returns ``None`` so the
caller starts cold instead of crashing — a serving process must come up even
when its cache file was torn by the failure that restarted it.

Storing the plan's scatter arrays (not just the config) means a warm-started
pattern pays *neither* featurization *nor* the coordinate sort: first request
after restart is already the steady-state O(nnz) value scatter.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import warnings
import zlib
from pathlib import Path

import numpy as np

from repro.core.autotune import AutotuneCache, KernelAutotuner, TunedKernel
from repro.kernels.format import BsrPlan
from repro.kernels.spmm import BK

__all__ = ["CACHE_FORMAT_VERSION", "LEGACY_NAMESPACE", "GroupedCacheLoad",
           "save_cache", "save_backends", "load_cache", "load_grouped",
           "warm_start"]

CACHE_FORMAT_VERSION = 4

#: Namespace key ``load_grouped`` files version-1 (pre-tag) entries under;
#: callers route it to their default backend.
LEGACY_NAMESPACE = None

_PLAN_ARRAYS = ("rowids", "colids", "take", "slot", "rloc", "cloc")

#: The plan layout's scatter-array dtypes — validated at load so a file
#: whose arrays were tampered with (or written by foreign code) is skipped
#: at restore instead of failing at first scatter.
_PLAN_DTYPES = {"rowids": np.int32, "colids": np.int32, "take": np.int32,
                "slot": np.int32, "rloc": np.int16, "cloc": np.int16}


@dataclasses.dataclass
class GroupedCacheLoad:
    """Result of ``load_grouped``: per-namespace entries + skip accounting.

    ``entries`` maps a platform tag (or ``LEGACY_NAMESPACE`` for
    unnamespaced entries: version-1 files and tag-less ``save_cache``
    output) to ``[((op, digest), TunedKernel), ...]`` in saved (LRU) order.
    ``skipped`` counts individually-invalid entries dropped during load.
    """
    entries: dict
    skipped: int = 0
    #: True when corrupt entries were found and the file was copied to
    #: ``<path>.corrupt`` (``quarantine=True`` loads only)
    quarantined: bool = False

    def __len__(self):
        return sum(len(v) for v in self.entries.values())


def _flat_entries(grouped: dict) -> list[tuple]:
    """{tag: cache | [caches]} -> [(tag, (op, digest), entry), ...]."""
    flat = []
    for tag, caches in grouped.items():
        if isinstance(caches, AutotuneCache):
            caches = [caches]
        for cache in caches:
            for key, e in cache.items():
                flat.append((tag, key, e))
    return flat


def _atomic_savez(path: Path, arrays: dict) -> Path:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)               # atomic commit
    # fsync the directory too: os.replace orders the rename against the
    # file's data, but the *directory entry* itself can still be lost on
    # power failure without this — then the save never happened
    try:
        dfd = os.open(path.parent, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:                     # platforms without dir fsync
        pass
    return path


def _entry_crc(arrs: dict, dindex) -> int:
    """CRC32 over one entry's plan arrays (+ device index), in layout
    order — the v4 cross-array integrity check."""
    crc = 0
    for name in _PLAN_ARRAYS:
        crc = zlib.crc32(np.ascontiguousarray(arrs[name]).tobytes(), crc)
    if dindex is not None:
        crc = zlib.crc32(np.ascontiguousarray(dindex).tobytes(), crc)
    return crc


def _serialize(flat: list[tuple], path: Path, version: int) -> Path:
    """[(tag, (op, digest), entry), ...] -> atomically committed ``.npz``.
    ``version=1`` omits the per-entry backend tag (the legacy format);
    ``version=2`` omits the device-scatter index arrays; ``version=3``
    omits the per-entry CRC32."""
    manifest = {"version": version, "entries": []}
    arrays = {}
    for i, (tag, (op, digest), e) in enumerate(flat):
        plan = e.plan
        m = {"op": op, "digest": digest, "config": e.config,
             "n_blockrows": plan.n_blockrows,
             "n_blockcols": plan.n_blockcols, "block_m": plan.block_m}
        if version >= 2:
            m["backend"] = tag
        manifest["entries"].append(m)
        for name in _PLAN_ARRAYS:
            arrays[f"e{i}_{name}"] = getattr(plan, name)
        if version >= 3:
            arrays[f"e{i}_dindex"] = plan.flat_index()
        if version >= 4:
            m["crc"] = _entry_crc(
                {name: arrays[f"e{i}_{name}"] for name in _PLAN_ARRAYS},
                arrays[f"e{i}_dindex"])
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), np.uint8)
    return _atomic_savez(path, arrays)


def save_backends(grouped, path: str | os.PathLike, *,
                  version: int = CACHE_FORMAT_VERSION) -> Path:
    """Atomically write every backend's cache to one namespaced ``.npz``.

    ``grouped`` is ``{platform_tag: AutotuneCache | [AutotuneCache, ...]}``
    (the shape ``BackendRegistry.caches_by_platform`` returns) — a backend
    registry itself also works.  Entries keep their in-cache ``(op, digest)``
    keys; the platform tag is recorded per entry in the manifest.
    ``version=2`` writes the pre-device-index byte layout (compatibility
    tests / older readers).
    """
    if version not in (2, 3, CACHE_FORMAT_VERSION):
        raise ValueError(f"save_backends writes version 2, 3, or "
                         f"{CACHE_FORMAT_VERSION}, not {version}")
    if hasattr(grouped, "caches_by_platform"):      # a BackendRegistry
        grouped = grouped.caches_by_platform()
    return _serialize(_flat_entries(grouped), Path(path), version)


def save_cache(cache: AutotuneCache, path: str | os.PathLike,
               backend: str | None = None, *, version: int | None = None
               ) -> Path:
    """Atomically write a single cache to ``path`` (a ``.npz`` file).

    With ``backend`` given, entries are namespaced under that platform tag.
    Without it they are written *unnamespaced* (like legacy files), so a
    restoring engine maps them to its **own** default platform, whatever
    that is — exactly how pre-registry round-trips behaved.  ``version=1``
    writes the legacy single-backend format byte-layout — useful for
    compatibility tests and for producing files consumable by older code;
    ``version=2`` the pre-device-index namespaced layout.
    """
    if version == 1:
        return _serialize([(None, key, e) for key, e in cache.items()],
                          Path(path), 1)
    return save_backends({backend: cache}, path,
                         version=version or CACHE_FORMAT_VERSION)


def _decode_entry(data, i: int, m: dict) -> tuple:
    """One manifest entry -> ((op, digest), TunedKernel); raises on defects."""
    arrs = {name: data[f"e{i}_{name}"] for name in _PLAN_ARRAYS}
    dindex = data[f"e{i}_dindex"] if f"e{i}_dindex" in data else None
    if "crc" in m:                      # v4: entry-level integrity check
        got = _entry_crc(arrs, dindex)
        if got != int(m["crc"]):
            raise ValueError(f"entry {i}: CRC mismatch "
                             f"(manifest {int(m['crc'])}, arrays {got})")
    for name, want in _PLAN_DTYPES.items():
        # a wrong-dtype scatter array would restore fine and then fail (or
        # silently mis-scatter) on the entry's first build — reject it here
        if arrs[name].dtype != np.dtype(want):
            raise ValueError(f"entry {i}: {name} dtype {arrs[name].dtype} "
                             f"!= {np.dtype(want)}")
    n_entries = arrs["take"].shape[0]
    for name in _PLAN_ARRAYS[2:]:
        if arrs[name].shape[0] != n_entries:
            raise ValueError(f"entry {i}: ragged plan arrays")
    if arrs["rowids"].shape != arrs["colids"].shape:
        raise ValueError(f"entry {i}: ragged block ids")
    nnzb = arrs["rowids"].shape[0]
    if n_entries and (
            arrs["slot"].min() < 0
            or arrs["slot"].max() >= nnzb
            or arrs["take"].min() < 0
            or arrs["rloc"].min() < 0
            or arrs["rloc"].max() >= int(m["block_m"])
            or arrs["cloc"].min() < 0
            or arrs["cloc"].max() >= BK):
        raise ValueError(f"entry {i}: scatter index out of range")
    plan = BsrPlan(n_blockrows=int(m["n_blockrows"]),
                   n_blockcols=int(m["n_blockcols"]),
                   block_m=int(m["block_m"]), **arrs)
    if dindex is not None:      # v3+: restored device-scatter index
        # an in-range but *wrong* index would silently mis-scatter on the
        # device path only — validate against the (already range-checked)
        # scatter arrays it is derived from, not just its bounds
        want = (arrs["slot"].astype(np.int64) * int(m["block_m"])
                + arrs["rloc"].astype(np.int64)) * BK \
            + arrs["cloc"].astype(np.int64)
        if (dindex.dtype not in (np.int32, np.int64)
                or dindex.shape != want.shape
                or not np.array_equal(dindex, want)):
            raise ValueError(f"entry {i}: device scatter index inconsistent "
                             f"with plan arrays")
        plan._flat = dindex
    entry = TunedKernel(m["digest"], m["op"], dict(m["config"]), plan)
    return (m["op"], m["digest"]), entry


def _quarantine_file(path: Path, *, rename: bool) -> bool:
    """Preserve a corrupt cache file as ``<path>.corrupt`` (evidence,
    never silently dropped).  ``rename=True`` moves the file out of the
    way (wholesale-unreadable — nothing left worth serving);
    ``rename=False`` copies it (some entries were good and the original
    keeps serving them).  Best-effort: returns whether it happened."""
    target = path.with_name(path.name + ".corrupt")
    try:
        if rename:
            os.replace(path, target)
        else:
            shutil.copyfile(path, target)
            # the copy is evidence — fsync it like every other persistence
            # write path, so the quarantine itself survives power loss
            fd = os.open(target, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        warnings.warn(f"autotune cache quarantined to {target}")
        return True
    except OSError:
        return False


def load_grouped(path: str | os.PathLike, *, quarantine: bool = False,
                 on_event=None) -> GroupedCacheLoad | None:
    """Read a persisted cache file into per-backend namespaces.

    Version-2/3/4 entries land under their recorded platform tag (version
    3 additionally restores each plan's device-scatter index; version 4
    additionally verifies a per-entry CRC32); version-1 entries (no tags)
    land under ``LEGACY_NAMESPACE``.  Individually broken entries — CRC
    mismatches, ragged or out-of-range arrays, scatter dtypes that don't
    match the plan layout — are dropped and counted in ``.skipped``
    (versions >= 2) — the rest of the file still loads.  Returns ``None``
    only when the file as a whole is unreadable (absent, torn zip, bad
    manifest, unknown version), so callers fall back to a cold cache.

    With ``quarantine=True`` (what the engine's warm-start passes), a
    wholesale-unreadable file is renamed to ``<path>.corrupt`` and a file
    with skipped entries is copied there (``.quarantined`` set on the
    result) — corruption is preserved as evidence, never silently
    dropped.

    ``on_event`` is an optional structured-event callback ``(kind,
    **fields) -> None`` (e.g. ``EventLog.emit``): it receives one
    ``persist_entry_skipped`` event per dropped entry and one
    ``persist_quarantined`` event per quarantined file — how the
    engine's event log sees persistence trouble.  Callback errors are
    swallowed; observability must never break a load."""
    path = Path(path)

    def _emit(kind: str, **fields) -> None:
        if on_event is not None:
            try:
                on_event(kind, path=str(path), **fields)
            except Exception:
                pass

    try:
        with np.load(path) as data:
            manifest = json.loads(bytes(data["manifest"]).decode())
            version = manifest.get("version")
            if version not in (1, 2, 3, CACHE_FORMAT_VERSION):
                raise ValueError(f"unsupported cache version {version}")
            out = GroupedCacheLoad(entries={})
            for i, m in enumerate(manifest["entries"]):
                tag = m.get("backend") if version >= 2 else LEGACY_NAMESPACE
                try:
                    key, entry = _decode_entry(data, i, m)
                except Exception as e:
                    if version == 1:    # legacy: keep whole-file semantics
                        raise
                    warnings.warn(f"autotune cache at {path}: skipping "
                                  f"entry {i} ({e})")
                    _emit("persist_entry_skipped", entry=i, error=str(e))
                    out.skipped += 1
                    continue
                out.entries.setdefault(tag, []).append((key, entry))
        if out.skipped and quarantine:
            out.quarantined = _quarantine_file(path, rename=False)
            if out.quarantined:
                _emit("persist_quarantined", wholesale=False,
                      skipped=out.skipped)
        return out
    except FileNotFoundError:
        return None
    except Exception as e:             # torn file, bad json, bad zip, ...
        warnings.warn(f"autotune cache at {path} unreadable "
                      f"({type(e).__name__}: {e}); starting cold")
        _emit("persist_load_failure", error=f"{type(e).__name__}: {e}")
        if quarantine:
            if _quarantine_file(path, rename=True):
                _emit("persist_quarantined", wholesale=True)
        return None


def load_cache(path: str | os.PathLike, backend: str | None = None
               ) -> list[tuple[tuple, TunedKernel]] | None:
    """Read one backend's entries -> [(key, entry), ...] in saved order.

    An explicit ``backend`` returns *only* that platform's namespace —
    legacy/unnamespaced entries are excluded, because they carry no claim
    about which backend tuned them.  ``backend=None`` selects the default
    namespace: unnamespaced entries (legacy version-1 files and tag-less
    ``save_cache`` output) plus anything saved under the stock default
    platform — exactly what pre-registry ``save_cache``/``load_cache``
    round-trips produced.  Returns ``None`` when the file is unreadable
    (callers start cold)."""
    grouped = load_grouped(path)
    if grouped is None:
        return None
    if backend is not None:
        return list(grouped.entries.get(backend, []))
    from repro.serving.backends import DEFAULT_PLATFORM
    return (grouped.entries.get(LEGACY_NAMESPACE, [])
            + grouped.entries.get(DEFAULT_PLATFORM, []))


def warm_start(tuner: KernelAutotuner, path: str | os.PathLike,
               backend: str | None = None) -> int:
    """Populate one ``tuner``'s cache from a persisted file (the default
    namespace unless ``backend`` names another).  Returns the number of
    entries restored (0 on a cold/corrupted file).  Restored entries do not
    count as featurizations or cache misses.  Multi-backend engines restore
    through ``SparseKernelEngine(persist_path=...)`` instead, which routes
    every namespace to its registered backend."""
    loaded = load_cache(path, backend)
    if not loaded:
        return 0
    for key, entry in loaded:
        tuner.cache.put(key, entry)
    return len(loaded)
