"""``repro.serving`` — the sparse-kernel serving runtime.

COGNATE's deployment loop (featurize a sparsity pattern -> score program
configurations with the transferred cost model -> launch the tuned Pallas
kernel) is O(nnz) per request after PR 1, but production traffic is
*batched, repetitive, and restartable*.  This subsystem owns that layer:

* ``engine`` — ``SparseKernelEngine``: accepts a micro-batch of
  ``KernelRequest`` (pattern, values, op[, dense operand]) per ``step``;
  partitions it into cache hits and misses against the pattern-keyed LRU,
  featurizes + scores **all** misses in one ``Autotuner.scores_batch``
  dispatch (``KernelAutotuner.get_batch``), builds each request through a
  double-buffered plan arena, and optionally executes the Pallas kernel with
  the tuned tile config.  ``stats()`` renders the full telemetry picture.
* ``arena`` — ``PlanArena``: a two-slot (configurable) rotation of BSR
  scatter buffers per cached pattern, generalizing
  ``BsrPlan.build(reuse=True)``.  Batch N+1's host-side scatter overlaps
  batch N's in-flight kernel; slot-generation leases guarantee an alias is
  never overwritten while referenced (exhaustion raises ``ArenaOverrun`` and
  the engine falls back to an un-aliased build).
* ``persist`` — atomic single-file serialization of the autotune cache
  (digest -> tile config + BSR block structure) next to model checkpoints,
  with the same commit discipline as ``repro.checkpoint.manager``.  A
  serving restart warm-starts known traffic with **zero** featurizations and
  zero coordinate sorts; torn or corrupted files fall back to a cold cache.
* ``telemetry`` — hit rates, per-stage latency histograms (log-bucketed
  p50/p99), eviction and arena-overflow counters.

Typical use::

    from repro.serving import KernelRequest, SparseKernelEngine

    engine = SparseKernelEngine(tuner, persist_path="ckpt/autotune.npz")
    for batch in traffic:                    # micro-batches of requests
        responses = engine.step([KernelRequest(mat, values, "spmm", rhs)
                                 for mat, values, rhs in batch])
    engine.save()                            # warm-start the next restart

``benchmarks/serving_engine.py`` measures steady-state requests/sec and
p50/p99 against the one-pattern-at-a-time loop; ``examples/
moe_kernel_serving.py`` drives the engine with MoE dispatch traffic.  This
is the seam later scaling work (multi-backend dispatch, sharded serving)
plugs into.
"""
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.engine import (KernelRequest, KernelResponse,
                                  SparseKernelEngine)
from repro.serving.persist import (CACHE_FORMAT_VERSION, load_cache,
                                   save_cache, warm_start)
from repro.serving.telemetry import EngineTelemetry, LatencyHistogram

__all__ = ["SparseKernelEngine", "KernelRequest", "KernelResponse",
           "PlanArena", "ArenaLease", "ArenaOverrun",
           "save_cache", "load_cache", "warm_start", "CACHE_FORMAT_VERSION",
           "EngineTelemetry", "LatencyHistogram"]
