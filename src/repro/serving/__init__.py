"""``repro.serving`` — the sparse-kernel serving runtime.

COGNATE's deployment loop (featurize a sparsity pattern -> score program
configurations with the transferred cost model -> launch the tuned kernel)
is O(nnz) per request after PR 1, but production traffic is *batched,
repetitive, restartable — and heterogeneous across hardware*.  This
subsystem owns that layer:

* ``engine`` — ``SparseKernelEngine``: accepts a micro-batch of
  ``KernelRequest`` (pattern, values, op[, dense operand][, platform tag])
  per ``step`` and serves it through an explicit staged pipeline (route ->
  partition -> score -> build -> execute -> account): the router decides
  each request's backend, the batch partitions per tag, each backend's
  cache misses featurize + score in one ``Autotuner.scores_batch``
  dispatch (``KernelAutotuner.get_batch``), values build through a
  double-buffered plan arena, and kernels launch with the tuned tile
  config.  ``stats()`` renders the full telemetry picture, including
  per-backend, routing, and load sections.
* ``router`` — the routing policies: ``StaticRouter`` (explicit tags /
  default platform — the default), ``CostModelRouter`` (scores untagged
  patterns against every candidate backend's config space in one batched
  dispatch and places them on the argmin predicted cost, calibrated
  online against observed latencies), and ``LoadAwareRouter`` (spills a
  saturated backend's overflow to a fallback).  Any object implementing
  the ``Router`` protocol plugs into ``SparseKernelEngine(router=...)``.
* ``backends`` — ``BackendRegistry``: maps ``(platform, op)`` tags to
  {kernel executor, ``KernelAutotuner``, config space, live load} bundles.
  Ships ``tpu_pallas`` (compiled; degrades to interpreter off-TPU),
  ``tpu_interpret``, and ``cpu_ref`` (the pure-jnp reference) — one engine
  fronts them all, each with an isolated cache.
* ``arena`` — ``PlanArena``: a two-slot (configurable) rotation of BSR
  scatter buffers per cached pattern, generalizing
  ``BsrPlan.build(reuse=True)``.  Each slot carries a host buffer (numpy
  scatter) and a device buffer (jitted scatter, steady state donated in
  place — the path device-resident values take with zero host numpy).
  Batch N+1's scatter overlaps batch N's in-flight kernel — kernel
  launches stay asynchronous and ``SparseKernelEngine.drain()`` is the
  synchronous point; slot-generation leases guarantee an alias is
  never overwritten while referenced (exhaustion raises ``ArenaOverrun`` and
  the engine falls back to an un-aliased build).
* ``persist`` — atomic single-file serialization of every backend's autotune
  cache (platform-tag-namespaced digest -> tile config + BSR block
  structure) next to model checkpoints, with the same commit discipline as
  ``repro.checkpoint.manager``.  A serving restart warm-starts known traffic
  on every backend with **zero** featurizations and zero coordinate sorts;
  legacy single-backend files restore the default platform; torn or
  corrupted files fall back to a cold cache.
* ``telemetry`` — hit rates, per-stage and per-backend latency histograms
  (log-bucketed p50/p99), routing-decision counters, per-platform
  observed-vs-predicted latency calibration (``RouteCalibration`` — what
  keeps cost-model routing honest), eviction and arena-overflow counters.
* ``health`` — per-``(platform, op)`` ``BackendHealth`` (rolling
  success/failure/latency windows) behind a three-state circuit breaker
  (closed -> open -> half-open probe with escalating backoff), fed from
  the engine's execute/account stages; a failing backend's traffic
  fast-fails into the retry lane (failover to the healthiest surviving
  candidate, ``cpu_ref`` as the stock floor) instead of aborting the
  batch.  ``stats()["health"]`` renders it all.
* ``trace`` — per-request observability: ``Span``/``Trace`` span trees
  stamped with trace IDs, the ``FlightRecorder`` (head-sampled main ring +
  an always-retained error ring for degraded/failed-over requests — tail
  retention never loses an incident to sampling), and the ``EventLog``
  (bounded structured events: breaker transitions, failovers, quarantines,
  warm starts, drains — exportable as JSONL).  ``engine.traces()`` /
  ``engine.events`` / ``stats()["tracing"]``.
* ``export`` — machine-readable views: ``prometheus_text`` (full text
  exposition incl. histogram buckets + the calibration drift gauge, with
  ``parse_prometheus_text`` as the validating minimal parser),
  ``chrome_trace`` (Perfetto-loadable span timelines where generation
  windows make the async run-ahead visible), and ``stats_delta``
  (windowed req/s + hit-rate between two ``stats()`` snapshots;
  ``engine.stats_delta()`` keeps the previous snapshot for you).
* ``shard`` — horizontal scale: ``ShardedEngine`` fronts N engine
  replicas behind a consistent-hash ring (``HashRing``, virtual nodes +
  bounded-load overflow to the ring successor) keyed on pattern digest,
  each replica on its own serving thread and mesh device slot.  Cache
  capacity, autotune throughput, and build bandwidth scale with replica
  count; replica add/remove re-homes only the digests whose ring
  ownership moved (cache rows migrate warm via the persistence
  namespaces), and one merged cache file warm-starts any layout.  A
  ``ReplicaSupervisor`` watches per-replica serving-thread heartbeats —
  a hung or crashed replica is quarantined off the ring (warm state
  re-homed to the survivors), its in-flight sub-batch re-dispatched
  (``step_timeout_s``), and re-admitted after a probation probe;
  ``close()`` is the graceful shutdown (drain, save, join every thread).
* ``admission`` — the open-loop front door: a bounded ``AdmissionQueue``
  callers ``submit(request, deadline_ms, priority)`` into for an
  ``AdmissionTicket`` future.  A batcher thread forms SLO-aware batches
  (sized from the ``"step"`` histograms + ``BackendLoad``), expired
  requests complete ``deadline_exceeded`` without touching the pipeline,
  and over the high-watermark the queue sheds lowest-priority-first
  instead of blocking producers — every submit resolves, none block,
  none are lost.
* ``faults`` — a deterministic, seedable fault-injection harness
  (``FaultPlan``: raise-on-nth-call windows, NaN outputs, latency
  spikes, hangs held until released, serving-thread crashes
  (``ReplicaCrash``), plus torn-write/bit-rot helpers for persistence
  files) that wraps any registered backend's executor in place — what
  the fault-tolerance tests, the supervisor watchdog tests, and
  ``benchmarks/serving_faults.py`` drive.

Typical use::

    from repro.serving import KernelRequest, SparseKernelEngine

    engine = SparseKernelEngine(tuner, persist_path="ckpt/autotune.npz")
    for batch in traffic:                    # micro-batches of requests
        responses = engine.step(
            [KernelRequest(mat, values, "spmm", rhs, platform=tag)
             for mat, values, rhs, tag in batch])
    engine.save()                            # warm-start the next restart

``benchmarks/serving_engine.py`` measures steady-state requests/sec and
p50/p99 against the one-pattern-at-a-time loop, including a mixed-platform
scenario driving all three stock backends through one ``step()`` stream;
``benchmarks/serving_routing.py`` compares the routing policies on
identical untagged traffic (per-backend share, spills, p50/p99);
``examples/moe_kernel_serving.py`` drives the engine with MoE dispatch
traffic, routes untagged traffic through ``CostModelRouter``, and
shadow-verifies on ``cpu_ref``.  See ``docs/serving.md`` for the full
request lifecycle, routing policies, persistence format, and how to add a
backend.
"""
from repro.serving.admission import (AdmissionQueue, AdmissionTicket,
                                     DeadlineExceededError, QueueClosed,
                                     ShedError)
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.backends import (DEFAULT_PLATFORM, BackendLoad,
                                    BackendRegistry, KernelBackend,
                                    cpu_ref_backend, default_registry,
                                    pallas_backend)
from repro.serving.engine import (KernelRequest, KernelResponse,
                                  OutputGuardError, SparseKernelEngine)
from repro.serving.export import (admission_prometheus_text, chrome_trace,
                                  parse_prometheus_text, prom_get,
                                  prometheus_text, stats_delta)
from repro.serving.faults import (FaultPlan, FaultWindow, FaultyExecutor,
                                  InjectedFault, ReplicaCrash, flip_byte,
                                  inject_faults, truncate_file)
from repro.serving.health import (BackendHealth, HealthConfig,
                                  HealthRegistry)
from repro.serving.persist import (CACHE_FORMAT_VERSION, GroupedCacheLoad,
                                   LEGACY_NAMESPACE, load_cache,
                                   load_grouped, save_backends, save_cache,
                                   warm_start)
from repro.serving.shard import HashRing, ReplicaSupervisor, ShardedEngine
from repro.serving.router import (CostModelRouter, LoadAwareRouter,
                                  RouteDecision, Router, RoutingContext,
                                  StaticRouter)
from repro.serving.telemetry import (EngineTelemetry, LatencyHistogram,
                                     RouteCalibration)
from repro.serving.trace import EventLog, FlightRecorder, Span, Trace

__all__ = ["SparseKernelEngine", "KernelRequest", "KernelResponse",
           "BackendRegistry", "KernelBackend", "BackendLoad",
           "DEFAULT_PLATFORM",
           "pallas_backend", "cpu_ref_backend", "default_registry",
           "Router", "RouteDecision", "RoutingContext", "StaticRouter",
           "CostModelRouter", "LoadAwareRouter",
           "PlanArena", "ArenaLease", "ArenaOverrun",
           "save_cache", "save_backends", "load_cache", "load_grouped",
           "warm_start", "CACHE_FORMAT_VERSION", "LEGACY_NAMESPACE",
           "GroupedCacheLoad", "EngineTelemetry", "LatencyHistogram",
           "RouteCalibration",
           "BackendHealth", "HealthConfig", "HealthRegistry",
           "OutputGuardError",
           "HashRing", "ShardedEngine", "ReplicaSupervisor",
           "AdmissionQueue", "AdmissionTicket", "QueueClosed", "ShedError",
           "DeadlineExceededError",
           "Span", "Trace", "FlightRecorder", "EventLog",
           "prometheus_text", "admission_prometheus_text",
           "parse_prometheus_text", "prom_get",
           "chrome_trace", "stats_delta",
           "FaultPlan", "FaultWindow", "FaultyExecutor", "InjectedFault",
           "ReplicaCrash", "inject_faults", "truncate_file", "flip_byte"]
