"""``SparseKernelEngine`` — micro-batched serving of tuned sparse kernels
across multiple hardware backends, behind a pluggable routing policy.

One ``step(requests)`` call serves a micro-batch of (pattern, values, op
[, platform]) requests through the COGNATE deployment loop as an explicit
six-stage pipeline — each stage a separate method, so scheduling PRs
(sharding, async dispatch) can interpose on a seam instead of a monolith:

1. **Route** — each request's pattern is digested once and the batch is
   handed to the engine's ``Router`` (``repro.serving.router``), which
   returns one ``RouteDecision`` per request.  The default ``StaticRouter``
   honors explicit ``platform`` tags and sends untagged requests to the
   registry's default platform (the pre-router behavior, bit for bit);
   ``CostModelRouter`` instead scores untagged patterns against every
   candidate backend's config space in one batched dispatch and routes to
   the argmin calibrated cost; ``LoadAwareRouter`` spills saturated
   backends to a fallback.  Every decision is validated against the
   ``BackendRegistry`` here — an unknown tag raises ``KeyError`` (naming
   the tag and the registered backends) before any work happens.
2. **Partition** — the batch splits into one partition per decided
   ``(platform, op)`` tag; per-backend cache hit/miss status is peeked, and
   each backend's in-flight depth (``KernelBackend.load``) is raised by its
   share of the batch (lowered again when this stream's leases release).
3. **Score** — within *each* backend, cache misses are featurized and
   scored in a single ``Autotuner.scores_batch`` dispatch via that
   backend's ``KernelAutotuner.get_batch``.  Misses whose decision carries
   a routing config hint (the cost-model router already scored them in its
   routing dispatch) are *installed* directly — no second dispatch.  Hits
   skip featurization entirely.  Backends never share cache entries.
4. **Build** — values scatter through each pattern's cached ``BsrPlan``
   into a two-slot double-buffered ``PlanArena`` (keyed per backend tag);
   slot exhaustion falls back to a counted un-aliased build.  Two scatter
   paths: values already on device (e.g. MoE router outputs) take the
   **device** path — one asynchronous jitted gather+scatter, steady state
   donating the slot's previous device buffer in place, zero host numpy —
   while host values take the classic numpy scatter.  ``device_build``
   selects ``"auto"`` (by value residency) / ``"always"`` / ``"never"``;
   ``stats()["build_paths"]`` counts both paths, the overlap ratio, and
   drain waits.
5. **Execute** — requests carrying a dense operand run through their
   backend's executor with the tuned tile config; the launch is JAX-async
   (nothing calls ``block_until_ready``), so the kernel is still in
   flight when ``step`` returns and the *next* batch's scatter overlaps
   it.  Operand-less requests are "prepare-only".
6. **Account** — responses assemble in request order; routing decisions,
   per-backend serve latency, and observed-vs-predicted calibration
   (``RouteCalibration`` — what keeps ``CostModelRouter`` honest, now fed
   per ``(platform, op)``) fold into telemetry; the batch is stamped with
   a dispatch generation and handed to the calling thread's stream; the
   *previous* generation — dispatched a full step ago, its kernels
   overlapped by everything this step just did — is awaited and its
   leases and load accounting release (double-buffer hand-off with
   backpressure: run-ahead is bounded at two generations, so the host can
   never flood the dispatch queue, and a donated device buffer is never
   re-donated under a live consumer).

Batch N's leases are released only after batch N+1 is dispatched
(generation hand-off), so the engine is safe with asynchronous kernel
launches; ``drain()`` forces completion of the calling thread's in-flight
work (blocks on every dispatched array) and releases every generation —
call it before reading results out-of-band or timing a synchronous
baseline.  ``stats()`` renders global hit rates, per-stage latency
histograms (p50/p99), build-path counters, evictions, persistence events,
a per-backend section, a ``"routing"`` section (decision reasons,
per-platform shares, spill + hysteresis counts, calibration), and
per-backend live load.

With ``persist_path`` set, the engine warm-starts every backend's cache from
one namespaced file at construction (zero featurizations for
previously-seen traffic; legacy single-backend files restore the default
platform; entries whose tag no registered backend claims are skipped and
counted — torn or missing files fall back to a cold cache) and ``save()``
atomically writes all backends back via ``repro.serving.persist``.

Thread-safety: ``step`` may be called from several threads; the caches,
arenas, routers, and telemetry are lock-guarded, and double-buffer leases
(plus the matching load accounting) are tracked per calling thread —
one stream's ``step`` or ``release_stream()`` never releases another's.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from pathlib import Path

import jax
import numpy as np

from repro.core.autotune import (Autotuner, KernelAutotuner, TunedKernel,
                                 matrix_digest)
from repro.data.matrices import SparseMatrix
from repro.kernels.format import BsrMatrix
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.backends import (BackendRegistry, KernelBackend,
                                    default_registry)
from repro.serving.persist import (LEGACY_NAMESPACE, load_grouped,
                                   save_backends)
from repro.serving.router import Router, RoutingContext, StaticRouter
from repro.serving.telemetry import EngineTelemetry

__all__ = ["KernelRequest", "KernelResponse", "SparseKernelEngine"]


@dataclasses.dataclass
class KernelRequest:
    """One unit of serving work: a sparsity pattern with this batch's values.

    ``values`` aligns with ``mat.rows``/``mat.cols`` (defaults to ones —
    pattern-only traffic).  ``operand`` is the dense right-hand side: a (K, N)
    array for ``op="spmm"``, a ``(b, c)`` tuple for ``op="sddmm"``; ``None``
    means prepare-only (tune + build, let the caller launch).  ``platform``
    pins the request to that backend tag in the engine's registry; ``None``
    leaves the choice to the engine's router (the default ``StaticRouter``
    sends it to the registry's default platform)."""
    mat: SparseMatrix
    values: np.ndarray | None = None
    op: str = "spmm"
    operand: object = None
    platform: str | None = None


@dataclasses.dataclass
class KernelResponse:
    """Per-request result: the tuned config, built BSR matrix, kernel output
    (``None`` for prepare-only), and routing/caching provenance
    (``platform`` + ``route_reason`` say where the request ran and why).

    ``output`` and ``matrix.data`` are asynchronously dispatched device
    arrays — consuming them (or ``engine.drain()``) forces completion.  A
    *device-built* arena matrix additionally aliases arena device storage:
    it is physically invalidated (JAX raises on access) once its slot
    rotates, i.e. after the thread's next-next ``step`` — consume or copy
    it before then, exactly the lease contract.  Host-built matrices are
    independent device copies and never invalidate."""
    digest: str
    config: dict
    matrix: BsrMatrix
    output: object | None       # kernel result, or None for prepare-only
    cache_hit: bool
    arena_slot: bool            # False -> overflow fallback (fresh buffer)
    platform: str = ""          # backend tag the request was served by
    route_reason: str = ""      # router's reason (explicit/default/... )
    device_built: bool = False  # True -> jitted device scatter built it
    generation: int = 0         # engine dispatch generation of this batch


@dataclasses.dataclass
class _StepState:
    """One micro-batch's pipeline state, threaded through the stages."""
    requests: list
    digests: list = dataclasses.field(default_factory=list)
    decisions: list = dataclasses.field(default_factory=list)
    groups: OrderedDict = dataclasses.field(default_factory=OrderedDict)
    resolved: dict = dataclasses.field(default_factory=dict)
    hit_of: dict = dataclasses.field(default_factory=dict)
    entries: list = dataclasses.field(default_factory=list)
    built: list = dataclasses.field(default_factory=list)
    device_flags: list = dataclasses.field(default_factory=list)
    outputs: list = dataclasses.field(default_factory=list)
    leases: list = dataclasses.field(default_factory=list)
    loads: list = dataclasses.field(default_factory=list)   # (backend, n)
    tag_seconds: dict = dataclasses.field(default_factory=dict)
    tag_serve_seconds: dict = dataclasses.field(default_factory=dict)
    installs: int = 0           # router config hints installed this step
    handed_off: bool = False    # leases/loads transferred to the stream


class SparseKernelEngine:
    """Batched, double-buffered, warm-startable, multi-backend,
    router-scheduled sparse-kernel server.

    Args:
        tuner: a learned ``Autotuner`` or prebuilt ``KernelAutotuner`` for
            the default platform (``None`` -> structural heuristic).  Only
            consulted when ``backends`` is not given.
        cache_size: per-backend autotune LRU capacity (default registry).
        arena_slots: double-buffer depth per cached pattern.
        persist_path: warm-start/save location for the namespaced cache file.
        autosave_every: if set, ``save()`` runs every N batches.
        interpret: selects the default platform of the stock registry —
            ``True`` -> ``tpu_interpret``, ``False`` -> ``tpu_pallas``
            (compiled; degrades to interpreter off-TPU).
        backends: an explicit ``BackendRegistry``; overrides ``tuner``/
            ``interpret``.  Register custom platforms here.
        router: the routing policy (``repro.serving.router``) deciding which
            backend serves each request.  Default ``StaticRouter`` —
            explicit tags honored, untagged traffic to the default platform.
        device_build: which scatter path builds block data.  ``"auto"``
            (default) takes the jitted device path for values that are
            already device-resident (``jax.Array``) and the numpy host
            path otherwise; ``"always"`` forces the device path (host
            values are transferred first); ``"never"`` forces the host
            path.  ``True``/``False`` alias always/never.

    Thread-safety: all public methods are safe under concurrent callers;
    see the module docstring for the per-thread lease protocol.
    """

    def __init__(self, tuner: KernelAutotuner | Autotuner | None = None,
                 cache_size: int = 128, arena_slots: int = 2,
                 persist_path: str | Path | None = None,
                 autosave_every: int | None = None, interpret: bool = True,
                 backends: BackendRegistry | None = None,
                 router: Router | None = None,
                 device_build: str | bool = "auto"):
        if backends is None:
            backends = default_registry(
                tuner, cache_size=cache_size,
                default_platform="tpu_interpret" if interpret
                else "tpu_pallas")
        elif tuner is not None:
            raise ValueError("pass either a tuner or a backend registry, "
                             "not both")
        self.backends = backends
        self.default_platform = backends.default_platform
        self.router = router if router is not None else StaticRouter()
        # compat: the default platform's tuner (spmm if registered), what
        # single-backend callers passed in and still introspect
        # (featurize_calls, cache)
        try:
            self.tuner = backends.get(self.default_platform, "spmm").tuner
        except KeyError:
            default_bes = [be for be in backends
                           if be.platform == backends.default_platform]
            all_bes = default_bes or list(backends)
            if not all_bes:
                raise ValueError("backend registry has no backends")
            self.tuner = all_bes[0].tuner
        if device_build is True:
            device_build = "always"
        elif device_build is False:
            device_build = "never"
        if device_build not in ("auto", "always", "never"):
            raise ValueError(f"device_build must be auto/always/never, "
                             f"got {device_build!r}")
        self.device_build = device_build
        self.arena_slots = arena_slots
        self.autosave_every = autosave_every
        self.telemetry = EngineTelemetry()
        self.persist_path = Path(persist_path) if persist_path else None
        self._arenas: OrderedDict = OrderedDict()  # (plat, op, digest) -> arena
        # arenas are keyed across ALL backends, so the LRU bound is the sum
        # of the per-backend cache capacities — a max() here would thrash
        # arenas as soon as the combined working set outgrew one backend's
        self._arena_cap = sum(kt.cache.maxsize for kt in backends.tuners())
        # previous-batch leases (and the matching backend-load accounting)
        # are per *thread*: each serving stream double-buffers independently,
        # so one thread's step can never release (and let the arena
        # overwrite) a batch another thread's caller still holds.  Concurrent
        # streams hitting one pattern contend for its slots; losers take the
        # counted un-aliased fallback.
        self._stream = threading.local()
        self._outstanding = 0
        self._generation = 0            # monotonically stamps dispatches
        self._lock = threading.Lock()   # guards _arenas/_outstanding/_generation
        if self.persist_path is not None:
            self._warm_start()

    def _warm_start(self) -> None:
        """Route every persisted namespace to its registered backend."""
        loaded = load_grouped(self.persist_path)
        if loaded is None:
            if self.persist_path.exists():
                self.telemetry.count(persist_load_failures=1)
            return
        restored = 0
        skipped = loaded.skipped
        for tag, items in loaded.entries.items():
            platform = self.default_platform if tag is LEGACY_NAMESPACE \
                else tag
            for (op, digest), entry in items:
                if (platform, op) in self.backends:
                    be = self.backends.get(platform, op)
                    be.tuner.cache.put((op, digest), entry)
                    restored += 1
                else:                   # orphaned tag: serve it cold instead
                    skipped += 1
        self.telemetry.count(warm_start_entries=restored,
                             warm_start_skipped=skipped)

    # ------------------------------------------------------------- serving

    def step(self, requests: list[KernelRequest]) -> list[KernelResponse]:
        """Serve one micro-batch; returns responses in request order.

        Runs the staged pipeline route -> partition -> score -> build ->
        execute -> account (each stage is a ``_*_stage`` method and gets its
        own latency histogram).  Raises ``KeyError`` — before any work is
        done — if routing produces a ``(platform, op)`` tag with no
        registered backend."""
        t_step = time.perf_counter()
        st = _StepState(requests)
        try:
            for name, stage in (("route", self._route_stage),
                                ("partition", self._partition_stage),
                                ("score", self._score_stage),
                                ("build", self._build_stage),
                                ("execute", self._execute_stage)):
                t0 = time.perf_counter()
                stage(st)
                self.telemetry.record_stage(name, time.perf_counter() - t0)
            return self._account_stage(st, t_step)
        except BaseException:
            # a stage failed mid-step: roll back this step's arena leases
            # and load accounting so a caller that catches the error keeps
            # a consistent engine (no permanently-saturated backend, no
            # exhausted arena).  Once _account_stage has handed the batch
            # to the stream, the normal hand-off owns the cleanup.
            if not st.handed_off:
                for lease in st.leases:
                    lease.release()
                for be, n in st.loads:
                    be.load.end(n)
            raise

    # ------------------------------------------------------ pipeline stages

    def routing_context(self) -> RoutingContext:
        """The engine state routers consult (registry, calibration ledger,
        default platform) — also handy for driving a ``Router`` directly in
        tests."""
        return RoutingContext(self.backends, self.telemetry.calibration,
                              self.default_platform)

    def _route_stage(self, st: _StepState) -> None:
        """Digest every pattern once, let the router decide each request's
        backend, and validate every decision against the registry — an
        unknown tag fails here, with nothing partially served."""
        st.digests = [matrix_digest(r.mat) for r in st.requests]
        st.decisions = self.router.route(st.requests, st.digests,
                                         self.routing_context())
        for r, d in zip(st.requests, st.decisions):
            if (d.platform, r.op) not in self.backends:
                self.backends.get(d.platform, r.op)   # raises the KeyError

    def _partition_stage(self, st: _StepState) -> None:
        """Split the batch into one partition per decided (platform, op)
        tag, peek per-backend hit/miss status (so responses can report
        ``cache_hit`` truthfully), and raise each backend's in-flight
        depth by its share of the batch."""
        for i, r in enumerate(st.requests):
            st.groups.setdefault((st.decisions[i].platform, r.op),
                                 []).append(i)
        st.resolved = {tag: self.backends.get(*tag) for tag in st.groups}
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            cache = be.tuner.cache
            for i in idxs:
                st.hit_of[i] = (st.requests[i].op, st.digests[i]) in cache
            be.load.begin(len(idxs))
            st.loads.append((be, len(idxs)))

    def _score_stage(self, st: _StepState) -> None:
        """Tune every partition's misses: routing config hints install
        directly (the router's multi-space dispatch already scored them);
        the rest go through one batched ``get_batch`` dispatch per
        backend."""
        st.entries = [None] * len(st.requests)
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            t0 = time.perf_counter()
            for i in idxs:
                d = st.decisions[i]
                if d.config is not None and not st.hit_of[i] \
                        and (tag[1], st.digests[i]) not in be.tuner.cache:
                    be.tuner.install(st.requests[i].mat, tag[1], d.config,
                                     digest=st.digests[i])
                    st.installs += 1
            unscored = sum((tag[1], st.digests[i]) not in be.tuner.cache
                           for i in idxs)
            got = be.tuner.get_batch([st.requests[i].mat for i in idxs],
                                     tag[1],
                                     digests=[st.digests[i] for i in idxs])
            for i, e in zip(idxs, got):
                st.entries[i] = e
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            if unscored:
                self.telemetry.count(score_dispatches=1)

    def _device_path(self, values) -> bool:
        """Whether this request's values take the jitted device scatter."""
        if self.device_build == "always":
            return True
        if self.device_build == "never":
            return False
        return isinstance(values, jax.Array)

    def _build_stage(self, st: _StepState) -> None:
        """Scatter each request's values through its cached plan into an
        arena slot (double buffer), falling back to a counted un-aliased
        build on slot exhaustion.  Device-resident values scatter on
        device (one async jitted dispatch, no host numpy); host values
        take the numpy path.  Builds issued while this thread's previous
        generation is still in flight count as *overlapped* — the async
        pipeline working as intended."""
        st.built = [None] * len(st.requests)
        st.device_flags = [False] * len(st.requests)
        overlapped = bool(getattr(self._stream, "leases", ()))
        n_device = n_host = 0
        for tag, idxs in st.groups.items():
            t0 = time.perf_counter()
            for i in idxs:
                r, entry = st.requests[i], st.entries[i]
                values = r.values if r.values is not None \
                    else np.ones(r.mat.nnz, np.float32)
                on_device = self._device_path(values)
                st.device_flags[i] = on_device
                arena = self._arena_for(tag + (st.digests[i],), entry)
                try:
                    lease = arena.build_device(values) if on_device \
                        else arena.build(values)
                    st.leases.append(lease)
                    st.built[i] = (lease.matrix, True)
                except ArenaOverrun:
                    self.telemetry.count(arena_fallbacks=1)
                    built = entry.plan.build_device(values) if on_device \
                        else entry.plan.build(values)
                    st.built[i] = (built, False)
                if on_device:
                    n_device += 1
                else:
                    n_host += 1
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + dt
        self.telemetry.count(
            device_builds=n_device, host_builds=n_host,
            overlapped_builds=(n_device + n_host) if overlapped else 0)

    def _execute_stage(self, st: _StepState) -> None:
        """Launch each backend's kernel for requests carrying a dense
        operand; operand-less requests stay prepare-only."""
        st.outputs = [None] * len(st.requests)
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            t0 = time.perf_counter()
            for i in idxs:
                r = st.requests[i]
                if r.operand is not None:
                    st.outputs[i] = be.run(st.entries[i].config,
                                           st.built[i][0], r.operand)
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + dt

    def _account_stage(self, st: _StepState,
                       t_step: float) -> list[KernelResponse]:
        """Assemble responses, fold this step into telemetry (per-backend
        serve time, routing decisions, observed-vs-predicted calibration),
        and hand off the double buffer: the *previous* batch's leases and
        load accounting release now that this batch is in flight."""
        total_hits = total_misses = 0
        for tag, idxs in st.groups.items():
            d_hits = sum(st.hit_of[i] for i in idxs)
            total_hits += d_hits
            total_misses += len(idxs) - d_hits
            self.telemetry.record_backend(
                "/".join(tag), requests=len(idxs), hits=d_hits,
                misses=len(idxs) - d_hits,
                seconds=st.tag_seconds.get(tag, 0.0))
            # every served route feeds the observed-latency ledger; routes
            # that carried a prediction also calibrate predicted-vs-observed.
            # Calibration sees build+execute time only — folding in the
            # score stage would charge one-time tuning cost to whichever
            # backend just received fresh patterns, and the early EMA
            # samples it poisons are exactly the ones that steer routing
            per_req = st.tag_serve_seconds.get(tag, 0.0) / len(idxs) \
                if idxs else 0.0
            for i in idxs:
                self.telemetry.calibration.observe(
                    tag[0], per_req, st.decisions[i].predicted, op=tag[1])
        reasons: dict[tuple[str, str], int] = {}
        for d in st.decisions:
            key = (d.platform, d.reason)
            reasons[key] = reasons.get(key, 0) + 1
        for (platform, reason), n in reasons.items():
            self.telemetry.record_route(platform, reason, n)
        if st.installs:
            self.telemetry.count(route_config_installs=st.installs)
        self.telemetry.count(hits=total_hits, misses=total_misses)

        with self._lock:
            self._generation += 1
            generation = self._generation
        responses = [
            KernelResponse(dg, entry.config, matrix, output, st.hit_of[i],
                           in_arena, st.decisions[i].platform,
                           st.decisions[i].reason, st.device_flags[i],
                           generation)
            for i, (dg, entry, (matrix, in_arena), output) in enumerate(
                zip(st.digests, st.entries, st.built, st.outputs))]

        # everything this generation dispatched asynchronously — every
        # built matrix (arena-leased AND overrun-fallback builds, which
        # carry no lease but were still async device dispatches) plus the
        # kernel outputs — so drain() can force completion of all of it
        refs = [matrix.data for matrix, _ in st.built] \
            + [o for o in st.outputs if o is not None]

        # this stream's batch N-1 kernels were dispatched a full step ago —
        # its slots can rotate now that batch N is in flight (double-buffer
        # hand-off), and its backend in-flight depth drops with it.  The
        # thread-local swap is what keys release to the dispatch
        # generation: a stream holds exactly one outstanding generation,
        # and only the one being swapped out is ever released.
        prev_leases, prev_loads, prev_refs = self._swap_stream(
            st.leases, st.loads, refs)
        st.handed_off = True
        # two-deep pipeline backpressure: wait for generation N-1 (its
        # entire step overlapped batch N's host work) before rotating its
        # slots — run-ahead stays bounded at two generations instead of
        # flooding the dispatch queue, and a donated device buffer can
        # never be re-donated while a consumer might still read it.
        for ref in prev_refs:
            jax.block_until_ready(ref)
        for lease in prev_leases:
            lease.release()
        for be, n in prev_loads:
            be.load.end(n)

        self.telemetry.count(requests=len(st.requests), batches=1)
        self.telemetry.record_stage("step", time.perf_counter() - t_step)
        if (self.autosave_every and self.persist_path is not None
                and self.telemetry.batches % self.autosave_every == 0):
            self.save()
        return responses

    # ----------------------------------------------------- stream plumbing

    def _arena_for(self, key, entry: TunedKernel) -> PlanArena:
        with self._lock:
            arena = self._arenas.get(key)
            if arena is None or arena.plan is not entry.plan:
                arena = PlanArena(entry.plan, n_slots=self.arena_slots)
                self._arenas[key] = arena
            self._arenas.move_to_end(key)
            while len(self._arenas) > max(self._arena_cap, 1):
                self._arenas.popitem(last=False)
            return arena

    def _swap_stream(self, leases: list[ArenaLease],
                     loads: list[tuple[KernelBackend, int]],
                     refs: list = ()):
        """Install this thread's new outstanding batch (leases, backend-load
        shares, async dispatch refs); return the old one (leases, loads,
        refs — to be released, and optionally waited on, together).  A
        stream holds exactly one outstanding generation, so this swap IS
        the generation hand-off."""
        prev_leases = getattr(self._stream, "leases", [])
        prev_loads = getattr(self._stream, "loads", [])
        prev_refs = getattr(self._stream, "refs", [])
        self._stream.leases = leases
        self._stream.loads = loads
        self._stream.refs = list(refs)
        with self._lock:
            self._outstanding += len(leases) - len(prev_leases)
        return prev_leases, prev_loads, prev_refs

    def release_stream(self) -> None:
        """Release the calling thread's outstanding arena leases and drop
        its backend in-flight accounting (call once this stream's last
        results have been consumed or copied).  Idempotent: a second call
        with nothing outstanding is a no-op, and it never touches another
        thread's leases.  Does NOT wait for in-flight dispatches — use
        ``drain()`` to force completion first."""
        prev_leases, prev_loads, _ = self._swap_stream([], [])
        for lease in prev_leases:
            lease.release()
        for be, n in prev_loads:
            be.load.end(n)

    def drain(self) -> None:
        """Force completion of the calling thread's in-flight work, then
        release every outstanding generation.

        Blocks until every array the stream's last dispatched batch
        produced (arena matrices and kernel outputs) is ready, releases the
        leases and load accounting, and counts a ``drain_wait`` when there
        was anything to wait on.  After ``drain()`` the thread holds no
        leases of any generation — the synchronous point the async pipeline
        is measured against, and the right call before tearing a stream
        down or handing its results across threads.  Idempotent."""
        prev_leases, prev_loads, prev_refs = self._swap_stream([], [])
        pending = bool(prev_leases or prev_loads or prev_refs)
        for ref in prev_refs:
            jax.block_until_ready(ref)
        for lease in prev_leases:
            lease.release()
        for be, n in prev_loads:
            be.load.end(n)
        if pending:
            self.telemetry.count(drain_waits=1)

    def flush(self) -> None:
        """Alias of ``release_stream()`` (the historical name)."""
        self.release_stream()

    # ------------------------------------------------------- observability

    @property
    def featurize_calls(self) -> int:
        """Total featurize+score computations across every backend's tuner
        (shared tuners counted once) — zero on fully warm-started traffic."""
        return sum(kt.featurize_calls for kt in self.backends.tuners())

    def stats(self) -> dict:
        """Snapshot of all counters: global hit rates, per-stage latency
        histograms, ``"build_paths"`` (device vs host scatter counts,
        overlap ratio, drain waits), a ``"backends"`` section keyed
        ``"platform/op"`` with per-backend requests / hit rate / serve
        p50-p99, a ``"routing"`` section (decision reasons, per-platform
        request shares, spill + hysteresis counts, per-platform
        observed-vs-predicted calibration with per-op detail), per-backend
        live load (``"load"``: in-flight depth / peak / total), cache and
        arena occupancy, and persistence events.  ``"cache"`` is the
        *default* backend's cache (pre-registry compat); ``"caches"``
        reports every platform's occupancy and eviction counters.  Safe to
        call concurrently with ``step``."""
        out = self.telemetry.snapshot(cache=self.tuner.cache)
        out["routing"]["spill_hysteresis"] = getattr(self.router,
                                                     "spill_hysteresis", 0)
        out["featurize_calls"] = self.featurize_calls
        out["caches"] = {}
        for plat, caches in self.backends.caches_by_platform().items():
            for j, c in enumerate(caches):
                key = plat if len(caches) == 1 else f"{plat}[{j}]"
                out["caches"][key] = {
                    "size": len(c), "maxsize": c.maxsize, "hits": c.hits,
                    "misses": c.misses, "evictions": c.evictions}
        out["load"] = {tag: {"inflight": load.inflight, "peak": load.peak,
                             "total": load.total}
                       for tag, load in self.backends.loads_by_tag().items()}
        with self._lock:
            out["arenas"] = {"resident": len(self._arenas),
                             "outstanding_leases": self._outstanding,
                             "generation": self._generation}
        return out

    # --------------------------------------------------------- persistence

    def save(self, path: str | Path | None = None) -> Path:
        """Atomically persist every backend's autotune cache (platform-tag
        namespaced digest -> config + plan) to one file."""
        target = Path(path) if path is not None else self.persist_path
        if target is None:
            raise ValueError("no persist_path configured and none given")
        out = save_backends(self.backends, target)
        self.telemetry.count(persist_saves=1)
        return out
