"""``SparseKernelEngine`` — micro-batched serving of tuned sparse kernels
across multiple hardware backends, behind a pluggable routing policy.

One ``step(requests)`` call serves a micro-batch of (pattern, values, op
[, platform]) requests through the COGNATE deployment loop as an explicit
six-stage pipeline — each stage a separate method, so scheduling PRs
(sharding, async dispatch) can interpose on a seam instead of a monolith:

1. **Route** — each request's pattern is digested once and the batch is
   handed to the engine's ``Router`` (``repro.serving.router``), which
   returns one ``RouteDecision`` per request.  The default ``StaticRouter``
   honors explicit ``platform`` tags and sends untagged requests to the
   registry's default platform (the pre-router behavior, bit for bit);
   ``CostModelRouter`` instead scores untagged patterns against every
   candidate backend's config space in one batched dispatch and routes to
   the argmin calibrated cost; ``LoadAwareRouter`` spills saturated
   backends to a fallback.  Every decision is validated against the
   ``BackendRegistry`` here — an unknown tag raises ``KeyError`` (naming
   the tag and the registered backends) before any work happens.
2. **Partition** — the batch splits into one partition per decided
   ``(platform, op)`` tag; per-backend cache hit/miss status is peeked, and
   each backend's in-flight depth (``KernelBackend.load``) is raised by its
   share of the batch (lowered again when this stream's leases release).
3. **Score** — within *each* backend, cache misses are featurized and
   scored in a single ``Autotuner.scores_batch`` dispatch via that
   backend's ``KernelAutotuner.get_batch``.  Misses whose decision carries
   a routing config hint (the cost-model router already scored them in its
   routing dispatch) are *installed* directly — no second dispatch.  Hits
   skip featurization entirely.  Backends never share cache entries.
4. **Build** — values scatter through each pattern's cached ``BsrPlan``
   into a two-slot double-buffered ``PlanArena`` (keyed per backend tag);
   slot exhaustion falls back to a counted un-aliased build.  Two scatter
   paths: values already on device (e.g. MoE router outputs) take the
   **device** path — one asynchronous jitted gather+scatter, steady state
   donating the slot's previous device buffer in place, zero host numpy —
   while host values take the classic numpy scatter.  ``device_build``
   selects ``"auto"`` (by value residency) / ``"always"`` / ``"never"``;
   ``stats()["build_paths"]`` counts both paths, the overlap ratio, and
   drain waits.
5. **Execute** — requests carrying a dense operand run through their
   backend's executor with the tuned tile config; the launch is JAX-async
   (nothing calls ``block_until_ready``), so the kernel is still in
   flight when ``step`` returns and the *next* batch's scatter overlaps
   it.  Operand-less requests are "prepare-only".
6. **Account** — responses assemble in request order; routing decisions,
   per-backend serve latency, and observed-vs-predicted calibration
   (``RouteCalibration`` — what keeps ``CostModelRouter`` honest, now fed
   per ``(platform, op)``) fold into telemetry; the batch is stamped with
   a dispatch generation and handed to the calling thread's stream; the
   *previous* generation — dispatched a full step ago, its kernels
   overlapped by everything this step just did — is awaited and its
   leases and load accounting release (double-buffer hand-off with
   backpressure: run-ahead is bounded at two generations, so the host can
   never flood the dispatch queue, and a donated device buffer is never
   re-donated under a live consumer).

**Fault tolerance.**  Every executor launch runs under per-request fault
isolation: one backend raising (or, with ``validate_outputs=True``,
returning NaN/inf or a mis-shaped output) fails only its own partition's
requests.  Failed requests re-enter the pipeline once via a **retry
lane** that re-routes them to the healthiest surviving backend for their
op (stock registries bottom out at ``cpu_ref``, which never dies); the
response then reports ``attempts=2``, ``failed_over_from``, and
``degraded=True``.  Outcomes feed a per-``(platform, op)`` circuit
breaker (``repro.serving.health``): a backend crossing the failure-rate
or consecutive-error threshold trips **open** and its traffic is
rewritten to the failover target at route time (no executor call at
all), until a **half-open** probe — granted after an exponentially
escalating backoff — succeeds and closes the circuit.  Health-aware
routers (``RoutingContext.health``) additionally keep open-circuit
backends out of candidate sets and sticky memos.  ``stats()["health"]``
accounts for every failure, fast-fail, failover, and probe, and
``repro.serving.faults`` injects deterministic failures for tests and
the ``benchmarks/serving_faults.py`` degraded-mode scenario.

**Observability.**  Every step's stage timings double as a per-request
**span tree** (``repro.serving.trace``): with ``trace_sample_rate > 0``
a deterministic head sampler retains whole steps into a bounded flight
recorder ring, and degraded / failed-over / retried requests are
*always* retained into a separate error ring regardless of sampling —
``engine.traces()`` / ``traces(errors=True)`` reads them back, and
retained responses carry the ``trace_id``.  ``engine.events`` is a
bounded structured-event ring (breaker transitions, failovers,
quarantines, warm starts, router spills, drains) and
``engine.stats_delta()`` gives windowed rates; ``repro.serving.export``
renders all of it as Prometheus text, JSONL, and Chrome-trace JSON (the
per-generation dispatch->retire windows in ``generation_log()`` make the
async run-ahead visible on a timeline).

Batch N's leases are released only after batch N+1 is dispatched
(generation hand-off), so the engine is safe with asynchronous kernel
launches; ``drain()`` forces completion of the calling thread's in-flight
work (blocks on every dispatched array) and releases every generation —
call it before reading results out-of-band or timing a synchronous
baseline.  ``stats()`` renders global hit rates, per-stage latency
histograms (p50/p99), build-path counters, evictions, persistence events,
a per-backend section, a ``"routing"`` section (decision reasons,
per-platform shares, spill + hysteresis counts, calibration), and
per-backend live load.

With ``persist_path`` set, the engine warm-starts every backend's cache from
one namespaced file at construction (zero featurizations for
previously-seen traffic; legacy single-backend files restore the default
platform; entries whose tag no registered backend claims are skipped and
counted — torn or missing files fall back to a cold cache) and ``save()``
atomically writes all backends back via ``repro.serving.persist``.

Thread-safety: ``step`` may be called from several threads; the caches,
arenas, routers, and telemetry are lock-guarded, and double-buffer leases
(plus the matching load accounting) are tracked per calling thread —
one stream's ``step`` or ``release_stream()`` never releases another's.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import uuid
import weakref
from collections import OrderedDict, deque
from pathlib import Path

import jax
import numpy as np

from repro.core.autotune import (Autotuner, KernelAutotuner, TunedKernel,
                                 matrix_digest)
from repro.data.matrices import SparseMatrix
from repro.kernels.format import BsrMatrix
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.backends import (BackendRegistry, KernelBackend,
                                    default_registry)
from repro.serving.health import CLOSED, HealthConfig, HealthRegistry
from repro.serving.persist import (LEGACY_NAMESPACE, load_grouped,
                                   save_backends)
from repro.serving.router import (RouteDecision, Router, RoutingContext,
                                  StaticRouter)
from repro.serving.telemetry import EngineTelemetry
from repro.serving.trace import (CounterSampler, EventLog, FlightRecorder,
                                 Span, Trace)

__all__ = ["KernelRequest", "KernelResponse", "OutputGuardError",
           "SparseKernelEngine"]

# routing reasons whose outcome the warm lane may replay: a deliberate,
# per-pattern decision.  Spill/failover/explore outcomes are transient by
# construction and must keep flowing through the router.
_WARM_REASONS = frozenset({"explicit", "default", "sticky", "cost_model"})


class OutputGuardError(RuntimeError):
    """An executed kernel produced an invalid output (NaN/inf or wrong
    shape) — raised by the engine's opt-in output guard
    (``validate_outputs=True``) and treated exactly like an executor
    failure: recorded against the backend's health and failed over."""


@dataclasses.dataclass
class KernelRequest:
    """One unit of serving work: a sparsity pattern with this batch's values.

    ``values`` aligns with ``mat.rows``/``mat.cols`` (defaults to ones —
    pattern-only traffic).  ``operand`` is the dense right-hand side: a (K, N)
    array for ``op="spmm"``, a ``(b, c)`` tuple for ``op="sddmm"``; ``None``
    means prepare-only (tune + build, let the caller launch).  ``platform``
    pins the request to that backend tag in the engine's registry; ``None``
    leaves the choice to the engine's router (the default ``StaticRouter``
    sends it to the registry's default platform).

    ``deadline_ts`` is an absolute deadline on the engine's monotonic
    clock (``None`` = no deadline; the admission queue stamps it from the
    caller's ``deadline_ms`` budget).  A request whose deadline has passed
    is *expired*: it completes as ``KernelResponse.deadline_exceeded``
    instead of running, checked at step entry and again before the score,
    build, and execute stages — work already sunk stays sunk, but no new
    stage starts for a request that cannot make its deadline, and the
    retry lane never re-serves an expired failure.
    """
    mat: SparseMatrix
    values: np.ndarray | None = None
    op: str = "spmm"
    operand: object = None
    platform: str | None = None
    trace_id: str | None = None  # caller-supplied id; None -> engine stamps
                                 # one when the request's trace is retained
    deadline_ts: float | None = None  # absolute monotonic deadline, or None


@dataclasses.dataclass
class KernelResponse:
    """Per-request result: the tuned config, built BSR matrix, kernel output
    (``None`` for prepare-only), and routing/caching provenance
    (``platform`` + ``route_reason`` say where the request ran and why).

    ``output`` and ``matrix.data`` are asynchronously dispatched device
    arrays — consuming them (or ``engine.drain()``) forces completion.  A
    *device-built* arena matrix additionally aliases arena device storage:
    it is physically invalidated (JAX raises on access) once its slot
    rotates, i.e. after the thread's next-next ``step`` — consume or copy
    it before then, exactly the lease contract.  Host-built matrices are
    independent device copies and never invalidate."""
    digest: str
    config: dict
    matrix: BsrMatrix
    output: object | None       # kernel result, or None for prepare-only
    cache_hit: bool
    arena_slot: bool            # False -> overflow fallback (fresh buffer)
    platform: str = ""          # backend tag the request was served by
    route_reason: str = ""      # router's reason (explicit/default/... )
    device_built: bool = False  # True -> jitted device scatter built it
    generation: int = 0         # engine dispatch generation of this batch
    attempts: int = 1           # executions tried (2 -> retry lane served it)
    failed_over_from: str | None = None  # platform the request was moved off
    degraded: bool = False      # True -> served by a fallback, not the route
    trace_id: str | None = None  # set iff this request's trace was retained
                                 # (head-sampled step, or degraded) — the key
                                 # into engine.traces()
    deadline_exceeded: bool = False  # True -> the request expired instead of
                                     # serving: config/matrix/output are empty


@dataclasses.dataclass
class _StepState:
    """One micro-batch's pipeline state, threaded through the stages."""
    requests: list
    digests: list = dataclasses.field(default_factory=list)
    decisions: list = dataclasses.field(default_factory=list)
    groups: OrderedDict = dataclasses.field(default_factory=OrderedDict)
    resolved: dict = dataclasses.field(default_factory=dict)
    hit_of: dict = dataclasses.field(default_factory=dict)
    entries: list = dataclasses.field(default_factory=list)
    built: list = dataclasses.field(default_factory=list)
    device_flags: list = dataclasses.field(default_factory=list)
    outputs: list = dataclasses.field(default_factory=list)
    leases: list = dataclasses.field(default_factory=list)
    loads: list = dataclasses.field(default_factory=list)   # (backend, n)
    tag_seconds: dict = dataclasses.field(default_factory=dict)
    tag_serve_seconds: dict = dataclasses.field(default_factory=dict)
    installs: int = 0           # router config hints installed this step
    handed_off: bool = False    # leases/loads transferred to the stream
    errors: list = dataclasses.field(default_factory=list)  # per-request
    failover_from: dict = dataclasses.field(default_factory=dict)  # i -> tag
    retried: set = dataclasses.field(default_factory=set)   # retry-lane idxs
    probes: set = dataclasses.field(default_factory=set)    # tags probing
    expired: set = dataclasses.field(default_factory=set)   # deadline-expired
    replaced_refs: list = dataclasses.field(default_factory=list)
    # --- tracing (repro.serving.trace): the step's clock anchors, the
    # head-sampling decision, and the raw stage timing tuples
    # (name, t0_rel_s, dur_s) span trees materialize from at account time
    t0: float = 0.0             # perf_counter at step start (span zero)
    wall0: float = 0.0          # time.time() at step start (trace anchor)
    sampled: bool = False       # head-sampling decision for this step
    stage_spans: list = dataclasses.field(default_factory=list)
    retry_spans: list = dataclasses.field(default_factory=list)


class SparseKernelEngine:
    """Batched, double-buffered, warm-startable, multi-backend,
    router-scheduled sparse-kernel server.

    Args:
        tuner: a learned ``Autotuner`` or prebuilt ``KernelAutotuner`` for
            the default platform (``None`` -> structural heuristic).  Only
            consulted when ``backends`` is not given.
        cache_size: per-backend autotune LRU capacity (default registry).
        arena_slots: double-buffer depth per cached pattern.
        persist_path: warm-start/save location for the namespaced cache file.
        autosave_every: if set, ``save()`` runs every N batches.
        interpret: selects the default platform of the stock registry —
            ``True`` -> ``tpu_interpret``, ``False`` -> ``tpu_pallas``
            (compiled; degrades to interpreter off-TPU).
        backends: an explicit ``BackendRegistry``; overrides ``tuner``/
            ``interpret``.  Register custom platforms here.
        router: the routing policy (``repro.serving.router``) deciding which
            backend serves each request.  Default ``StaticRouter`` —
            explicit tags honored, untagged traffic to the default platform.
        device_build: which scatter path builds block data.  ``"auto"``
            (default) takes the jitted device path for values that are
            already device-resident (``jax.Array``) and the numpy host
            path otherwise; ``"always"`` forces the device path (host
            values are transferred first); ``"never"`` forces the host
            path.  ``True``/``False`` alias always/never.
        health: an explicit ``HealthRegistry`` (inject one with a fake
            clock for deterministic breaker tests, or share one across
            engines fronting the same hardware).  Default: a fresh
            registry built from ``health_config``.
        health_config: breaker thresholds/backoff for the default-built
            registry (ignored when ``health`` is given).
        max_retries: ``1`` (default) re-serves a failed request once via
            the retry lane — re-routed to the healthiest surviving
            backend for its op; the response reports ``attempts=2``,
            ``failed_over_from``, and ``degraded=True``.  ``0`` disables
            the lane: the first executor failure propagates out of
            ``step()`` (leases and load still release).  The lane runs at
            most once per request regardless of larger values.
        validate_outputs: when ``True``, every executed output is checked
            for NaN/inf and the op's expected shape before it is returned;
            a bad output counts as a backend failure (feeding the breaker)
            and the request fails over like an executor raise.  Off by
            default — the check forces the async dispatch to completion,
            serializing the pipeline.
        trace_sample_rate: fraction of steps whose requests get full span
            traces into the flight recorder's main ring (deterministic
            head sampling — see ``repro.serving.trace``).  ``0.0``
            (default) disables head sampling; degraded / failed-over /
            retried requests are *always* traced into the error ring
            regardless, so postmortems never depend on sampling luck.
        trace_capacity: main trace ring size (last N sampled traces).
        trace_error_capacity: error trace ring size (always retained).
        event_capacity: structured event ring size (breaker transitions,
            failovers, quarantines, warm starts, spills, drains —
            ``engine.events``, exported as JSONL).
        warm_lane: enable the fused warm fast path (default ``True``).
            When every replayable condition holds for a request — a
            recorded prior routing decision for its (digest, op,
            requested platform), the decided backend healthy (breaker
            CLOSED, health generation unmoved since the decision was
            recorded), its cache entry resident — the staged pipeline
            collapses to one pass: replayed decision -> cached plan ->
            fused arena scatter (aligned buffer + cached zero-copy wrap)
            -> async dispatch, rejoining the shared execute/retry/account
            stages.  Mixed batches split once up front; cold/unhealthy
            requests take the staged sub-pipeline.  ``False`` restores
            the always-staged engine bit for bit.
        warm_sample_rate: fraction of warm steps whose per-request
            calibration observes run (deterministic counter sampling,
            default 1/16).  Health accounting, hit counters, and stage
            histograms are never sampled — only the per-request
            calibration ledger writes are.
        warm_drift_ms: optional calibration-drift gate — a warm
            candidate whose backend's drift gauge exceeds this many
            milliseconds falls through to the router (``None`` disables
            the check).
        clock: the monotonic clock ``KernelRequest.deadline_ts`` is
            checked against (default ``time.monotonic``).  Inject a fake
            for deterministic deadline tests; share one with the
            ``AdmissionQueue`` feeding this engine so budgets agree.

    Thread-safety: all public methods are safe under concurrent callers;
    see the module docstring for the per-thread lease protocol.
    """

    def __init__(self, tuner: KernelAutotuner | Autotuner | None = None,
                 cache_size: int = 128, arena_slots: int = 2,
                 persist_path: str | Path | None = None,
                 autosave_every: int | None = None, interpret: bool = True,
                 backends: BackendRegistry | None = None,
                 router: Router | None = None,
                 device_build: str | bool = "auto",
                 health: HealthRegistry | None = None,
                 health_config: HealthConfig | None = None,
                 max_retries: int = 1, validate_outputs: bool = False,
                 trace_sample_rate: float = 0.0, trace_capacity: int = 256,
                 trace_error_capacity: int = 64,
                 event_capacity: int = 1024,
                 warm_lane: bool = True,
                 warm_sample_rate: float = 0.0625,
                 warm_drift_ms: float | None = None,
                 clock=time.monotonic):
        if backends is None:
            backends = default_registry(
                tuner, cache_size=cache_size,
                default_platform="tpu_interpret" if interpret
                else "tpu_pallas")
        elif tuner is not None:
            raise ValueError("pass either a tuner or a backend registry, "
                             "not both")
        self.backends = backends
        self.default_platform = backends.default_platform
        self.router = router if router is not None else StaticRouter()
        # compat: the default platform's tuner (spmm if registered), what
        # single-backend callers passed in and still introspect
        # (featurize_calls, cache)
        try:
            self.tuner = backends.get(self.default_platform, "spmm").tuner
        except KeyError:
            default_bes = [be for be in backends
                           if be.platform == backends.default_platform]
            all_bes = default_bes or list(backends)
            if not all_bes:
                raise ValueError("backend registry has no backends")
            self.tuner = all_bes[0].tuner
        if device_build is True:
            device_build = "always"
        elif device_build is False:
            device_build = "never"
        if device_build not in ("auto", "always", "never"):
            raise ValueError(f"device_build must be auto/always/never, "
                             f"got {device_build!r}")
        self.device_build = device_build
        self.arena_slots = arena_slots
        self.autosave_every = autosave_every
        self.health = health if health is not None \
            else HealthRegistry(health_config)
        self.max_retries = int(max_retries)
        self.validate_outputs = bool(validate_outputs)
        self._clock = clock             # deadline checks only
        self.telemetry = EngineTelemetry()
        self.persist_path = Path(persist_path) if persist_path else None
        self._arenas: OrderedDict = OrderedDict()  # (plat, op, digest) -> arena
        # arenas are keyed across ALL backends, so the LRU bound is the sum
        # of the per-backend cache capacities — a max() here would thrash
        # arenas as soon as the combined working set outgrew one backend's
        self._arena_cap = sum(kt.cache.maxsize for kt in backends.tuners())
        # previous-batch leases (and the matching backend-load accounting)
        # are per *thread*: each serving stream double-buffers independently,
        # so one thread's step can never release (and let the arena
        # overwrite) a batch another thread's caller still holds.  Concurrent
        # streams hitting one pattern contend for its slots; losers take the
        # counted un-aliased fallback.
        self._stream = threading.local()
        self._outstanding = 0
        self._generation = 0            # monotonically stamps dispatches
        self._lock = threading.Lock()   # guards _arenas/_outstanding/_generation
        # --- observability (repro.serving.trace / .export) -------------
        self.recorder = FlightRecorder(trace_sample_rate,
                                       capacity=trace_capacity,
                                       error_capacity=trace_error_capacity)
        self.events = EventLog(capacity=event_capacity)
        self._trace_prefix = uuid.uuid4().hex[:8]   # unique per engine
        # per-generation dispatch->retire windows (wall clock) — what the
        # Chrome-trace exporter renders to make run-ahead overlap visible
        self._gen_log: deque = deque(maxlen=512)
        self.health.listeners.append(
            lambda ev: self.events.emit("breaker_transition", **ev))
        self._delta_prev: dict | None = None    # stats_delta() baseline
        self._ctor_ts = time.monotonic()        # zeroth delta window start
        # --- warm fast path ---------------------------------------------
        self.warm_lane = bool(warm_lane)
        self.warm_drift_ms = warm_drift_ms
        # per-request warm telemetry (calibration observes) is *sampled*:
        # one deterministic counter decision per warm step
        self._warm_sampler = CounterSampler(warm_sample_rate)
        # (digest, op, requested_platform) -> (decided platform, the
        # health generation the decision was recorded under) — guarded by
        # self._lock, LRU-bounded at the arena capacity
        self._warm_table: OrderedDict = OrderedDict()
        # id(mat) -> (digest, weakref) — SparseMatrix holds ndarrays and
        # is unhashable, so the memo keys on identity and a weakref
        # callback evicts entries when the matrix is collected
        self._digest_memo: dict = {}
        if self.persist_path is not None:
            self._warm_start()

    def _warm_start(self) -> None:
        """Route every persisted namespace to its registered backend.
        Corrupt files (or files with corrupt entries) are quarantined —
        renamed/copied to ``<path>.corrupt`` by ``load_grouped`` — and
        counted, never silently dropped."""
        existed = self.persist_path.exists()
        loaded = load_grouped(self.persist_path, quarantine=True,
                              on_event=self.events.emit)
        if loaded is None:
            if existed:
                self.telemetry.count(
                    persist_load_failures=1,
                    # the unreadable file was renamed out of the way
                    persist_quarantined=int(
                        not self.persist_path.exists()))
            return
        if loaded.quarantined:
            self.telemetry.count(persist_quarantined=1)
        restored = 0
        skipped = loaded.skipped
        for tag, items in loaded.entries.items():
            platform = self.default_platform if tag is LEGACY_NAMESPACE \
                else tag
            for (op, digest), entry in items:
                if (platform, op) in self.backends:
                    be = self.backends.get(platform, op)
                    be.tuner.cache.put((op, digest), entry)
                    restored += 1
                else:                   # orphaned tag: serve it cold instead
                    skipped += 1
        self.telemetry.count(warm_start_entries=restored,
                             warm_start_skipped=skipped)
        self.events.emit("warm_start", entries=restored, skipped=skipped,
                         quarantined=loaded.quarantined,
                         path=str(self.persist_path))

    # ------------------------------------------------------------- serving

    def step(self, requests: list[KernelRequest]) -> list[KernelResponse]:
        """Serve one micro-batch; returns responses in request order.

        Runs the staged pipeline route -> partition -> score -> build ->
        execute -> retry -> account (each stage is a ``_*_stage`` method and
        gets its own latency histogram).  Raises ``KeyError`` — before any
        work is done — if routing produces a ``(platform, op)`` tag with no
        registered backend.  An executor failure fails only its own
        request: with ``max_retries >= 1`` the request is re-served once on
        the healthiest surviving backend (retry lane); only a failed retry
        — or ``max_retries=0`` — propagates the error, and even then every
        arena lease and load counter this step took is released."""
        t_step = time.perf_counter()
        st = _StepState(requests)
        st.t0 = t_step
        st.wall0 = time.time()
        st.sampled = self.recorder.sample()
        try:
            # entry deadline gate: a request already past its deadline
            # never routes, partitions, or takes load — it completes as
            # deadline_exceeded at account time
            self._deadline_gate(st)
            if self.warm_lane and requests:
                warm = self._warm_probe(st)
                if warm:
                    return self._warm_step(st, warm, t_step)
            for name, stage in (("route", self._route_stage),
                                ("partition", self._partition_stage),
                                ("score", self._score_stage),
                                ("build", self._build_stage),
                                ("execute", self._execute_stage),
                                ("retry", self._retry_stage)):
                t0 = time.perf_counter()
                stage(st)
                dt = time.perf_counter() - t0
                self.telemetry.record_stage(name, dt)
                # raw span tuples — materialized into Trace objects only
                # for retained requests, at account time
                st.stage_spans.append((name, t0 - t_step, dt))
            return self._account_stage(st, t_step)
        except BaseException:
            # a stage failed mid-step: roll back this step's arena leases
            # and load accounting so a caller that catches the error keeps
            # a consistent engine (no permanently-saturated backend, no
            # exhausted arena).  Per-item, not all-or-nothing: one lease
            # whose release throws must not leak the rest.  Once
            # _account_stage has handed the batch to the stream, the
            # normal hand-off owns the cleanup.
            if not st.handed_off:
                for lease in st.leases:
                    try:
                        lease.release()
                    except Exception:
                        pass            # the original error propagates
                for be, n in st.loads:
                    try:
                        be.load.end(n)
                    except Exception:
                        pass
            raise

    # ------------------------------------------------------ pipeline stages

    def routing_context(self) -> RoutingContext:
        """The engine state routers consult (registry, calibration ledger,
        default platform, backend health) — also handy for driving a
        ``Router`` directly in tests."""
        return RoutingContext(self.backends, self.telemetry.calibration,
                              self.default_platform, self.health,
                              self.events)

    def _route_stage(self, st: _StepState) -> None:
        """Digest every pattern once, let the router decide each request's
        backend, and validate every decision against the registry — an
        unknown tag fails here, with nothing partially served.  Then the
        health gate runs: a decision aimed at an open circuit is rewritten
        to the failover target *before* any work is partitioned its way (a
        dead backend costs a dict lookup, not an executor timeout), unless
        the breaker grants a half-open probe."""
        if not st.digests:      # the warm probe (or retry lane) pre-digests
            st.digests = [self._digest(r.mat) for r in st.requests]
        if st.expired:
            # entry-expired requests never reach the router (no scoring,
            # no routing telemetry); their decisions stay None
            live = [i for i in range(len(st.requests))
                    if i not in st.expired]
            decs = self.router.route(
                [st.requests[i] for i in live],
                [st.digests[i] for i in live],
                self.routing_context()) if live else []
            st.decisions = [None] * len(st.requests)
            for i, d in zip(live, decs):
                st.decisions[i] = d
        else:
            st.decisions = self.router.route(st.requests, st.digests,
                                             self.routing_context())
        for r, d in zip(st.requests, st.decisions):
            if d is not None and (d.platform, r.op) not in self.backends:
                self.backends.get(d.platform, r.op)   # raises the KeyError
        self._health_gate(st)

    def _health_gate(self, st: _StepState) -> None:
        """Fast-fail requests whose decided backend's circuit is open."""
        admitted: dict[tuple[str, str], bool] = {}
        fast_fails = 0
        for i, (r, d) in enumerate(zip(st.requests, st.decisions)):
            if d is None:       # entry-expired: nothing routed to gate
                continue
            tag = (d.platform, r.op)
            if tag not in admitted:
                was_closed = self.health.state(tag) == CLOSED
                ok = self.health.allow(tag)
                if ok and not was_closed:
                    # this admission *is* the half-open probe grant; the
                    # execute stage returns it if nothing actually runs
                    st.probes.add(tag)
                admitted[tag] = ok
            if admitted[tag]:
                continue
            target = self._failover_target(r.op, exclude={d.platform})
            if target is None:
                continue    # nowhere to go: let the executor try anyway
            st.failover_from[i] = d.platform
            st.decisions[i] = RouteDecision(target, "failover")
            fast_fails += 1
        if fast_fails:
            self.telemetry.count(circuit_fast_fails=fast_fails)
            self.events.emit("circuit_fast_fail", n=fast_fails)

    def _failover_target(self, op: str, exclude=frozenset()) -> str | None:
        """The healthiest surviving backend for ``op``: lowest rolling
        failure rate among routable (non-open-circuit) candidates, ties
        resolved toward the default platform then alphabetically — with
        ``cpu_ref`` (never failing, always registered in the stock
        registry) as the natural floor.  When *every* candidate's circuit
        is open, the least-failing one is still returned — serving a
        request on a suspect backend beats dropping it."""
        cands = [be for be in self.backends
                 if be.op == op and be.platform not in exclude]
        if not cands:
            return None
        alive = [be for be in cands if self.health.routable(be.tag)]
        pool = alive or cands
        return min(pool, key=lambda be: (
            self.health.failure_rate(be.tag),
            be.platform != self.default_platform, be.platform)).platform

    def _deadline_gate(self, st: _StepState) -> None:
        """Expire every request whose ``deadline_ts`` has passed.

        Runs at step entry and again at the top of the score, build, and
        execute stages (covering staged, warm, cold-subset, and retry
        sub-batches alike — they all share those stage methods): an
        expired request is pulled out of its partition group so no later
        stage spends work on it, while partition-time load accounting and
        any lease its build already took stay in the step's pools — the
        normal hand-off/unwind paths release them, so early exit never
        leaks a lease or an in-flight count."""
        now = None
        for i, r in enumerate(st.requests):
            if r.deadline_ts is None or i in st.expired:
                continue
            if now is None:
                now = self._clock()
            if now >= r.deadline_ts:
                self._expire(st, i)

    def _expire(self, st: _StepState, i: int) -> None:
        """Mark request ``i`` expired and detach it from its partition."""
        st.expired.add(i)
        if st.decisions and st.decisions[i] is not None:
            idxs = st.groups.get((st.decisions[i].platform,
                                  st.requests[i].op))
            if idxs is not None and i in idxs:
                idxs.remove(i)

    def _partition_stage(self, st: _StepState) -> None:
        """Split the batch into one partition per decided (platform, op)
        tag, peek per-backend hit/miss status (so responses can report
        ``cache_hit`` truthfully), and raise each backend's in-flight
        depth by its share of the batch."""
        for i, r in enumerate(st.requests):
            if i in st.expired:
                continue
            st.groups.setdefault((st.decisions[i].platform, r.op),
                                 []).append(i)
        st.resolved = {tag: self.backends.get(*tag) for tag in st.groups}
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            cache = be.tuner.cache
            for i in idxs:
                st.hit_of[i] = (st.requests[i].op, st.digests[i]) in cache
            be.load.begin(len(idxs))
            st.loads.append((be, len(idxs)))

    def _score_stage(self, st: _StepState) -> None:
        """Tune every partition's misses: routing config hints install
        directly (the router's multi-space dispatch already scored them);
        the rest go through one batched ``get_batch`` dispatch per
        backend."""
        self._deadline_gate(st)
        st.entries = [None] * len(st.requests)
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            t0 = time.perf_counter()
            for i in idxs:
                d = st.decisions[i]
                if d.config is not None and not st.hit_of[i] \
                        and (tag[1], st.digests[i]) not in be.tuner.cache:
                    be.tuner.install(st.requests[i].mat, tag[1], d.config,
                                     digest=st.digests[i])
                    st.installs += 1
            unscored = sum((tag[1], st.digests[i]) not in be.tuner.cache
                           for i in idxs)
            got = be.tuner.get_batch([st.requests[i].mat for i in idxs],
                                     tag[1],
                                     digests=[st.digests[i] for i in idxs])
            for i, e in zip(idxs, got):
                st.entries[i] = e
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            if unscored:
                self.telemetry.count(score_dispatches=1)

    def _device_path(self, values) -> bool:
        """Whether this request's values take the jitted device scatter."""
        if self.device_build == "always":
            return True
        if self.device_build == "never":
            return False
        return isinstance(values, jax.Array)

    def _build_stage(self, st: _StepState) -> None:
        """Scatter each request's values through its cached plan into an
        arena slot (double buffer), falling back to a counted un-aliased
        build on slot exhaustion.  Device-resident values scatter on
        device (one async jitted dispatch, no host numpy); host values
        take the numpy path.  Builds issued while this thread's previous
        generation is still in flight count as *overlapped* — the async
        pipeline working as intended."""
        self._deadline_gate(st)
        st.built = [None] * len(st.requests)
        st.device_flags = [False] * len(st.requests)
        overlapped = bool(getattr(self._stream, "leases", ()))
        n_device = n_host = 0
        for tag, idxs in st.groups.items():
            t0 = time.perf_counter()
            for i in idxs:
                r, entry = st.requests[i], st.entries[i]
                values = r.values if r.values is not None \
                    else np.ones(r.mat.nnz, np.float32)
                on_device = self._device_path(values)
                st.device_flags[i] = on_device
                arena = self._arena_for(tag + (st.digests[i],), entry)
                try:
                    lease = arena.build_device(values) if on_device \
                        else arena.build(values)
                    st.leases.append(lease)
                    st.built[i] = (lease.matrix, True)
                except ArenaOverrun:
                    self.telemetry.count(arena_fallbacks=1)
                    built = entry.plan.build_device(values) if on_device \
                        else entry.plan.build(values)
                    st.built[i] = (built, False)
                if on_device:
                    n_device += 1
                else:
                    n_host += 1
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + dt
        self.telemetry.count(
            device_builds=n_device, host_builds=n_host,
            overlapped_builds=(n_device + n_host) if overlapped else 0)

    def _execute_stage(self, st: _StepState) -> None:
        """Launch each backend's kernel for requests carrying a dense
        operand; operand-less requests stay prepare-only.

        Fault isolation: each request's launch (and opt-in output guard)
        runs under its own ``try`` — one backend raising fails only its
        partition's requests, captured per index in ``st.errors`` for the
        retry stage, recorded against the backend's health.  A granted
        half-open probe whose partition had nothing to execute is returned
        to the breaker (no outcome will ever arrive for it)."""
        self._deadline_gate(st)
        st.outputs = [None] * len(st.requests)
        st.errors = [None] * len(st.requests)
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            t0 = time.perf_counter()
            executed = 0
            for i in idxs:
                r = st.requests[i]
                if r.operand is None:
                    continue
                executed += 1
                try:
                    out = be.run(st.entries[i].config, st.built[i][0],
                                 r.operand)
                    if self.validate_outputs:
                        self._guard_output(out, r, st.built[i][0])
                except Exception as e:      # KeyboardInterrupt etc. escape
                    st.errors[i] = e
                    self.health.record_failure(tag)
                    self.telemetry.count(
                        execute_failures=1,
                        output_guard_failures=int(
                            isinstance(e, OutputGuardError)))
                else:
                    st.outputs[i] = out
            if executed == 0 and tag in st.probes:
                self.health.cancel_probe(tag)
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + dt

    @staticmethod
    def _guard_output(out, r, matrix) -> None:
        """Opt-in output validation: NaN/inf and op shape.  Forces the
        async dispatch to completion (that is the cost of the guard)."""
        arr = np.asarray(out)
        if r.op == "spmm":
            want = (matrix.shape[0], int(np.shape(r.operand)[-1]))
            if tuple(arr.shape) != want:
                raise OutputGuardError(
                    f"spmm output shape {tuple(arr.shape)} != {want}")
        elif r.op == "sddmm":
            if tuple(arr.shape) != tuple(np.shape(matrix.data)):
                raise OutputGuardError(
                    f"sddmm output shape {tuple(arr.shape)} != "
                    f"{tuple(np.shape(matrix.data))}")
        if arr.dtype.kind == "f" and not np.all(np.isfinite(arr)):
            raise OutputGuardError("non-finite values in kernel output")

    def _retry_stage(self, st: _StepState) -> None:
        """Re-serve this step's failed requests once on the healthiest
        surviving backend for their op (``cpu_ref`` as the stock floor).

        The failed indices run through partition -> score -> build ->
        execute as a sub-batch whose leases and load accounting merge into
        the parent step (so hand-off and unwind cover them); on success
        each index's partition bookkeeping moves to the fallback tag and
        its response will report ``attempts=2`` / ``failed_over_from`` /
        ``degraded``.  A failed *retry* — or ``max_retries=0`` — re-raises
        the failure to the caller."""
        failed = [i for i, e in enumerate(st.errors) if e is not None] \
            if st.errors else []
        if not failed:
            return
        # the retry lane respects the remaining deadline budget: a failed
        # request whose deadline has passed completes as deadline_exceeded
        # instead of burning a fallback backend's time (the failure was
        # already recorded against the original backend's health)
        if any(st.requests[i].deadline_ts is not None for i in failed):
            now = self._clock()
            exhausted = [i for i in failed
                         if st.requests[i].deadline_ts is not None
                         and now >= st.requests[i].deadline_ts]
            if exhausted:
                for i in exhausted:
                    st.errors[i] = None
                    self._expire(st, i)
                self.telemetry.count(
                    retry_deadline_exhausted=len(exhausted))
                failed = [i for i in failed if i not in st.expired]
                if not failed:
                    return
        if self.max_retries < 1:
            raise st.errors[failed[0]]
        targets: dict[tuple[str, str], str | None] = {}
        for i in failed:
            key = (st.decisions[i].platform, st.requests[i].op)
            if key not in targets:
                targets[key] = self._failover_target(
                    st.requests[i].op, exclude={st.decisions[i].platform})
            if targets[key] is None:    # nowhere to fail over to
                raise st.errors[i]
        sub = _StepState([st.requests[i] for i in failed])
        sub.digests = [st.digests[i] for i in failed]
        sub.decisions = [
            RouteDecision(targets[(st.decisions[i].platform,
                                   st.requests[i].op)], "failover")
            for i in failed]
        try:
            for name, stage in (("partition", self._partition_stage),
                                ("score", self._score_stage),
                                ("build", self._build_stage),
                                ("execute", self._execute_stage)):
                t0 = time.perf_counter()
                stage(sub)
                # sub-stage spans, relative to the PARENT step's t0 — they
                # nest under the retry span in retained requests' traces
                st.retry_spans.append((f"retry.{name}", t0 - st.t0,
                                       time.perf_counter() - t0))
        finally:
            # parent step owns the sub-batch's resources on every path
            st.leases.extend(sub.leases)
            st.loads.extend(sub.loads)
        for k, i in enumerate(failed):
            if sub.errors[k] is not None:
                self.telemetry.count(retry_failures=1)
                raise sub.errors[k]     # double failure: surface it
        if sub.expired:
            # the deadline passed while the retry sub-batch was being
            # scored/built: those requests expire in the parent too (their
            # first-attempt failure stands; sub leases/loads merged above)
            for k, i in enumerate(failed):
                if k in sub.expired:
                    st.errors[i] = None
                    self._expire(st, i)
            self.telemetry.count(retry_deadline_exhausted=len(sub.expired))
            failed = [i for k, i in enumerate(failed)
                      if k not in sub.expired]
            sub_k = [k for k in range(len(sub.requests))
                     if k not in sub.expired]
        else:
            sub_k = list(range(len(sub.requests)))
        if not failed:
            return
        self.telemetry.count(failovers=len(failed))
        self.events.emit(
            "failover", n=len(failed),
            moves=sorted({f"{st.decisions[i].platform}->"
                          f"{sub.decisions[k].platform}"
                          for k, i in zip(sub_k, failed)}))
        for k, i in zip(sub_k, failed):
            old_tag = (st.decisions[i].platform, st.requests[i].op)
            new_tag = (sub.decisions[k].platform, st.requests[i].op)
            st.groups[old_tag].remove(i)
            st.groups.setdefault(new_tag, []).append(i)
            st.resolved.setdefault(new_tag, sub.resolved[new_tag])
            if st.built[i] is not None:
                # the abandoned first-attempt build was still an async
                # dispatch — keep its ref so drain() can force it
                st.replaced_refs.append(st.built[i][0].data)
            st.failover_from[i] = st.decisions[i].platform
            st.decisions[i] = sub.decisions[k]
            st.retried.add(i)
            st.entries[i] = sub.entries[k]
            st.built[i] = sub.built[k]
            st.device_flags[i] = sub.device_flags[k]
            st.hit_of[i] = sub.hit_of[k]
            st.outputs[i] = sub.outputs[k]
            st.errors[i] = None
        for tag, s in sub.tag_seconds.items():
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + s
        for tag, s in sub.tag_serve_seconds.items():
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + s
        st.installs += sub.installs

    # ------------------------------------------------------ warm fast path

    def _digest(self, mat: SparseMatrix) -> str:
        """``matrix_digest`` memoized on object identity: repeated traffic
        re-serving the same ``SparseMatrix`` objects (the warm steady
        state) pays the digest hash once, not once per step.  A weakref
        callback evicts the memo entry when the matrix is collected, so
        the memo tracks the live working set, not history."""
        memo = self._digest_memo
        key = id(mat)
        hit = memo.get(key)
        if hit is not None and hit[1]() is mat:
            return hit[0]
        dg = matrix_digest(mat)
        try:
            ref = weakref.ref(mat, lambda _r, _k=key: memo.pop(_k, None))
        except TypeError:           # un-weakref-able pattern type: no memo
            return dg
        memo[key] = (dg, ref)
        return dg

    def _warm_probe(self, st: _StepState) -> dict[int, str] | None:
        """Decide, in one cheap pass, which of this batch's requests can
        take the warm lane: a recorded prior decision (digest + op +
        requested platform) whose backend is still registered, whose
        breaker is CLOSED, whose health generation hasn't moved since the
        decision was recorded (the sticky-invalidation analogue — a moved
        generation drops the entry), whose calibration drift is under
        ``warm_drift_ms`` (if configured), and whose cache entry is still
        resident.  A ``max_inflight`` router keeps its saturation
        semantics: warm traffic that would cross the limit falls through
        so the router can count/spill it.

        Returns ``{index: platform}`` for the warm subset (empty/None ->
        fully staged step).  The digests computed here are kept on the
        step state, so a fallthrough costs the staged path nothing."""
        reqs = st.requests
        st.digests = [self._digest(r.mat) for r in reqs]
        with self._lock:
            table = self._warm_table
            recs = [None if i in st.expired
                    else table.get((st.digests[i], r.op, r.platform))
                    for i, r in enumerate(reqs)]
        if not any(rec is not None for rec in recs):
            return None
        gen_of: dict[str, int] = {}
        closed: dict[tuple, bool] = {}
        calm: dict[tuple, bool] = {}
        warm: dict[int, str] = {}
        stale: list[tuple] = []
        fallthrough = 0
        for i, (r, rec) in enumerate(zip(reqs, recs)):
            if rec is None:
                continue
            plat, gen0 = rec
            tag = (plat, r.op)
            if tag not in self.backends:
                stale.append((st.digests[i], r.op, r.platform))
                fallthrough += 1
                continue
            g = gen_of.get(plat)
            if g is None:
                g = gen_of[plat] = self.health.generation(plat)
            if g != gen0:           # breaker transitioned since recording
                stale.append((st.digests[i], r.op, r.platform))
                fallthrough += 1
                continue
            ok = closed.get(tag)
            if ok is None:
                ok = closed[tag] = self.health.state(tag) == CLOSED
            if not ok:              # open/half-open: staged gate decides
                fallthrough += 1
                continue
            if self.warm_drift_ms is not None:
                c = calm.get(tag)
                if c is None:
                    d = self.telemetry.calibration.drift(plat, op=r.op)
                    c = calm[tag] = d is None or d <= self.warm_drift_ms
                if not c:           # drifting: let routing re-decide
                    fallthrough += 1
                    continue
            be = self.backends.get(plat, r.op)
            if (r.op, st.digests[i]) not in be.tuner.cache:
                stale.append((st.digests[i], r.op, r.platform))
                fallthrough += 1
                continue
            warm[i] = plat
        mi = getattr(self.router, "max_inflight", None)
        if mi is not None and warm:
            by_tag: dict[tuple, list[int]] = {}
            for i, plat in warm.items():
                by_tag.setdefault((plat, reqs[i].op), []).append(i)
            for tag, idxs in by_tag.items():
                if self.backends.get(*tag).load.inflight + len(idxs) > mi:
                    for i in idxs:
                        del warm[i]
                    fallthrough += len(idxs)
        if stale:
            with self._lock:
                for key in stale:
                    self._warm_table.pop(key, None)
            self.telemetry.count(warm_invalidations=len(stale))
            self.events.emit("warm_invalidation", n=len(stale))
        if fallthrough:
            self.telemetry.count(warm_fallthroughs=fallthrough)
        return warm or None

    def _warm_step(self, st: _StepState, warm: dict[int, str],
                   t_step: float) -> list[KernelResponse]:
        """The fused warm lane: for the warm subset, route->partition->
        score->build collapse into one pass (recorded decision -> cache
        entry -> fused arena scatter), the cold remainder runs the staged
        sub-pipeline once, and both rejoin the *shared* execute / retry /
        account stages — so fault isolation, breaker feeding, error-ring
        retention, generation hand-off, and backpressure are the same code
        on both paths.  Per-request telemetry (calibration observes) is
        sampled by the deterministic counter sampler; the rest of the
        bookkeeping is amortized per step."""
        t0 = time.perf_counter()
        self._warm_prepare(st, warm)
        self._warm_build(st, warm)
        dt = time.perf_counter() - t0
        self.telemetry.record_stage("warm", dt)
        st.stage_spans.append(("warm", t0 - t_step, dt))
        cold = [i for i in range(len(st.requests))
                if i not in warm and i not in st.expired]
        if cold:
            self._cold_subset(st, cold)
        for name, stage in (("execute", self._execute_stage),
                            ("retry", self._retry_stage)):
            t0 = time.perf_counter()
            stage(st)
            dt = time.perf_counter() - t0
            self.telemetry.record_stage(name, dt)
            st.stage_spans.append((name, t0 - t_step, dt))
        warm_sampled = self._warm_sampler.sample()
        self.telemetry.count(warm_steps=1, warm_requests=len(warm),
                             warm_sampled_steps=int(warm_sampled))
        return self._account_stage(st, t_step, warm_set=warm,
                                   warm_sampled=warm_sampled)

    def _warm_prepare(self, st: _StepState, warm: dict[int, str]) -> None:
        """Stand in for route/partition/score on the warm subset: replay
        the recorded decision, group per tag, fetch cache entries (one
        ``cache.get`` per request — the same hit accounting the staged
        score stage produces), and raise backend load.  A request that
        lost its entry to a concurrent eviction between probe and here is
        re-scored individually and reported as a miss."""
        n = len(st.requests)
        st.decisions = [None] * n
        st.entries = [None] * n
        st.built = [None] * n
        st.device_flags = [False] * n
        for i, plat in warm.items():
            st.decisions[i] = RouteDecision(plat, "warm")
            st.groups.setdefault((plat, st.requests[i].op), []).append(i)
        st.resolved = {tag: self.backends.get(*tag) for tag in st.groups}
        for tag, idxs in st.groups.items():
            be = st.resolved[tag]
            cache = be.tuner.cache
            for i in idxs:
                entry = cache.get((st.requests[i].op, st.digests[i]))
                if entry is None:
                    entry = be.tuner.get_batch(
                        [st.requests[i].mat], st.requests[i].op,
                        digests=[st.digests[i]])[0]
                    st.hit_of[i] = False
                else:
                    st.hit_of[i] = True
                st.entries[i] = entry
            be.load.begin(len(idxs))
            st.loads.append((be, len(idxs)))

    def _warm_build(self, st: _StepState, warm: dict[int, str]) -> None:
        """The warm subset's builds: host values scatter into the arena's
        *fused* slot (64-byte-aligned buffer + one cached zero-copy wrap
        — steady state touches only the nnz positions and never copies
        the block data), device values take the donated device path
        unchanged.  Slot exhaustion falls back to the counted un-aliased
        build, exactly like the staged build stage."""
        overlapped = bool(getattr(self._stream, "leases", ()))
        n_device = n_host = n_fused = 0
        for tag, idxs in st.groups.items():
            t0 = time.perf_counter()
            for i in idxs:
                r, entry = st.requests[i], st.entries[i]
                values = r.values if r.values is not None \
                    else np.ones(r.mat.nnz, np.float32)
                on_device = self._device_path(values)
                st.device_flags[i] = on_device
                arena = self._arena_for(tag + (st.digests[i],), entry)
                try:
                    if on_device:
                        lease = arena.build_device(values)
                    else:
                        lease = arena.build_fused(values)
                        n_fused += 1
                    st.leases.append(lease)
                    st.built[i] = (lease.matrix, True)
                except ArenaOverrun:
                    self.telemetry.count(arena_fallbacks=1)
                    built = entry.plan.build_device(values) if on_device \
                        else entry.plan.build(values)
                    st.built[i] = (built, False)
                if on_device:
                    n_device += 1
                else:
                    n_host += 1
            dt = time.perf_counter() - t0
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + dt
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + dt
        self.telemetry.count(
            device_builds=n_device, host_builds=n_host,
            fused_builds=n_fused,
            overlapped_builds=(n_device + n_host) if overlapped else 0)

    def _cold_subset(self, st: _StepState, cold: list[int]) -> None:
        """A mixed batch's cold/unhealthy remainder runs the staged
        route->partition->score->build sub-pipeline once (split up
        front, not per stage) and merges into the parent step before the
        shared execute — the retry-lane merge pattern, with the parent
        owning the sub-batch's leases and loads on every path."""
        sub = _StepState([st.requests[i] for i in cold])
        sub.digests = [st.digests[i] for i in cold]
        sub.t0 = st.t0
        sub.wall0 = st.wall0
        try:
            for name, stage in (("route", self._route_stage),
                                ("partition", self._partition_stage),
                                ("score", self._score_stage),
                                ("build", self._build_stage)):
                t0 = time.perf_counter()
                stage(sub)
                dt = time.perf_counter() - t0
                self.telemetry.record_stage(name, dt)
                st.stage_spans.append((name, t0 - st.t0, dt))
        finally:
            st.leases.extend(sub.leases)
            st.loads.extend(sub.loads)
        for k, i in enumerate(cold):
            st.decisions[i] = sub.decisions[k]
            st.entries[i] = sub.entries[k]
            st.built[i] = sub.built[k]
            st.device_flags[i] = sub.device_flags[k]
            st.hit_of[i] = sub.hit_of[k]
            if k in sub.failover_from:
                st.failover_from[i] = sub.failover_from[k]
            if k in sub.expired:    # deadline passed inside the sub-pipeline
                st.expired.add(i)   # (sub groups already pruned, so the
                                    # group merge below skips it)
        st.probes |= sub.probes
        st.resolved.update(sub.resolved)
        for tag, idxs in sub.groups.items():
            st.groups.setdefault(tag, []).extend(cold[k] for k in idxs)
        for tag, s in sub.tag_seconds.items():
            st.tag_seconds[tag] = st.tag_seconds.get(tag, 0.0) + s
        for tag, s in sub.tag_serve_seconds.items():
            st.tag_serve_seconds[tag] = \
                st.tag_serve_seconds.get(tag, 0.0) + s
        st.installs += sub.installs
        st.replaced_refs.extend(sub.replaced_refs)

    def _warm_record(self, st: _StepState, responses,
                     warm_set=frozenset()) -> None:
        """Record this step's replayable routing outcomes into the warm
        table: deliberate per-pattern decisions (explicit / default /
        sticky / cost_model) that finished clean, stamped with the
        platform's current health generation so any breaker transition
        invalidates them at probe time.  LRU-bounded at the arena
        capacity."""
        gen_of: dict[str, int] = {}
        cand = []
        for i, resp in enumerate(responses):
            if i in warm_set or resp.degraded or resp.attempts > 1 \
                    or resp.deadline_exceeded:
                continue
            if st.decisions[i].reason not in _WARM_REASONS:
                continue
            g = gen_of.get(resp.platform)
            if g is None:
                g = gen_of[resp.platform] = \
                    self.health.generation(resp.platform)
            cand.append(((st.digests[i], st.requests[i].op,
                          st.requests[i].platform), (resp.platform, g)))
        if not cand:
            return
        with self._lock:
            table = self._warm_table
            for key, val in cand:
                table[key] = val
                table.move_to_end(key)
            while len(table) > max(self._arena_cap, 1):
                table.popitem(last=False)

    def _account_stage(self, st: _StepState, t_step: float,
                       warm_set=frozenset(),
                       warm_sampled: bool = True) -> list[KernelResponse]:
        """Assemble responses, fold this step into telemetry (per-backend
        serve time, routing decisions, observed-vs-predicted calibration),
        and hand off the double buffer: the *previous* batch's leases and
        load accounting release now that this batch is in flight.  Last of
        all, retained requests' span trees materialize into the flight
        recorder — strictly after the batch is dispatched, so tracing
        never sits between a request and its kernel launch."""
        t_acct = time.perf_counter()
        total_hits = total_misses = 0
        for tag, idxs in st.groups.items():
            if not idxs:        # retry lane moved this tag's last request
                continue
            d_hits = sum(st.hit_of[i] for i in idxs)
            total_hits += d_hits
            total_misses += len(idxs) - d_hits
            self.telemetry.record_backend(
                "/".join(tag), requests=len(idxs), hits=d_hits,
                misses=len(idxs) - d_hits,
                seconds=st.tag_seconds.get(tag, 0.0))
            # every served route feeds the observed-latency ledger; routes
            # that carried a prediction also calibrate predicted-vs-observed.
            # Calibration sees build+execute time only — folding in the
            # score stage would charge one-time tuning cost to whichever
            # backend just received fresh patterns, and the early EMA
            # samples it poisons are exactly the ones that steer routing
            per_req = st.tag_serve_seconds.get(tag, 0.0) / len(idxs) \
                if idxs else 0.0
            warm_exec = 0
            for i in idxs:
                # warm-lane per-request calibration is *sampled* (the
                # deterministic counter sampler): the observed latencies
                # of replayed decisions are near-identical step to step,
                # so one observe in 1/rate steps keeps the ledger honest
                # at a fraction of the bookkeeping
                if i not in warm_set or warm_sampled:
                    self.telemetry.calibration.observe(
                        tag[0], per_req, st.decisions[i].predicted,
                        op=tag[1])
                # only executed requests feed the breaker — a prepare-only
                # request proves nothing about the executor
                if st.requests[i].operand is not None:
                    if i in warm_set:
                        warm_exec += 1      # batched below: one lock/tag
                    else:
                        self.health.record_success(tag, per_req)
            if warm_exec:
                self.health.record_successes(tag, warm_exec, per_req)
        reasons: dict[tuple[str, str], int] = {}
        for d in st.decisions:
            if d is None:       # entry-expired: never routed
                continue
            key = (d.platform, d.reason)
            reasons[key] = reasons.get(key, 0) + 1
        for (platform, reason), n in reasons.items():
            self.telemetry.record_route(platform, reason, n)
        if st.installs:
            self.telemetry.count(route_config_installs=st.installs)
        self.telemetry.count(hits=total_hits, misses=total_misses)

        with self._lock:
            self._generation += 1
            generation = self._generation
        responses = []
        for i in range(len(st.requests)):
            if i in st.expired:
                # expired requests hand back no plan/matrix/output: any
                # build they sunk before expiring stays in the step's
                # lease/ref pools and releases through the normal hand-off
                d = st.decisions[i] if st.decisions else None
                responses.append(KernelResponse(
                    st.digests[i] if st.digests else "", {}, None, None,
                    False, False, d.platform if d is not None else "",
                    "deadline", False, generation,
                    deadline_exceeded=True))
                continue
            matrix, in_arena = st.built[i]
            responses.append(KernelResponse(
                st.digests[i], st.entries[i].config, matrix,
                st.outputs[i], st.hit_of[i], in_arena,
                st.decisions[i].platform, st.decisions[i].reason,
                st.device_flags[i], generation,
                2 if i in st.retried else 1,
                st.failover_from.get(i), i in st.failover_from))
        if self.warm_lane:
            self._warm_record(st, responses, warm_set)

        # everything this generation dispatched asynchronously — every
        # built matrix (arena-leased AND overrun-fallback builds, which
        # carry no lease but were still async device dispatches, plus
        # first-attempt builds the retry lane abandoned) and the kernel
        # outputs — so drain() can force completion of all of it
        refs = [b[0].data for b in st.built if b is not None] \
            + st.replaced_refs \
            + [o for o in st.outputs if o is not None]

        # this stream's batch N-1 kernels were dispatched a full step ago —
        # its slots can rotate now that batch N is in flight (double-buffer
        # hand-off), and its backend in-flight depth drops with it.  The
        # thread-local swap is what keys release to the dispatch
        # generation: a stream holds exactly one outstanding generation,
        # and only the one being swapped out is ever released.
        prev_leases, prev_loads, prev_refs, prev_gen = self._swap_stream(
            st.leases, st.loads, refs, gen_info=(generation, st.wall0))
        st.handed_off = True
        # two-deep pipeline backpressure: wait for generation N-1 (its
        # entire step overlapped batch N's host work) before rotating its
        # slots — run-ahead stays bounded at two generations instead of
        # flooding the dispatch queue, and a donated device buffer can
        # never be re-donated while a consumer might still read it.
        # A ref that errors at completion time (poisoned async dispatch)
        # must not leak the generation's leases/loads: release everything
        # first, then surface the first error.
        t_wait = time.perf_counter()
        err = self._release_generation(prev_refs, prev_leases, prev_loads)
        self._retire_generation(prev_gen, time.perf_counter() - t_wait)
        if err is not None:
            raise err

        self.telemetry.count(requests=len(st.requests), batches=1,
                             deadline_expired=len(st.expired))
        self.telemetry.record_stage("step", time.perf_counter() - t_step)
        self._finish_traces(st, responses, t_acct)
        if (self.autosave_every and self.persist_path is not None
                and self.telemetry.batches % self.autosave_every == 0):
            self.save()
        return responses

    def _finish_traces(self, st: _StepState, responses, t_acct) -> None:
        """Materialize span trees for this step's *retained* requests and
        file them in the flight recorder.

        Retention = head sampling OR tail: a head-sampled step retains
        every request (main ring); a degraded / retried / failed-over
        request is retained unconditionally (error ring) — the flight
        recorder's whole point is that the traces behind an incident
        survive even at ``trace_sample_rate=0``.  The un-retained fast
        path is one set construction over the (almost always empty)
        degraded indices."""
        degraded = {i for i, r in enumerate(responses)
                    if r.degraded or r.attempts > 1}
        if not st.sampled and not degraded:
            return
        now = time.perf_counter()
        acct = ("account", t_acct - st.t0, now - t_acct)
        idxs = range(len(responses)) if st.sampled else sorted(degraded)
        for i in idxs:
            r = responses[i]
            tid = st.requests[i].trace_id \
                or f"{self._trace_prefix}-{r.generation:06x}-{i:03x}"
            r.trace_id = tid
            children = []
            for name, rel, dur in st.stage_spans:
                if name == "retry":
                    if i not in st.retried:
                        continue        # clean requests skip the lane
                    children.append(Span(
                        name, rel, dur,
                        attrs={"failed_over_from": r.failed_over_from,
                               "attempts": r.attempts},
                        children=[Span(n2, rel2, d2) for n2, rel2, d2
                                  in st.retry_spans]))
                else:
                    children.append(Span(name, rel, dur))
            children.append(Span(*acct))
            root = Span("request", 0.0, now - st.t0,
                        attrs={"digest": r.digest,
                               "op": st.requests[i].op,
                               "platform": r.platform,
                               "route_reason": r.route_reason,
                               "cache_hit": r.cache_hit,
                               "device_built": r.device_built,
                               "attempts": r.attempts,
                               "failed_over_from": r.failed_over_from,
                               "degraded": r.degraded,
                               "deadline_exceeded": r.deadline_exceeded},
                        children=children)
            self.recorder.record(
                Trace(tid, st.wall0,
                      "degraded" if i in degraded else "ok",
                      st.requests[i].op, r.platform, r.digest,
                      r.generation, root),
                sampled=st.sampled, error=i in degraded)

    # ----------------------------------------------------- stream plumbing

    def _retire_generation(self, gen_info, wait_s: float,
                           drained: bool = False) -> None:
        """Record one generation's dispatch->retire wall-clock window (and
        how long the releasing step blocked on it).  Overlapping windows
        in this log ARE the PR-5 run-ahead — the Chrome-trace exporter
        renders them as per-generation rows."""
        if gen_info is None:
            return
        generation, dispatched = gen_info
        with self._lock:
            self._gen_log.append({"generation": generation,
                                  "dispatched": dispatched,
                                  "retired": time.time(),
                                  "wait_ms": wait_s * 1e3,
                                  "drained": drained})

    def _arena_for(self, key, entry: TunedKernel) -> PlanArena:
        with self._lock:
            arena = self._arenas.get(key)
            if arena is None or arena.plan is not entry.plan:
                arena = PlanArena(entry.plan, n_slots=self.arena_slots)
                self._arenas[key] = arena
            self._arenas.move_to_end(key)
            while len(self._arenas) > max(self._arena_cap, 1):
                self._arenas.popitem(last=False)
            return arena

    @staticmethod
    def _release_generation(refs, leases, loads) -> BaseException | None:
        """Wait on a generation's dispatch refs, then release its leases
        and load accounting — per item, unconditionally.  Returns the
        first completion error (if any) instead of raising, so one
        poisoned ref can never leak the rest of the generation."""
        err = None
        for ref in refs:
            try:
                jax.block_until_ready(ref)
            except Exception as e:
                if err is None:
                    err = e
        for lease in leases:
            try:
                lease.release()
            except Exception as e:
                if err is None:
                    err = e
        for be, n in loads:
            be.load.end(n)
        return err

    def _swap_stream(self, leases: list[ArenaLease],
                     loads: list[tuple[KernelBackend, int]],
                     refs: list = (), gen_info=None):
        """Install this thread's new outstanding batch (leases, backend-load
        shares, async dispatch refs, and its ``(generation, dispatch wall
        time)`` identity); return the old one (leases, loads, refs,
        gen_info — to be released, and optionally waited on, together).  A
        stream holds exactly one outstanding generation, so this swap IS
        the generation hand-off."""
        prev_leases = getattr(self._stream, "leases", [])
        prev_loads = getattr(self._stream, "loads", [])
        prev_refs = getattr(self._stream, "refs", [])
        prev_gen = getattr(self._stream, "gen_info", None)
        self._stream.leases = leases
        self._stream.loads = loads
        self._stream.refs = list(refs)
        self._stream.gen_info = gen_info
        with self._lock:
            self._outstanding += len(leases) - len(prev_leases)
        return prev_leases, prev_loads, prev_refs, prev_gen

    def release_stream(self) -> None:
        """Release the calling thread's outstanding arena leases and drop
        its backend in-flight accounting (call once this stream's last
        results have been consumed or copied).  Idempotent: a second call
        with nothing outstanding is a no-op, and it never touches another
        thread's leases.  Does NOT wait for in-flight dispatches — use
        ``drain()`` to force completion first."""
        prev_leases, prev_loads, _, prev_gen = self._swap_stream([], [])
        for lease in prev_leases:
            lease.release()
        for be, n in prev_loads:
            be.load.end(n)
        self._retire_generation(prev_gen, 0.0)

    def drain(self) -> None:
        """Force completion of the calling thread's in-flight work, then
        release every outstanding generation.

        Blocks until every array the stream's last dispatched batch
        produced (arena matrices and kernel outputs) is ready, releases the
        leases and load accounting, and counts a ``drain_wait`` when there
        was anything to wait on.  After ``drain()`` the thread holds no
        leases of any generation — the synchronous point the async pipeline
        is measured against, and the right call before tearing a stream
        down or handing its results across threads.  Idempotent."""
        prev_leases, prev_loads, prev_refs, prev_gen = \
            self._swap_stream([], [])
        pending = bool(prev_leases or prev_loads or prev_refs)
        t_wait = time.perf_counter()
        err = self._release_generation(prev_refs, prev_leases, prev_loads)
        self._retire_generation(prev_gen, time.perf_counter() - t_wait,
                                drained=True)
        if pending:
            self.telemetry.count(drain_waits=1)
            self.events.emit("drain", refs=len(prev_refs),
                             leases=len(prev_leases))
        if err is not None:
            raise err

    def flush(self) -> None:
        """Alias of ``release_stream()`` (the historical name)."""
        self.release_stream()

    # ------------------------------------------------------- observability

    @property
    def featurize_calls(self) -> int:
        """Total featurize+score computations across every backend's tuner
        (shared tuners counted once) — zero on fully warm-started traffic."""
        return sum(kt.featurize_calls for kt in self.backends.tuners())

    def stats(self) -> dict:
        """Snapshot of all counters: global hit rates, per-stage latency
        histograms, ``"build_paths"`` (device vs host scatter counts,
        overlap ratio, drain waits), a ``"backends"`` section keyed
        ``"platform/op"`` with per-backend requests / hit rate / serve
        p50-p99, a ``"routing"`` section (decision reasons, per-platform
        request shares, spill + hysteresis counts, per-platform
        observed-vs-predicted calibration with per-op detail), per-backend
        live load (``"load"``: in-flight depth / peak / total, plus the
        EMA-``"smoothed"`` depth when a ``LoadAwareRouter`` maintains
        one), a ``"health"`` section (per-tag circuit-breaker snapshots
        under ``"breakers"`` plus execute-failure / output-guard /
        fast-fail / failover counters — see ``docs/serving.md``), cache
        and arena occupancy, persistence events, a ``"tracing"`` section
        (flight-recorder sampler/ring counters), an ``"events"`` section
        (event-log volume by kind), and a monotonic ``"ts"`` (what
        ``stats_delta()`` computes interval rates over).  ``"cache"`` is
        the *default* backend's cache (pre-registry compat); ``"caches"``
        reports every platform's occupancy and eviction counters.  Safe to
        call concurrently with ``step`` — histogram rendering happens
        outside the telemetry lock, so a stats poll never stalls
        accounting."""
        out = self.telemetry.snapshot(cache=self.tuner.cache)
        out["routing"]["spill_hysteresis"] = getattr(self.router,
                                                     "spill_hysteresis", 0)
        out["featurize_calls"] = self.featurize_calls
        out["caches"] = {}
        for plat, caches in self.backends.caches_by_platform().items():
            for j, c in enumerate(caches):
                key = plat if len(caches) == 1 else f"{plat}[{j}]"
                out["caches"][key] = {
                    "size": len(c), "maxsize": c.maxsize, "hits": c.hits,
                    "misses": c.misses, "evictions": c.evictions}
        out["load"] = {tag: load.snapshot()
                       for tag, load in self.backends.loads_by_tag().items()}
        smoothed = getattr(self.router, "smoothed_depth", None)
        if smoothed:
            for tag, v in smoothed.items():
                out["load"].setdefault(tag, {})["smoothed"] = v
        t = self.telemetry
        out["health"] = {
            "breakers": self.health.snapshot(),
            "execute_failures": t.execute_failures,
            "output_guard_failures": t.output_guard_failures,
            "circuit_fast_fails": t.circuit_fast_fails,
            "failovers": t.failovers,
            "retry_failures": t.retry_failures,
        }
        with self._lock:
            out["arenas"] = {"resident": len(self._arenas),
                             "outstanding_leases": self._outstanding,
                             "generation": self._generation}
            out["warm_lane"]["table"] = len(self._warm_table)
        out["tracing"] = self.recorder.snapshot()
        out["events"] = self.events.snapshot()
        # monotonic timestamp: what stats_delta() computes rates over
        out["ts"] = time.monotonic()
        return out

    def traces(self, *, errors: bool = False, n: int | None = None):
        """Recent traces from the flight recorder, oldest-first: the
        head-sampled main ring by default, the always-retained
        degraded/failed-over ring with ``errors=True``.  ``n`` limits to
        the most recent n.  Returns ``repro.serving.trace.Trace`` objects
        (``.to_dict()`` for JSON)."""
        return self.recorder.traces(errors=errors, n=n)

    def generation_log(self) -> list[dict]:
        """Per-generation dispatch->retire wall-clock windows (last 512):
        ``{"generation", "dispatched", "retired", "wait_ms", "drained"}``.
        Consecutive generations' overlapping windows are the async
        run-ahead; ``repro.serving.export.chrome_trace`` renders them."""
        with self._lock:
            return [dict(g) for g in self._gen_log]

    def stats_delta(self) -> dict:
        """Windowed-rate view: counter deltas and rates (req/s, windowed
        hit rate, failovers/s, per-backend shares) since the *previous*
        ``stats_delta()`` call (engine construction counts as the zeroth).
        Lifetime counters answer "how much ever"; this answers "what is
        happening *now*" — what a dashboard poll plots.  See
        ``repro.serving.export.stats_delta`` for the field contract."""
        from repro.serving.export import stats_delta as _delta
        cur = self.stats()
        with self._lock:
            prev, self._delta_prev = self._delta_prev, cur
        if prev is None:
            # zeroth window: every counter was 0 at engine construction
            prev = {"ts": self._ctor_ts, "requests": 0, "batches": 0,
                    "hits": 0, "misses": 0,
                    "health": {"failovers": 0, "execute_failures": 0},
                    "backends": {}}
        return _delta(prev, cur)

    # --------------------------------------------------------- persistence

    def save(self, path: str | Path | None = None) -> Path:
        """Atomically persist every backend's autotune cache (platform-tag
        namespaced digest -> config + plan) to one file."""
        target = Path(path) if path is not None else self.persist_path
        if target is None:
            raise ValueError("no persist_path configured and none given")
        out = save_backends(self.backends, target)
        entries = sum(len(c) for caches in
                      self.backends.caches_by_platform().values()
                      for c in caches)
        self.telemetry.count(persist_saves=1, persist_saved_entries=entries)
        self.events.emit("persist_save", path=str(out), entries=entries)
        return out
