"""``SparseKernelEngine`` — micro-batched serving of tuned sparse kernels.

One ``step(requests)`` call serves a micro-batch of (pattern, values, op)
requests through the COGNATE deployment loop with every stage amortized:

1. **Partition** — each request's pattern is digested once and looked up in
   the pattern-keyed autotune LRU.
2. **Score** — all cache *misses* (per op) are featurized and scored in a
   single ``Autotuner.scores_batch`` dispatch via ``KernelAutotuner.
   get_batch``: one jitted embed+score round-trip for the whole batch instead
   of one per pattern.  Hits skip featurization entirely.
3. **Build** — values scatter through each pattern's cached ``BsrPlan`` into
   a two-slot double-buffered ``PlanArena``: batch N+1's host-side scatter
   lands in the slot batch N is *not* using, and slot-generation checks
   guarantee an alias is never overwritten while its lease is held.  If a
   pattern's arena is exhausted (more outstanding builds than slots), the
   engine falls back to a fresh un-aliased allocation and counts it.
4. **Execute** — requests carrying a dense operand are run through the
   Pallas kernels (``ops.spmm`` / ``ops.sddmm``) with the tuned tile config;
   operand-less requests are "prepare-only" (the caller launches later).

Batch N's leases are released only after batch N+1 is dispatched, so the
engine is safe even when kernel launches are asynchronous.  ``stats()``
renders hit rates, per-stage latency histograms (p50/p99), evictions, and
persistence events from ``repro.serving.telemetry``.

With ``persist_path`` set, the engine warm-starts its cache from disk at
construction (zero featurizations for previously-seen traffic — torn or
missing files fall back to a cold cache) and ``save()`` atomically writes it
back via ``repro.serving.persist``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import (Autotuner, KernelAutotuner, TunedKernel,
                                 matrix_digest)
from repro.data.matrices import SparseMatrix
from repro.kernels import ops
from repro.kernels.format import BsrMatrix
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.persist import load_cache, save_cache
from repro.serving.telemetry import EngineTelemetry

__all__ = ["KernelRequest", "KernelResponse", "SparseKernelEngine"]


@dataclasses.dataclass
class KernelRequest:
    """One unit of serving work: a sparsity pattern with this batch's values.

    ``values`` aligns with ``mat.rows``/``mat.cols`` (defaults to ones —
    pattern-only traffic).  ``operand`` is the dense right-hand side: a (K, N)
    array for ``op="spmm"``, a ``(b, c)`` tuple for ``op="sddmm"``; ``None``
    means prepare-only (tune + build, let the caller launch)."""
    mat: SparseMatrix
    values: np.ndarray | None = None
    op: str = "spmm"
    operand: object = None


@dataclasses.dataclass
class KernelResponse:
    digest: str
    config: dict
    matrix: BsrMatrix
    output: object | None       # kernel result, or None for prepare-only
    cache_hit: bool
    arena_slot: bool            # False -> overflow fallback (fresh buffer)


class SparseKernelEngine:
    """Batched, double-buffered, warm-startable sparse-kernel server."""

    def __init__(self, tuner: KernelAutotuner | Autotuner | None = None,
                 cache_size: int = 128, arena_slots: int = 2,
                 persist_path: str | Path | None = None,
                 autosave_every: int | None = None, interpret: bool = True):
        if isinstance(tuner, KernelAutotuner):
            self.tuner = tuner
        else:       # a learned Autotuner (or None -> structural heuristic)
            self.tuner = KernelAutotuner(tuner, cache_size=cache_size)
        self.arena_slots = arena_slots
        self.interpret = interpret
        self.autosave_every = autosave_every
        self.telemetry = EngineTelemetry()
        self.persist_path = Path(persist_path) if persist_path else None
        self._arenas: OrderedDict = OrderedDict()   # (op, digest) -> PlanArena
        # previous-batch leases are per *thread*: each serving stream double-
        # buffers independently, so one thread's step can never release (and
        # let the arena overwrite) a batch another thread's caller still
        # holds.  Concurrent streams hitting one pattern contend for its
        # slots; losers take the counted un-aliased fallback.
        self._stream = threading.local()
        self._outstanding = 0
        self._lock = threading.Lock()   # guards _arenas and _outstanding
        if self.persist_path is not None:
            loaded = load_cache(self.persist_path)
            if loaded is not None:      # an empty cache file is a valid load
                for key, entry in loaded:
                    self.tuner.cache.put(key, entry)
                self.telemetry.count(warm_start_entries=len(loaded))
            elif self.persist_path.exists():
                self.telemetry.count(persist_load_failures=1)

    # ------------------------------------------------------------- serving

    def step(self, requests: list[KernelRequest]) -> list[KernelResponse]:
        """Serve one micro-batch; returns responses in request order."""
        t_step = time.perf_counter()
        cache = self.tuner.cache

        t0 = time.perf_counter()
        digests = [matrix_digest(r.mat) for r in requests]
        was_hit = [(r.op, d) in cache for r, d in zip(requests, digests)]
        by_op: OrderedDict = OrderedDict()
        for i, r in enumerate(requests):
            by_op.setdefault(r.op, []).append(i)
        self.telemetry.record_stage("partition", time.perf_counter() - t0)

        t0 = time.perf_counter()
        hits0, misses0 = cache.hits, cache.misses
        entries: list[TunedKernel | None] = [None] * len(requests)
        for op, idxs in by_op.items():
            m0 = cache.misses
            got = self.tuner.get_batch([requests[i].mat for i in idxs], op,
                                       digests=[digests[i] for i in idxs])
            for i, e in zip(idxs, got):
                entries[i] = e
            if cache.misses > m0:
                self.telemetry.count(score_dispatches=1)
        self.telemetry.record_stage("score", time.perf_counter() - t0)
        self.telemetry.count(hits=cache.hits - hits0,
                             misses=cache.misses - misses0)

        t0 = time.perf_counter()
        leases: list[ArenaLease] = []
        built: list[tuple[BsrMatrix, bool]] = []
        for r, d, entry in zip(requests, digests, entries):
            values = r.values if r.values is not None \
                else np.ones(r.mat.nnz, np.float32)
            arena = self._arena_for((r.op, d), entry)
            try:
                lease = arena.build(values)
                leases.append(lease)
                built.append((lease.matrix, True))
            except ArenaOverrun:
                self.telemetry.count(arena_fallbacks=1)
                built.append((entry.plan.build(values), False))
        self.telemetry.record_stage("build", time.perf_counter() - t0)

        t0 = time.perf_counter()
        responses = []
        for r, d, entry, (matrix, in_arena), hit in zip(
                requests, digests, entries, built, was_hit):
            output = self._execute(r, entry, matrix)
            responses.append(KernelResponse(d, entry.config, matrix, output,
                                            hit, in_arena))
        self.telemetry.record_stage("execute", time.perf_counter() - t0)

        # this stream's batch N-1 kernels were dispatched a full step ago —
        # its slots can rotate now that batch N is in flight (double-buffer
        # hand-off)
        for lease in self._swap_stream_leases(leases):
            lease.release()

        self.telemetry.count(requests=len(requests), batches=1)
        self.telemetry.record_stage("step", time.perf_counter() - t_step)
        if (self.autosave_every and self.persist_path is not None
                and self.telemetry.batches % self.autosave_every == 0):
            self.save()
        return responses

    def _execute(self, r: KernelRequest, entry: TunedKernel,
                 matrix: BsrMatrix):
        if r.operand is None:
            return None
        cfg = entry.config
        if r.op == "spmm":
            return ops.spmm(matrix, jnp.asarray(r.operand),
                            block_n=cfg["block_n"], n_major=cfg["n_major"],
                            interpret=self.interpret)
        if r.op == "sddmm":
            b, c = r.operand
            return ops.sddmm(matrix, jnp.asarray(b), jnp.asarray(c),
                             interpret=self.interpret)
        raise ValueError(f"unknown op {r.op!r}")

    def _arena_for(self, key, entry: TunedKernel) -> PlanArena:
        with self._lock:
            arena = self._arenas.get(key)
            if arena is None or arena.plan is not entry.plan:
                arena = PlanArena(entry.plan, n_slots=self.arena_slots)
                self._arenas[key] = arena
            self._arenas.move_to_end(key)
            while len(self._arenas) > max(self.tuner.cache.maxsize, 1):
                self._arenas.popitem(last=False)
            return arena

    def _swap_stream_leases(self, leases: list[ArenaLease]) -> list[ArenaLease]:
        """Install this thread's new outstanding batch; return the old one."""
        prev = getattr(self._stream, "leases", [])
        self._stream.leases = leases
        with self._lock:
            self._outstanding += len(leases) - len(prev)
        return prev

    def flush(self) -> None:
        """Release the calling thread's outstanding arena leases (call once
        this stream's last results have been consumed or copied)."""
        for lease in self._swap_stream_leases([]):
            lease.release()

    # ------------------------------------------------------- observability

    @property
    def featurize_calls(self) -> int:
        return self.tuner.featurize_calls

    def stats(self) -> dict:
        out = self.telemetry.snapshot(cache=self.tuner.cache)
        out["featurize_calls"] = self.tuner.featurize_calls
        with self._lock:
            out["arenas"] = {"resident": len(self._arenas),
                             "outstanding_leases": self._outstanding}
        return out

    # --------------------------------------------------------- persistence

    def save(self, path: str | Path | None = None) -> Path:
        """Atomically persist the autotune cache (digest -> config + plan)."""
        target = Path(path) if path is not None else self.persist_path
        if target is None:
            raise ValueError("no persist_path configured and none given")
        out = save_cache(self.tuner.cache, target)
        self.telemetry.count(persist_saves=1)
        return out
