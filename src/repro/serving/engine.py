"""``SparseKernelEngine`` — micro-batched serving of tuned sparse kernels
across multiple hardware backends.

One ``step(requests)`` call serves a micro-batch of (pattern, values, op
[, platform]) requests through the COGNATE deployment loop with every stage
amortized:

1. **Partition** — each request's pattern is digested once, its
   ``(platform, op)`` tag resolved against the ``BackendRegistry`` (requests
   without a tag go to the registry's default platform), and the batch is
   split into one partition per backend.
2. **Score** — within *each* backend, all cache misses are featurized and
   scored in a single ``Autotuner.scores_batch`` dispatch via that backend's
   ``KernelAutotuner.get_batch``: one jitted embed+score round-trip per
   backend per step instead of one per pattern.  Hits skip featurization
   entirely.  Backends never share cache entries — the same pattern tuned
   for ``tpu_pallas`` and ``cpu_ref`` yields two independent entries.
3. **Build** — values scatter through each pattern's cached ``BsrPlan`` into
   a two-slot double-buffered ``PlanArena`` (keyed per backend tag): batch
   N+1's host-side scatter lands in the slot batch N is *not* using, and
   slot-generation checks guarantee an alias is never overwritten while its
   lease is held.  If a pattern's arena is exhausted (more outstanding
   builds than slots), the engine falls back to a fresh un-aliased
   allocation and counts it.
4. **Execute** — requests carrying a dense operand run through their
   backend's executor (compiled Pallas, Pallas interpreter, or the pure-jnp
   reference) with the tuned tile config; operand-less requests are
   "prepare-only" (the caller launches later).

Batch N's leases are released only after batch N+1 is dispatched, so the
engine is safe even when kernel launches are asynchronous.  ``stats()``
renders global hit rates, per-stage latency histograms (p50/p99), evictions,
persistence events, and a per-backend section (requests, hit rate, serve
p50/p99 for every ``platform/op`` tag that saw traffic).

With ``persist_path`` set, the engine warm-starts every backend's cache from
one namespaced file at construction (zero featurizations for
previously-seen traffic; legacy single-backend files restore the default
platform; entries whose tag no registered backend claims are skipped and
counted — torn or missing files fall back to a cold cache) and ``save()``
atomically writes all backends back via ``repro.serving.persist``.

Thread-safety: ``step`` may be called from several threads; the caches,
arenas, and telemetry are lock-guarded, and double-buffer leases are
tracked per calling thread.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from pathlib import Path

import numpy as np

from repro.core.autotune import (Autotuner, KernelAutotuner, TunedKernel,
                                 matrix_digest)
from repro.data.matrices import SparseMatrix
from repro.kernels.format import BsrMatrix
from repro.serving.arena import ArenaLease, ArenaOverrun, PlanArena
from repro.serving.backends import BackendRegistry, default_registry
from repro.serving.persist import (LEGACY_NAMESPACE, load_grouped,
                                   save_backends)
from repro.serving.telemetry import EngineTelemetry

__all__ = ["KernelRequest", "KernelResponse", "SparseKernelEngine"]


@dataclasses.dataclass
class KernelRequest:
    """One unit of serving work: a sparsity pattern with this batch's values.

    ``values`` aligns with ``mat.rows``/``mat.cols`` (defaults to ones —
    pattern-only traffic).  ``operand`` is the dense right-hand side: a (K, N)
    array for ``op="spmm"``, a ``(b, c)`` tuple for ``op="sddmm"``; ``None``
    means prepare-only (tune + build, let the caller launch).  ``platform``
    routes the request to that backend tag in the engine's registry
    (``None`` -> the registry's default platform)."""
    mat: SparseMatrix
    values: np.ndarray | None = None
    op: str = "spmm"
    operand: object = None
    platform: str | None = None


@dataclasses.dataclass
class KernelResponse:
    """Per-request result: the tuned config, built BSR matrix, kernel output
    (``None`` for prepare-only), and routing/caching provenance."""
    digest: str
    config: dict
    matrix: BsrMatrix
    output: object | None       # kernel result, or None for prepare-only
    cache_hit: bool
    arena_slot: bool            # False -> overflow fallback (fresh buffer)
    platform: str = ""          # backend tag the request was served by


class SparseKernelEngine:
    """Batched, double-buffered, warm-startable, multi-backend sparse-kernel
    server.

    Args:
        tuner: a learned ``Autotuner`` or prebuilt ``KernelAutotuner`` for
            the default platform (``None`` -> structural heuristic).  Only
            consulted when ``backends`` is not given.
        cache_size: per-backend autotune LRU capacity (default registry).
        arena_slots: double-buffer depth per cached pattern.
        persist_path: warm-start/save location for the namespaced cache file.
        autosave_every: if set, ``save()`` runs every N batches.
        interpret: selects the default platform of the stock registry —
            ``True`` -> ``tpu_interpret``, ``False`` -> ``tpu_pallas``
            (compiled; degrades to interpreter off-TPU).
        backends: an explicit ``BackendRegistry``; overrides ``tuner``/
            ``interpret``.  Register custom platforms here.

    Thread-safety: all public methods are safe under concurrent callers;
    see the module docstring for the per-thread lease protocol.
    """

    def __init__(self, tuner: KernelAutotuner | Autotuner | None = None,
                 cache_size: int = 128, arena_slots: int = 2,
                 persist_path: str | Path | None = None,
                 autosave_every: int | None = None, interpret: bool = True,
                 backends: BackendRegistry | None = None):
        if backends is None:
            backends = default_registry(
                tuner, cache_size=cache_size,
                default_platform="tpu_interpret" if interpret
                else "tpu_pallas")
        elif tuner is not None:
            raise ValueError("pass either a tuner or a backend registry, "
                             "not both")
        self.backends = backends
        self.default_platform = backends.default_platform
        # compat: the default platform's tuner (spmm if registered), what
        # single-backend callers passed in and still introspect
        # (featurize_calls, cache)
        try:
            self.tuner = backends.get(self.default_platform, "spmm").tuner
        except KeyError:
            default_bes = [be for be in backends
                           if be.platform == backends.default_platform]
            all_bes = default_bes or list(backends)
            if not all_bes:
                raise ValueError("backend registry has no backends")
            self.tuner = all_bes[0].tuner
        self.arena_slots = arena_slots
        self.autosave_every = autosave_every
        self.telemetry = EngineTelemetry()
        self.persist_path = Path(persist_path) if persist_path else None
        self._arenas: OrderedDict = OrderedDict()  # (plat, op, digest) -> arena
        # arenas are keyed across ALL backends, so the LRU bound is the sum
        # of the per-backend cache capacities — a max() here would thrash
        # arenas as soon as the combined working set outgrew one backend's
        self._arena_cap = sum(kt.cache.maxsize for kt in backends.tuners())
        # previous-batch leases are per *thread*: each serving stream double-
        # buffers independently, so one thread's step can never release (and
        # let the arena overwrite) a batch another thread's caller still
        # holds.  Concurrent streams hitting one pattern contend for its
        # slots; losers take the counted un-aliased fallback.
        self._stream = threading.local()
        self._outstanding = 0
        self._lock = threading.Lock()   # guards _arenas and _outstanding
        if self.persist_path is not None:
            self._warm_start()

    def _warm_start(self) -> None:
        """Route every persisted namespace to its registered backend."""
        loaded = load_grouped(self.persist_path)
        if loaded is None:
            if self.persist_path.exists():
                self.telemetry.count(persist_load_failures=1)
            return
        restored = 0
        skipped = loaded.skipped
        for tag, items in loaded.entries.items():
            platform = self.default_platform if tag is LEGACY_NAMESPACE \
                else tag
            for (op, digest), entry in items:
                if (platform, op) in self.backends:
                    be = self.backends.get(platform, op)
                    be.tuner.cache.put((op, digest), entry)
                    restored += 1
                else:                   # orphaned tag: serve it cold instead
                    skipped += 1
        self.telemetry.count(warm_start_entries=restored,
                             warm_start_skipped=skipped)

    # ------------------------------------------------------------- serving

    def step(self, requests: list[KernelRequest]) -> list[KernelResponse]:
        """Serve one micro-batch; returns responses in request order.

        Raises ``KeyError`` (before any work is done) if a request names a
        ``(platform, op)`` tag with no registered backend."""
        t_step = time.perf_counter()

        t0 = time.perf_counter()
        digests = [matrix_digest(r.mat) for r in requests]
        groups: OrderedDict = OrderedDict()     # (platform, op) -> [indices]
        for i, r in enumerate(requests):
            platform = r.platform or self.default_platform
            groups.setdefault((platform, r.op), []).append(i)
        resolved = {tag: self.backends.get(*tag) for tag in groups}
        hit_of = {}                     # request index -> was it a cache hit
        for tag, idxs in groups.items():
            cache = resolved[tag].tuner.cache
            for i in idxs:
                hit_of[i] = (requests[i].op, digests[i]) in cache
        self.telemetry.record_stage("partition", time.perf_counter() - t0)

        entries: list[TunedKernel | None] = [None] * len(requests)
        built: list[tuple[BsrMatrix, bool] | None] = [None] * len(requests)
        outputs: list[object | None] = [None] * len(requests)
        leases: list[ArenaLease] = []
        score_s = build_s = exec_s = 0.0
        total_hits = total_misses = 0
        for tag, idxs in groups.items():
            be = resolved[tag]
            t0 = time.perf_counter()
            got = be.tuner.get_batch([requests[i].mat for i in idxs],
                                     tag[1],
                                     digests=[digests[i] for i in idxs])
            for i, e in zip(idxs, got):
                entries[i] = e
            dt = time.perf_counter() - t0
            score_s += dt
            serve_s = dt
            # step-local accounting from the partition-stage peek (the
            # shared cache counters also move, but deltas on those would
            # cross-contaminate between concurrent steps)
            d_hits = sum(hit_of[i] for i in idxs)
            d_misses = len(idxs) - d_hits
            total_hits += d_hits
            total_misses += d_misses
            if d_misses:
                self.telemetry.count(score_dispatches=1)

            t0 = time.perf_counter()
            for i in idxs:
                r, entry = requests[i], entries[i]
                values = r.values if r.values is not None \
                    else np.ones(r.mat.nnz, np.float32)
                arena = self._arena_for(tag + (digests[i],), entry)
                try:
                    lease = arena.build(values)
                    leases.append(lease)
                    built[i] = (lease.matrix, True)
                except ArenaOverrun:
                    self.telemetry.count(arena_fallbacks=1)
                    built[i] = (entry.plan.build(values), False)
            dt = time.perf_counter() - t0
            build_s += dt
            serve_s += dt

            t0 = time.perf_counter()
            for i in idxs:
                r = requests[i]
                if r.operand is not None:
                    outputs[i] = be.run(entries[i].config, built[i][0],
                                        r.operand)
            dt = time.perf_counter() - t0
            exec_s += dt
            serve_s += dt
            self.telemetry.record_backend(
                "/".join(tag), requests=len(idxs), hits=d_hits,
                misses=d_misses, seconds=serve_s)

        self.telemetry.record_stage("score", score_s)
        self.telemetry.record_stage("build", build_s)
        self.telemetry.record_stage("execute", exec_s)
        self.telemetry.count(hits=total_hits, misses=total_misses)

        responses = [
            KernelResponse(d, entry.config, matrix, output, hit_of[i],
                           in_arena, r.platform or self.default_platform)
            for i, (r, d, entry, (matrix, in_arena), output) in enumerate(
                zip(requests, digests, entries, built, outputs))]

        # this stream's batch N-1 kernels were dispatched a full step ago —
        # its slots can rotate now that batch N is in flight (double-buffer
        # hand-off)
        for lease in self._swap_stream_leases(leases):
            lease.release()

        self.telemetry.count(requests=len(requests), batches=1)
        self.telemetry.record_stage("step", time.perf_counter() - t_step)
        if (self.autosave_every and self.persist_path is not None
                and self.telemetry.batches % self.autosave_every == 0):
            self.save()
        return responses

    def _arena_for(self, key, entry: TunedKernel) -> PlanArena:
        with self._lock:
            arena = self._arenas.get(key)
            if arena is None or arena.plan is not entry.plan:
                arena = PlanArena(entry.plan, n_slots=self.arena_slots)
                self._arenas[key] = arena
            self._arenas.move_to_end(key)
            while len(self._arenas) > max(self._arena_cap, 1):
                self._arenas.popitem(last=False)
            return arena

    def _swap_stream_leases(self, leases: list[ArenaLease]) -> list[ArenaLease]:
        """Install this thread's new outstanding batch; return the old one."""
        prev = getattr(self._stream, "leases", [])
        self._stream.leases = leases
        with self._lock:
            self._outstanding += len(leases) - len(prev)
        return prev

    def flush(self) -> None:
        """Release the calling thread's outstanding arena leases (call once
        this stream's last results have been consumed or copied)."""
        for lease in self._swap_stream_leases([]):
            lease.release()

    # ------------------------------------------------------- observability

    @property
    def featurize_calls(self) -> int:
        """Total featurize+score computations across every backend's tuner
        (shared tuners counted once) — zero on fully warm-started traffic."""
        return sum(kt.featurize_calls for kt in self.backends.tuners())

    def stats(self) -> dict:
        """Snapshot of all counters: global hit rates, per-stage latency
        histograms, a ``"backends"`` section keyed ``"platform/op"`` with
        per-backend requests / hit rate / serve p50-p99, cache and arena
        occupancy, and persistence events.  ``"cache"`` is the *default*
        backend's cache (pre-registry compat); ``"caches"`` reports every
        platform's occupancy and eviction counters.  Safe to call
        concurrently with ``step``."""
        out = self.telemetry.snapshot(cache=self.tuner.cache)
        out["featurize_calls"] = self.featurize_calls
        out["caches"] = {}
        for plat, caches in self.backends.caches_by_platform().items():
            for j, c in enumerate(caches):
                key = plat if len(caches) == 1 else f"{plat}[{j}]"
                out["caches"][key] = {
                    "size": len(c), "maxsize": c.maxsize, "hits": c.hits,
                    "misses": c.misses, "evictions": c.evictions}
        with self._lock:
            out["arenas"] = {"resident": len(self._arenas),
                             "outstanding_leases": self._outstanding}
        return out

    # --------------------------------------------------------- persistence

    def save(self, path: str | Path | None = None) -> Path:
        """Atomically persist every backend's autotune cache (platform-tag
        namespaced digest -> config + plan) to one file."""
        target = Path(path) if path is not None else self.persist_path
        if target is None:
            raise ValueError("no persist_path configured and none given")
        out = save_backends(self.backends, target)
        self.telemetry.count(persist_saves=1)
        return out
