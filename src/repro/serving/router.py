"""Routing policies — the layer that *chooses* which backend serves a request.

Until now the backend a request ran on was a static tag the caller supplied
(``KernelRequest.platform``); the ``BackendRegistry`` was a lookup table, not
a scheduler.  This module turns it into one.  The engine's first pipeline
stage hands every micro-batch to a ``Router``, which returns one
``RouteDecision`` per request; everything downstream (partition, score,
build, execute) consumes decisions instead of raw tags.

Three policies ship:

``StaticRouter``
    The default, preserving the pre-router behavior bit-for-bit: an explicit
    ``platform`` tag is honored verbatim, an untagged request goes to the
    registry's default platform.  Zero scoring, zero state.

``CostModelRouter``
    COGNATE's cost model as a *placement* policy, the way TLP and the TPU
    learned performance model drive schedule/placement decisions.  Each
    untagged request's pattern is scored against **every** candidate
    backend's config space in ONE batched dispatch
    (``Autotuner.scores_multi`` — one featurization feeds all spaces), and
    the request routes to the argmin *effective* cost

        effective(b) = min_config score_b + calibration_offset(b)

    where the offset is learned online from observed serve latencies
    (``repro.serving.telemetry.RouteCalibration``): the unitless rank score
    is corrected onto each backend's real latency scale, so routing tracks
    what the hardware actually does while the model breaks ties
    per-pattern.  Knob-free backends (no config space, e.g. ``cpu_ref``)
    score 0 and compete purely on their calibrated latency.  Decisions are
    memoized per pattern digest (sticky routing — a repeated pattern costs
    no re-scoring), and the winning config from the routing dispatch is
    attached to the decision so the engine installs it directly instead of
    scoring the miss a second time.

``LoadAwareRouter``
    Wraps any other router and overrides its decision when the chosen
    backend is saturated: if the backend's in-flight depth
    (``KernelBackend.load`` — outstanding leases plus requests already
    assigned earlier in this batch) has reached ``max_inflight`` for
    ``spill_after`` consecutive decisions (hysteresis — one transient
    burst doesn't flap traffic), the request spills to ``spill_to``
    (default ``cpu_ref``).  Spills and hysteresis suppressions are counted
    (``stats()["routing"]["spills"]`` / ``["spill_hysteresis"]``) and
    spilled latencies feed the spill target's calibration, so a cost-model
    inner router learns what the fallback actually costs.

Routers are pure policy objects: all engine state they need arrives in the
per-step ``RoutingContext`` (registry, calibration, default platform,
backend health), so a policy can be unit-tested with a hand-built context
and swapped per engine via ``SparseKernelEngine(router=...)``.  A custom
policy is any object with this protocol's ``route`` method.

Routing is **health-aware** (``repro.serving.health``): ``candidates()``
filters backends whose circuit breaker is open (unless every candidate
is), ``CostModelRouter`` sticky memos carry the health generation they
were decided under and invalidate on any breaker transition, and
``LoadAwareRouter`` treats an open circuit as instant saturation.  The
engine's route-stage health gate is the second line of defense — it
rewrites any surviving open-circuit decision to the failover target.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.autotune import KernelAutotuner
from repro.serving.backends import BackendRegistry, KernelBackend
from repro.serving.telemetry import RouteCalibration

__all__ = ["RouteDecision", "RoutingContext", "Router", "StaticRouter",
           "CostModelRouter", "LoadAwareRouter"]


@dataclasses.dataclass
class RouteDecision:
    """Where one request goes, and why.

    ``reason`` is a short tag rendered into routing telemetry:
    ``explicit`` (caller pinned the platform), ``default`` (untagged,
    static policy), ``cost_model`` (argmin predicted cost), ``sticky``
    (memoized earlier cost-model pick), ``explore`` (calibration probe),
    ``spill`` (load shed).  ``predicted`` is the raw (uncalibrated) cost
    score of the chosen backend (cost-model routes only) — the account
    stage feeds it, with the observed latency, into ``RouteCalibration``,
    whose offsets are defined against the raw score.
    ``config`` is an optional tuned-kernel kwargs hint recovered from the
    routing dispatch; the engine installs it on a cache miss instead of
    re-scoring the pattern."""
    platform: str
    reason: str = "explicit"
    predicted: float | None = None
    config: dict | None = None


@dataclasses.dataclass
class RoutingContext:
    """Engine state a router may consult, rebuilt per ``step``.

    ``health`` is the engine's ``HealthRegistry`` (``None`` in hand-built
    test contexts): routers use it to keep open-circuit backends out of
    candidate sets and memos.  ``events`` is the engine's ``EventLog``
    (``None`` in hand-built contexts): policies emit structured routing
    events through it (``router_spill``, ``sticky_invalidation``)."""
    registry: BackendRegistry
    calibration: RouteCalibration
    default_platform: str
    health: object | None = None        # repro.serving.health.HealthRegistry
    events: object | None = None        # repro.serving.trace.EventLog

    def emit(self, kind: str, **fields) -> None:
        """Emit a structured routing event if the engine wired a log
        (no-op in hand-built contexts; never raises into routing)."""
        if self.events is not None:
            try:
                self.events.emit(kind, **fields)
            except Exception:
                pass

    def candidates(self, op: str) -> list[KernelBackend]:
        """Backends that can serve ``op``, default platform first (ties in
        scoring resolve toward it), then alphabetically — deterministic
        whatever order the registry was populated in.  Backends whose
        circuit breaker is open (and not yet due a recovery probe) are
        filtered out — unless that would empty the list, in which case the
        full set is returned (routing *somewhere* beats refusing)."""
        bes = [be for be in self.registry if be.op == op]
        if self.health is not None:
            alive = [be for be in bes
                     if self.health.routable((be.platform, op))]
            if alive:
                bes = alive
        bes.sort(key=lambda be: (be.platform != self.default_platform,
                                 be.platform))
        return bes


@runtime_checkable
class Router(Protocol):
    """Routing policy protocol: one decision per request, in order.

    ``digests`` aligns with ``requests`` (the engine computes each
    pattern's digest exactly once per step and shares it with the policy so
    memoizing routers don't re-hash).  Implementations must be safe under
    concurrent ``step`` callers."""

    def route(self, requests: list, digests: list[str],
              ctx: RoutingContext) -> list[RouteDecision]: ...


class StaticRouter:
    """Honor explicit tags; send untagged traffic to the default platform.

    This is the engine's default policy and reproduces the pre-router
    engine exactly: no scoring, no state, no spilling."""

    def route(self, requests, digests, ctx: RoutingContext) \
            -> list[RouteDecision]:
        return [RouteDecision(r.platform, "explicit") if r.platform
                else RouteDecision(ctx.default_platform, "default")
                for r in requests]


class CostModelRouter:
    """Route untagged requests to the backend the cost model predicts
    fastest, calibrated online against observed serve latencies.

    Args:
        priors: platform -> cold-start effective-cost offset used until the
            platform has observed latencies (then ``RouteCalibration``
            takes over).  Unlisted platforms default to ``default_prior``
            (scorable candidates) or ``unscored_prior`` (knob-free ones).
            Use a large prior to keep a backend out of rotation until it
            has been measured, a negative one to favor it cold.
        default_prior: fallback cold-start offset for candidates the cost
            model can score (0.0 — they compete on raw predicted score
            until calibrated).
        unscored_prior: fallback cold-start offset for candidates the cost
            model *cannot* score (no config space, e.g. ``cpu_ref``).
            Default ``inf``: with neither a model nor a measurement there
            is zero evidence for such a backend, so it joins the rotation
            only once observed — through a spill, an ``explore`` probe, or
            explicitly pinned traffic.
        explore_every: if set, every Nth cost-model decision is instead
            routed to the candidate with the fewest calibration
            observations (reason ``explore``) so offsets stay fresh for
            backends the argmin would otherwise starve.
        memo_size: LRU capacity of the digest -> platform sticky map.

    Explicitly tagged requests pass through untouched (reason
    ``explicit``), so one engine can mix pinned and routed traffic.
    """

    def __init__(self, priors: dict[str, float] | None = None,
                 default_prior: float = 0.0,
                 unscored_prior: float = float("inf"),
                 explore_every: int | None = None, memo_size: int = 1024):
        self.priors = dict(priors or {})
        self.default_prior = float(default_prior)
        self.unscored_prior = float(unscored_prior)
        self.explore_every = explore_every
        # digest -> (platform, health generation at decision time): a memo
        # is only as durable as the health snapshot it was made under
        self._memo: OrderedDict = OrderedDict()
        self._memo_size = memo_size
        self._lock = threading.Lock()
        self._decide_count = 0
        #: multi-space scoring round-trips issued — the acceptance counter:
        #: one step with any number of untagged misses bumps this by at
        #: most one per distinct op in the batch (usually exactly one)
        self.dispatches = 0
        #: patterns actually scored (cache-missed the sticky memo)
        self.scored_patterns = 0
        #: sticky memos dropped because the memoized platform's health
        #: changed state (in either direction) since the decision
        self.sticky_invalidations = 0

    # ------------------------------------------------------------- helpers

    def _effective_offset(self, platform: str, ctx: RoutingContext,
                          scored: bool, op: str | None = None) -> float:
        # per-(platform, op) calibration when that pair has been served;
        # RouteCalibration itself falls back to the platform aggregate
        off = ctx.calibration.offset(platform, op)
        if off is not None:
            return off
        if platform in self.priors:
            return self.priors[platform]
        return self.default_prior if scored else self.unscored_prior

    @staticmethod
    def _scorer_for(candidates, op: str):
        """The learned Autotuner that featurizes this op's routing batch:
        the default platform's if it has one, else the first candidate's —
        but only a model *trained for this op* (the same guard
        ``KernelAutotuner.get_batch`` applies before trusting a learned
        tuner).  Returns ``None`` when no candidate has one (routing then
        falls back to calibration offsets alone)."""
        for be in candidates:           # candidates() puts default first
            tuner = be.tuner.tuner
            if tuner is not None and tuner.op == op:
                return tuner
        return None

    def _pick_explore(self, candidates, ctx: RoutingContext) -> str:
        return min(candidates,
                   key=lambda be: (ctx.calibration.n_observed(be.platform),
                                   be.platform)).platform

    # --------------------------------------------------------------- route

    def route(self, requests, digests, ctx: RoutingContext) \
            -> list[RouteDecision]:
        decisions: list[RouteDecision | None] = [None] * len(requests)
        todo: OrderedDict = OrderedDict()       # op -> [request indices]
        with self._lock:
            for i, r in enumerate(requests):
                if r.platform:
                    decisions[i] = RouteDecision(r.platform, "explicit")
                    continue
                hit = self._memo.get(digests[i])
                if hit is not None:
                    plat, gen = hit
                    if ctx.health is not None \
                            and ctx.health.generation(plat) != gen:
                        # the memoized platform's breaker transitioned
                        # (opened, or recovered) since this pick: drop the
                        # memo and re-decide against current health
                        del self._memo[digests[i]]
                        self.sticky_invalidations += 1
                        ctx.emit("sticky_invalidation", platform=plat,
                                 digest=digests[i])
                    else:
                        self._memo.move_to_end(digests[i])
                        decisions[i] = RouteDecision(plat, "sticky")
                        continue
                self._decide_count += 1
                if self.explore_every \
                        and self._decide_count % self.explore_every == 0:
                    decisions[i] = RouteDecision("", "explore")  # fill below
                todo.setdefault(r.op, []).append(i)

        for op, idxs in todo.items():
            candidates = ctx.candidates(op)
            if not candidates:          # let the engine raise its KeyError
                for i in idxs:
                    if decisions[i] is None or not decisions[i].platform:
                        decisions[i] = RouteDecision(ctx.default_platform,
                                                     "default")
                continue
            for i in idxs:              # explore probes need no scoring
                if decisions[i] is not None and decisions[i].reason \
                        == "explore":
                    decisions[i].platform = self._pick_explore(candidates,
                                                               ctx)
            score_idx = [i for i in idxs if decisions[i] is None]
            if not score_idx:
                continue
            decided = self._decide(
                [requests[i] for i in score_idx], op, candidates, ctx)
            gen_of = {}
            with self._lock:
                for i, d in zip(score_idx, decided):
                    decisions[i] = d
                    if d.platform not in gen_of:
                        gen_of[d.platform] = \
                            ctx.health.generation(d.platform) \
                            if ctx.health is not None else 0
                    self._memo[digests[i]] = (d.platform,
                                              gen_of[d.platform])
                    self._memo.move_to_end(digests[i])
                    while len(self._memo) > self._memo_size:
                        self._memo.popitem(last=False)
        return decisions

    def _decide(self, reqs, op, candidates, ctx: RoutingContext) \
            -> list[RouteDecision]:
        """Score ``reqs`` (all untagged, unmemoized, op ``op``) against
        every candidate and return their decisions."""
        B = len(reqs)
        scorer = self._scorer_for(candidates, op)
        scorable = [(j, be) for j, be in enumerate(candidates)
                    if scorer is not None and be.space is not None]
        base = np.zeros((B, len(candidates)), np.float32)
        argmin_cfg: dict[int, np.ndarray] = {}  # candidate pos -> (B,) idx
        if scorable:
            self.dispatches += 1
            self.scored_patterns += B
            per_space = scorer.scores_multi(
                [r.mat for r in reqs], [be.space for _, be in scorable])
            for (j, be), scores in zip(scorable, per_space):
                base[:, j] = scores.min(axis=1)
                # keep the winning config index: the engine can install it
                # directly when this backend wins, skipping a re-score
                if be.tuner.tuner is scorer and be.space is scorer.space:
                    argmin_cfg[j] = np.asarray(scores.argmin(axis=1))
        scored_pos = {j for j, _ in scorable}
        offs = np.asarray([self._effective_offset(be.platform, ctx,
                                                  j in scored_pos, op)
                           for j, be in enumerate(candidates)], np.float32)
        eff = base + offs[None, :]
        picks = np.argmin(eff, axis=1)
        out = []
        for b, j in enumerate(picks):
            be = candidates[int(j)]
            config = None
            if int(j) in argmin_cfg:
                space = be.space
                ci = int(argmin_cfg[int(j)][b])
                row = {name: space.params[name][ci].item()
                       for name in space.params}
                config = KernelAutotuner._kernel_kwargs(row)
            # calibration must see the RAW model score, not the effective
            # cost: offset = EMA[observed] - EMA[predicted], so feeding an
            # offset-inclusive value back in would double-count the
            # correction and bias cross-backend comparison
            predicted = float(base[b, int(j)])
            out.append(RouteDecision(
                be.platform, "cost_model",
                predicted=predicted if np.isfinite(predicted) else None,
                config=config))
        return out


class LoadAwareRouter:
    """Spill traffic off a saturated backend onto a fallback.

    Wraps another router (default ``StaticRouter``) and overrides its
    decision when the chosen backend is saturated: its in-flight depth —
    outstanding arena leases plus requests already assigned earlier in the
    same batch — has reached ``max_inflight`` for ``spill_after``
    *consecutive* decisions (default 2 — hysteresis, so one transient
    burst doesn't flap traffic to the fallback; a backend saturated for a
    single decision keeps its assignment and the suppression is counted in
    ``spill_hysteresis``).  Spilled requests go to ``spill_to``
    (which must serve the same op; otherwise the original decision stands)
    with reason ``spill``.  The spill target itself is never spilled *from*
    — when the whole system is saturated, shedding to the fallback is still
    the right call.

    An **open circuit is saturation**: when the chosen backend's breaker
    is open (``ctx.health``), the decision spills immediately —
    bypassing both the depth threshold and the hysteresis streak, because
    a dead backend is not a transient burst.

    Args:
        inner: the policy being wrapped (its reasons are preserved for
            requests that don't spill).
        max_inflight: per-backend depth at which spilling starts.
        spill_to: platform absorbing the overflow (default ``cpu_ref``).
        spill_after: consecutive saturated decisions (per backend tag)
            required before the first spill.  ``1`` restores the immediate
            pre-hysteresis behavior.  The streak resets as soon as a
            decision finds the backend below ``max_inflight``.
        depth_alpha: EMA coefficient smoothing the queue-depth signal the
            spill decision reads.  ``1.0`` (default) is the raw
            instantaneous depth — the historical behavior, bit for bit.
            Below 1.0, each decision sees ``(1-a)*ema + a*depth`` (seeded
            from 0), so a single spiky batch doesn't flip the spill
            decision but sustained saturation still does; the smoothed
            value per tag is exposed in ``stats()["load"][tag]
            ["smoothed"]``.
    """

    def __init__(self, inner: Router | None = None, max_inflight: int = 16,
                 spill_to: str = "cpu_ref", spill_after: int = 2,
                 depth_alpha: float = 1.0):
        self.inner = inner if inner is not None else StaticRouter()
        self.max_inflight = int(max_inflight)
        self.spill_to = spill_to
        self.spill_after = max(int(spill_after), 1)
        self.depth_alpha = float(depth_alpha)
        #: lifetime spill count (also in ``stats()["routing"]["spills"]``)
        self.spills = 0
        #: saturated decisions whose spill was suppressed by hysteresis
        #: (also in ``stats()["routing"]["spill_hysteresis"]``)
        self.spill_hysteresis = 0
        self._streak: dict[tuple[str, str], int] = {}
        self._ema: dict[tuple[str, str], float] = {}
        self._lock = threading.Lock()

    @property
    def smoothed_depth(self) -> dict[str, float]:
        """``"platform/op" -> EMA-smoothed queue depth`` (what the spill
        decision actually compared against ``max_inflight``); surfaces in
        the engine's ``stats()["load"]``."""
        with self._lock:
            return {f"{p}/{op}": v for (p, op), v in self._ema.items()}

    def route(self, requests, digests, ctx: RoutingContext) \
            -> list[RouteDecision]:
        decisions = self.inner.route(requests, digests, ctx)
        pending: dict[tuple[str, str], int] = {}
        a = self.depth_alpha
        with self._lock:
            for i, (r, d) in enumerate(zip(requests, decisions)):
                tag = (d.platform, r.op)
                if d.platform != self.spill_to and tag in ctx.registry:
                    raw = ctx.registry.get(*tag).load.inflight \
                        + pending.get(tag, 0)
                    depth = raw if a >= 1.0 \
                        else (1 - a) * self._ema.get(tag, 0.0) + a * raw
                    self._ema[tag] = depth
                    circuit_open = (ctx.health is not None
                                    and not ctx.health.routable(tag))
                    if (circuit_open or depth >= self.max_inflight) \
                            and (self.spill_to, r.op) in ctx.registry:
                        streak = self._streak.get(tag, 0) + 1
                        self._streak[tag] = streak
                        if circuit_open or streak >= self.spill_after:
                            d = decisions[i] = RouteDecision(self.spill_to,
                                                             "spill")
                            self.spills += 1
                            ctx.emit("router_spill",
                                     platform=tag[0], op=tag[1],
                                     to=self.spill_to, depth=float(depth),
                                     circuit_open=circuit_open)
                            tag = (self.spill_to, r.op)
                        else:       # transient burst: hold the assignment
                            self.spill_hysteresis += 1
                    else:
                        self._streak[tag] = 0
                pending[tag] = pending.get(tag, 0) + 1
        return decisions
