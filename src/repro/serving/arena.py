"""Double-buffered plan arena — alias-safe reuse of BSR block storage.

``BsrPlan.build(reuse=True)`` hands out an alias of a single plan-owned
buffer, so a serving loop that builds batch N+1 while batch N's kernel is
still consuming its input would overwrite in-flight data.  ``PlanArena``
generalizes that single buffer to an n-slot (default two-slot) rotation:

* ``build(values)`` scatters into the next *free* slot and returns an
  ``ArenaLease`` — the built ``BsrMatrix`` plus a generation token.
* A slot stays untouchable while its lease is held; ``release()`` returns it
  to the rotation.  With two slots, batch N+1's host-side scatter lands in
  slot B while batch N's kernel still reads slot A — the classic double
  buffer.
* Every checkout bumps the slot's generation.  A lease whose slot has been
  rehanded is ``.valid == False``, and the arena *never* rehands a slot whose
  lease is still held — asking for more concurrent buffers than there are
  slots raises ``ArenaOverrun`` (callers fall back to a fresh, un-aliased
  allocation; ``repro.serving.engine`` counts those).

Each slot carries **two** buffers, allocated lazily per path: a host numpy
buffer for ``build`` (the cold/reference scatter) and a device-resident
block buffer for ``build_device`` (the jitted scatter — after the slot's
first device build, rebuilds *donate* the previous buffer to the jitted
update, so the steady state allocates nothing and never touches host
memory).  Donation means a released device lease's matrix is physically
invalidated when its slot is reused — JAX raises on any further access, so
a protocol violation is loud, never silent corruption; host-path matrices,
by contrast, survive slot reuse because ``wrap`` copies to device.

The arena is per-plan (buffer shape is a function of the plan's nnzb and
block size); ``repro.serving.engine`` keeps one per cached pattern.
"""
from __future__ import annotations

import dataclasses
import threading

import jax.numpy as jnp
import numpy as np

from repro.kernels.format import BsrMatrix, BsrPlan

__all__ = ["PlanArena", "ArenaLease", "ArenaOverrun"]


class ArenaOverrun(RuntimeError):
    """All slots are leased — granting another build would overwrite a
    buffer that may still be referenced by an in-flight kernel."""


@dataclasses.dataclass
class _Slot:
    buf: np.ndarray | None = None   # host scatter buffer (lazy)
    dev: object = None              # device-resident block data (lazy)
    fused: np.ndarray | None = None     # 64B-aligned buffer (fused path)
    fused_mat: BsrMatrix | None = None  # cached zero-copy wrap of `fused`
    fused_alias: bool = False       # wrap verified to alias `fused`
    generation: int = 0
    leased: bool = False


@dataclasses.dataclass
class ArenaLease:
    """A built ``BsrMatrix`` plus the right to keep reading it.

    The matrix aliases arena slot storage.  It is guaranteed intact until
    ``release()``; afterwards ``valid`` reports whether the slot has been
    rehanded to a newer build (stale aliases can be detected, not just
    corrupted)."""
    matrix: BsrMatrix
    _arena: "PlanArena"
    _slot_index: int
    generation: int

    @property
    def valid(self) -> bool:
        return self._arena._slots[self._slot_index].generation == self.generation

    def release(self) -> None:
        self._arena._release(self._slot_index, self.generation)


class PlanArena:
    """n-slot rotation of scatter buffers for one ``BsrPlan``."""

    def __init__(self, plan: BsrPlan, n_slots: int = 2,
                 buf_dtype=np.float32):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.plan = plan
        self.buf_dtype = buf_dtype
        self._slots = [_Slot() for _ in range(n_slots)]
        self._next = 0
        self._lock = threading.Lock()
        self.builds = 0
        self.device_builds = 0
        self.fused_builds = 0
        self.overruns = 0

    @property
    def n_slots(self) -> int:
        return len(self._slots)

    def free_slots(self) -> int:
        with self._lock:
            return sum(not s.leased for s in self._slots)

    def _checkout(self) -> tuple[int, _Slot]:
        """Round-robin from the slot after the last one handed out, so the
        most recently built (likely still in-flight) buffer is tried last."""
        with self._lock:
            n = len(self._slots)
            for k in range(n):
                i = (self._next + k) % n
                slot = self._slots[i]
                if not slot.leased:
                    slot.leased = True
                    slot.generation += 1
                    self._next = (i + 1) % n
                    return i, slot
            self.overruns += 1
        raise ArenaOverrun(
            f"all {n} arena slots are leased; release a batch before "
            f"building another, or fall back to an un-aliased build")

    def _release(self, index: int, generation: int) -> None:
        with self._lock:
            slot = self._slots[index]
            if slot.generation == generation:
                slot.leased = False

    def build(self, values, dtype=jnp.float32) -> ArenaLease:
        """Host-scatter ``values`` through the plan into the next free
        slot's host buffer (allocated zeroed on the slot's first host
        build — every build writes the same positions, so it never needs
        re-zeroing)."""
        i, slot = self._checkout()
        try:
            if slot.buf is None or slot.buf.dtype != np.dtype(self.buf_dtype):
                slot.buf = self.plan.alloc_buffer(self.buf_dtype)
            self.plan.scatter_into(values, slot.buf)
        except BaseException:
            self._release(i, slot.generation)   # never leak a slot
            raise
        with self._lock:
            self.builds += 1
        return ArenaLease(self.plan.wrap(slot.buf, dtype), self, i,
                          slot.generation)

    def _ensure_fused(self, slot: _Slot, dtype) -> None:
        """Lazily stand up a slot's fused buffer: a 64-byte-aligned host
        buffer plus ONE cached ``wrap`` of it, with the aliasing verified
        by a sentinel write (write through numpy, read back through the
        jax array).  When the runtime does not zero-copy (non-CPU backend,
        dtype conversion), ``fused_alias`` stays False and ``build_fused``
        degrades to a per-build ``wrap`` — correct, just not zero-copy."""
        dt = np.dtype(dtype)
        if slot.fused is not None and slot.fused.dtype == dt:
            return
        buf = self.plan.alloc_buffer(dt, align=64)
        mat = self.plan.wrap(buf, dtype)
        alias = False
        if buf.size:
            flat = buf.reshape(-1)
            old = flat[0]
            flat[0] = old + 1.0
            try:
                alias = float(np.asarray(mat.data).reshape(-1)[0]) \
                    == float(flat[0])
            finally:
                flat[0] = old
        slot.fused = buf
        slot.fused_mat = mat
        slot.fused_alias = alias

    def build_fused(self, values, dtype=jnp.float32) -> ArenaLease:
        """The warm-lane host build: scatter ``values`` into the slot's
        aligned fused buffer and return the slot's *cached* zero-copy
        ``BsrMatrix`` — steady state touches only the nnz scatter
        positions and allocates nothing (no 1:1 block-data copy at
        ``wrap`` time, which dominates the classic host build).

        The returned matrix aliases slot storage like a ``reuse=True``
        plan build: it is intact until the lease releases and the slot is
        rehanded, after which its contents are silently rewritten — the
        engine's generation hand-off (leases released only after the
        consuming dispatches complete) is what makes that safe."""
        i, slot = self._checkout()
        try:
            self._ensure_fused(slot, dtype)
            self.plan.scatter_into(values, slot.fused)
            mat = slot.fused_mat if slot.fused_alias \
                else self.plan.wrap(slot.fused, dtype)
        except BaseException:
            self._release(i, slot.generation)   # never leak a slot
            raise
        with self._lock:
            self.builds += 1
            self.fused_builds += 1
        return ArenaLease(mat, self, i, slot.generation)

    def build_device(self, values, dtype=jnp.float32) -> ArenaLease:
        """Device-scatter ``values`` into the next free slot's device
        buffer — one asynchronous jitted dispatch, zero host numpy.

        The slot's first device build allocates on device
        (``BsrPlan.device_data``); every later build *donates* the slot's
        previous buffer to the jitted update (``BsrPlan.device_update``),
        so the steady state is an in-place rewrite.  Donation physically
        invalidates the previous generation's matrix when its slot is
        reused — safe because a slot is only rehanded once its lease was
        released (accessing the stale alias raises instead of reading
        corrupted data)."""
        i, slot = self._checkout()
        try:
            if slot.dev is not None and slot.dev.dtype == np.dtype(dtype):
                slot.dev = self.plan.device_update(slot.dev, values)
            else:
                slot.dev = self.plan.device_data(values, dtype)
        except BaseException:
            self._release(i, slot.generation)   # never leak a slot
            raise
        with self._lock:
            self.builds += 1
            self.device_builds += 1
        return ArenaLease(self.plan.wrap(slot.dev, dtype), self, i,
                          slot.generation)
