"""Per-request tracing — span trees, a flight recorder, and an event log.

``stats()`` tells you *how much*; this module tells you *where*.  Every
``SparseKernelEngine.step`` already times its six pipeline stages (route ->
partition -> score -> build -> execute -> retry) for the stage histograms —
tracing reuses exactly those measurements to build a **span tree** per
request, so the hot path pays for clock reads it was paying anyway.  What
is new per step is one deterministic sampling decision and, *only for
retained requests*, the materialization of ``Span``/``Trace`` objects at
account time (after the batch's kernels are dispatched — never between a
request and its launch).

**Head sampling + tail retention.**  ``FlightRecorder.sample()`` decides
per *step* (a request inherits its batch's decision) using a counter-based
sampler: step ``n`` is sampled iff ``floor((n+1)*rate) > floor(n*rate)``,
so ``rate=0.1`` keeps exactly every 10th step — deterministic, testable,
and free of RNG state.  Independent of that head decision, every request
that finished **degraded** (failed over, retried, or fast-failed off an
open circuit) is *always* materialized and retained in a separate error
ring — the traces you need most are precisely the ones head sampling would
usually throw away.  With ``trace_sample_rate=0`` (the engine default) the
per-step cost is one predicate; error traces are still captured.

**Flight recorder.**  Two bounded, lock-guarded rings: the last N sampled
traces (``capacity``) and the last M error traces (``error_capacity``),
queryable via ``engine.traces()`` / ``engine.traces(errors=True)``.  Rings
overwrite oldest-first (``dropped`` counts evictions); nothing here grows
without bound, so a long-running engine can fly with the recorder on
forever — the black-box model, hence the name.

**Event log.**  ``EventLog`` is a bounded ring of structured events —
breaker transitions, failovers, circuit fast-fails, persistence
quarantines, warm starts, saves, router spills, sticky invalidations,
drains, admission-queue sheds and batch failures, and the replica
supervisor's lifecycle (``replica_quarantined`` / ``replica_probe_failed``
/ ``replica_readmitted`` / ``quarantine_refused``) — each a flat dict
with a wall-clock ``ts``, a monotonic ``seq``, and a ``kind``.
``to_jsonl()`` renders the ring one-JSON-object-per-line for log
shippers; ``repro.serving.export`` consumes the same ring.

See ``docs/serving.md`` ("Observability") for the span model and the
exporters that render these structures (Prometheus text, Chrome trace).
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from collections import deque

__all__ = ["CounterSampler", "Span", "Trace", "FlightRecorder", "EventLog"]


class CounterSampler:
    """Deterministic counter-based sampler: decision ``n`` is True iff
    ``floor((n+1)*rate) > floor(n*rate)``, so with rate ``r`` exactly
    ``ceil(N*r)`` of any N consecutive decisions sample, evenly spaced, no
    RNG state.  This is the head-sampling rule the ``FlightRecorder`` has
    always used, extracted so other amortized bookkeeping (the engine's
    warm-lane telemetry) can share it.

    Thread-safe; ``sample()`` at rate 0 short-circuits before taking the
    lock, so a disabled sampler costs one float compare per decision."""

    def __init__(self, rate: float):
        self.rate = min(max(float(rate), 0.0), 1.0)
        self._lock = threading.Lock()
        self._n = 0             # decisions taken
        self.sampled = 0        # decisions that came up True

    @property
    def decisions(self) -> int:
        with self._lock:
            return self._n

    def sample(self) -> bool:
        """One sampling decision (call once per unit of work)."""
        r = self.rate
        if r <= 0.0:
            return False
        with self._lock:
            n = self._n
            self._n += 1
            take = r >= 1.0 or math.floor((n + 1) * r) > math.floor(n * r)
            if take:
                self.sampled += 1
            return take


@dataclasses.dataclass
class Span:
    """One timed operation inside a trace.

    ``t0`` is seconds relative to the owning trace's ``wall_ts`` (the
    step's start), ``dur`` seconds of duration — both host wall-clock
    windows from ``time.perf_counter`` pairs.  ``attrs`` carries
    span-scoped detail (e.g. the retry span's ``failed_over_from``);
    ``children`` nest (the retry span holds its sub-pipeline's
    partition/score/build/execute spans)."""
    name: str
    t0: float
    dur: float
    attrs: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        d = {"name": self.name, "t0_ms": self.t0 * 1e3,
             "dur_ms": self.dur * 1e3}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def find(self, name: str) -> "Span | None":
        """First span named ``name`` in this subtree (depth-first)."""
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None


@dataclasses.dataclass
class Trace:
    """One request's span tree plus identifying/routing provenance.

    ``trace_id`` matches the id stamped on the request's
    ``KernelResponse``; ``wall_ts`` is the absolute ``time.time()`` of the
    step's start (span ``t0``s are relative to it — what lets traces from
    different generations line up on one Chrome-trace timeline);
    ``status`` is ``"ok"`` or ``"degraded"``."""
    trace_id: str
    wall_ts: float
    status: str
    op: str
    platform: str
    digest: str
    generation: int
    root: Span

    @property
    def degraded(self) -> bool:
        return self.status == "degraded"

    def span_names(self) -> list[str]:
        """Top-level stage names in order (retry children not included)."""
        return [s.name for s in self.root.children]

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "wall_ts": self.wall_ts,
                "status": self.status, "op": self.op,
                "platform": self.platform, "digest": self.digest,
                "generation": self.generation, "root": self.root.to_dict()}


class FlightRecorder:
    """Bounded rings of recent traces + the deterministic head sampler.

    Args:
        sample_rate: fraction of *steps* head-sampled into the main ring
            (0 disables head sampling; degraded traces are retained
            regardless).  Clamped to [0, 1].
        capacity: main ring size (last N sampled traces).
        error_capacity: error ring size (last M degraded/failed-over
            traces — always retained, never subject to sampling).

    Thread-safe: the sampler counter and both rings sit behind one lock;
    ``sample()`` at rate 0 short-circuits before taking it, so the
    default-configured hot path costs a float compare per step.
    """

    def __init__(self, sample_rate: float = 0.0, capacity: int = 256,
                 error_capacity: int = 64):
        self._sampler = CounterSampler(sample_rate)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=max(int(capacity), 1))
        self._errors: deque = deque(maxlen=max(int(error_capacity), 1))
        self.recorded = 0           # traces entered into the main ring
        self.error_recorded = 0     # traces entered into the error ring
        self.dropped = 0            # main-ring evictions (oldest lost)
        self.error_dropped = 0      # error-ring evictions

    @property
    def sample_rate(self) -> float:
        return self._sampler.rate

    @property
    def sampled_steps(self) -> int:
        return self._sampler.sampled

    def sample(self) -> bool:
        """One head-sampling decision (call once per step).  Deterministic:
        with rate r, decision n is True iff ``floor((n+1)r) > floor(nr)``
        — exactly ``ceil(N*r)`` of any N consecutive steps sample, evenly
        spaced, no RNG (``CounterSampler``)."""
        return self._sampler.sample()

    def record(self, trace: Trace, *, sampled: bool = False,
               error: bool = False) -> None:
        """File one materialized trace: head-sampled traces enter the main
        ring, degraded traces the error ring (a sampled degraded trace
        enters both — it is part of the sampled timeline *and* must
        survive the main ring's churn)."""
        with self._lock:
            if sampled:
                if len(self._ring) == self._ring.maxlen:
                    self.dropped += 1
                self._ring.append(trace)
                self.recorded += 1
            if error:
                if len(self._errors) == self._errors.maxlen:
                    self.error_dropped += 1
                self._errors.append(trace)
                self.error_recorded += 1

    def traces(self, *, errors: bool = False, n: int | None = None
               ) -> list[Trace]:
        """Most-recent-last snapshot of a ring (the last ``n`` if given)."""
        with self._lock:
            ring = self._errors if errors else self._ring
            out = list(ring)
        return out[-n:] if n is not None else out

    def snapshot(self) -> dict:
        with self._lock:
            return {"sample_rate": self.sample_rate,
                    "steps": self._sampler.decisions,
                    "sampled_steps": self.sampled_steps,
                    "recorded": self.recorded,
                    "error_recorded": self.error_recorded,
                    "dropped": self.dropped,
                    "error_dropped": self.error_dropped,
                    "buffered": len(self._ring),
                    "error_buffered": len(self._errors)}


class EventLog:
    """Bounded ring of structured engine events, JSONL-renderable.

    Every event is a flat dict ``{"ts": wall seconds, "seq": monotonic
    int, "kind": str, **fields}``.  The ring keeps the last ``capacity``
    events (``emitted`` counts all of them, so consumers can detect loss);
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, capacity: int = 1024, clock=time.time):
        self.clock = clock
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=max(int(capacity), 1))
        self.emitted = 0
        self._by_kind: dict[str, int] = {}

    def emit(self, kind: str, **fields) -> None:
        with self._lock:
            ev = {"ts": self.clock(), "seq": self.emitted, "kind": kind}
            ev.update(fields)
            self._ring.append(ev)
            self.emitted += 1
            self._by_kind[kind] = self._by_kind.get(kind, 0) + 1

    def events(self, *, kind: str | None = None, n: int | None = None
               ) -> list[dict]:
        """Buffered events oldest-first (filtered by ``kind``, last ``n``)."""
        with self._lock:
            out = [dict(e) for e in self._ring]
        if kind is not None:
            out = [e for e in out if e["kind"] == kind]
        return out[-n:] if n is not None else out

    def to_jsonl(self, *, kind: str | None = None) -> str:
        """The buffered ring as one JSON object per line (trailing
        newline when non-empty) — the structured log shippers ingest."""
        evs = self.events(kind=kind)
        return "".join(json.dumps(e, default=str) + "\n" for e in evs)

    def write(self, path) -> None:
        """Write the buffered ring to ``path`` as JSONL."""
        from pathlib import Path
        Path(path).write_text(self.to_jsonl())

    def snapshot(self) -> dict:
        with self._lock:
            return {"emitted": self.emitted, "buffered": len(self._ring),
                    "by_kind": dict(self._by_kind)}
