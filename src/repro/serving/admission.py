"""Admission control: the bounded queue between open-loop traffic and
the serving pipeline.

Everything below this layer is *closed-loop*: callers hand ``step()`` a
pre-formed micro-batch and wait for it.  Production traffic is open-loop
— requests arrive on their own schedule, and when arrivals outrun
service capacity the only choices are unbounded queueing (latency
diverges for everyone) or bounded, *observable* degradation.  This
module implements the second: a thread-safe ``AdmissionQueue`` that the
engine drains itself.

Producers call ``submit(request, deadline_ms, priority)`` and get an
``AdmissionTicket`` (a future) back immediately — ``submit`` never
blocks.  Every ticket resolves to exactly one outcome:

``served``
    The request went through the pipeline; ``ticket.response`` is its
    ``KernelResponse``.
``shed``
    The queue was over its high-watermark and this request lost the
    priority comparison — either at submit (the incoming request was the
    lowest priority present) or later (a higher-priority submit evicted
    it).  Shedding is lowest-priority-first, youngest-first within a
    priority class, so under sustained overload the queue converges to
    FIFO service of the highest classes instead of thrashing everyone.
``deadline_exceeded``
    The request's deadline budget ran out — at submit (zero/negative
    budget), while queued (the batcher sweeps expired tickets before
    every batch, so they never touch the pipeline), or mid-pipeline (the
    engine's stage gates; see ``KernelRequest.deadline_ts``).
``failed``
    The dispatching ``step()`` raised; ``ticket.error`` carries the
    exception and ``ticket.result()`` re-raises it.  The batch's other
    tickets fail with it — nothing is ever silently dropped.

Batches form when the queue holds a full target batch OR when the
oldest admitted request's deadline slack (or plain age) says waiting any
longer would blow the SLO.  The target size is *SLO-aware*: the
per-request service time is estimated from the engine's ``"step"`` stage
histogram (``repro.serving.telemetry``), the current backend in-flight
depth (``BackendLoad``) counts as queue-ahead work, and the batch is
capped at the largest size whose estimated service time still fits the
tightest pending deadline — a loaded engine forms smaller, more urgent
batches instead of optimizing throughput it cannot deliver.

The queue fronts anything with a ``step(requests) -> responses`` method:
a ``SparseKernelEngine`` or a ``ShardedEngine``.  The batcher is ONE
thread, deliberately — the engine's arena lease protocol is per-thread,
so a single batcher owns a single serving stream and the double-buffer
hand-off works exactly as documented.  ``close()`` drains what's queued
(every ticket still resolves), drains the engine stream, and joins the
thread; the queue is a context manager.
"""
from __future__ import annotations

import itertools
import threading
import time

from repro.serving.trace import EventLog

__all__ = ["AdmissionQueue", "AdmissionTicket", "QueueClosed", "ShedError",
           "DeadlineExceededError", "OUTCOMES"]

OUTCOMES = ("served", "shed", "deadline_exceeded", "failed")


class QueueClosed(RuntimeError):
    """``submit`` after ``close()`` — the producer must stop."""


class ShedError(RuntimeError):
    """``ticket.result()`` on a shed request."""


class DeadlineExceededError(RuntimeError):
    """``ticket.result()`` on an expired request."""


class AdmissionTicket:
    """The future a ``submit`` returns.  Resolves exactly once.

    ``wait(timeout)`` blocks for resolution and returns the outcome (one
    of ``OUTCOMES``, or ``None`` on timeout).  ``result(timeout)``
    returns the ``KernelResponse`` for a served request and raises
    ``ShedError`` / ``DeadlineExceededError`` / the dispatch error for
    the other outcomes.  ``outcome`` / ``response`` / ``error`` are
    readable without blocking once ``done()`` is true."""

    __slots__ = ("request", "deadline_ts", "priority", "seq",
                 "submitted_ts", "outcome", "response", "error",
                 "resolved_ts", "_event")

    def __init__(self, request, deadline_ts, priority, seq, now):
        self.request = request
        self.deadline_ts = deadline_ts
        self.priority = priority
        self.seq = seq
        self.submitted_ts = now
        self.outcome: str | None = None
        self.response = None
        self.error: BaseException | None = None
        self.resolved_ts: float | None = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> str | None:
        if not self._event.wait(timeout):
            return None
        return self.outcome

    def result(self, timeout: float | None = None):
        if self.wait(timeout) is None:
            raise TimeoutError("ticket unresolved")
        if self.outcome == "served":
            return self.response
        if self.outcome == "shed":
            raise ShedError("request shed under overload")
        if self.outcome == "deadline_exceeded":
            raise DeadlineExceededError("deadline budget exhausted")
        raise self.error

    def _resolve(self, outcome, now, response=None, error=None) -> None:
        # single-resolution invariant: the queue only calls this while it
        # owns the ticket (pending under the lock, or popped into exactly
        # one batch), so no double-set is possible
        self.outcome = outcome
        self.response = response
        self.error = error
        self.resolved_ts = now
        self._event.set()


class AdmissionQueue:
    """Bounded, deadline- and priority-aware admission in front of an
    engine.

    Args:
        engine: anything with ``step(requests)`` — a
            ``SparseKernelEngine`` or ``ShardedEngine``.  The queue owns
            one serving stream on it (the batcher thread) and calls
            ``engine.drain()`` when closing.
        capacity: maximum pending tickets; ``submit`` beyond it sheds
            (never blocks, never errors).
        high_watermark: depth at which shedding starts (default:
            ``capacity``).  Between the watermark and ``capacity`` only
            submits that win the priority comparison displace pending
            work.
        max_batch: hard cap on batch size (also the "queue is full
            enough, go" trigger).
        min_batch: floor on the SLO-sized target.
        max_wait_ms: oldest-request age that forces a flush even when the
            batch isn't full and no deadline presses.
        default_service_ms: per-request service estimate used until the
            engine's ``"step"`` histogram has samples.
        slo_margin: safety factor on the service estimate when checking
            deadline slack (1.5 = flush when the tightest slack is
            within 1.5x the estimated batch service time).
        clock: monotonic clock (inject a fake for deterministic tests;
            share it with the engine so ``deadline_ts`` agrees).
        event_capacity: structured event ring size (shed / expiry /
            close events — ``queue.events``).
        start: ``False`` skips the batcher thread; tests drive the queue
            synchronously with ``pump()``.

    Priorities are integers, higher = more important (default 0).
    Deadlines are per-request millisecond budgets measured from submit.
    """

    def __init__(self, engine, *, capacity: int = 256,
                 high_watermark: int | None = None, max_batch: int = 16,
                 min_batch: int = 1, max_wait_ms: float = 5.0,
                 default_service_ms: float = 5.0, slo_margin: float = 1.5,
                 clock=time.monotonic, event_capacity: int = 256,
                 start: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if high_watermark is None:
            high_watermark = capacity
        if not 1 <= high_watermark <= capacity:
            raise ValueError("high_watermark must be in [1, capacity]")
        if max_batch < 1 or min_batch < 1 or min_batch > max_batch:
            raise ValueError("need 1 <= min_batch <= max_batch")
        self.engine = engine
        self.capacity = capacity
        self.high_watermark = high_watermark
        self.max_batch = max_batch
        self.min_batch = min_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.default_service_s = default_service_ms / 1e3
        self.slo_margin = slo_margin
        self.clock = clock
        self.events = EventLog(capacity=event_capacity)
        self._cv = threading.Condition()
        self._pending: list[AdmissionTicket] = []
        self._seq = itertools.count()
        self._closed = False
        # counters (guarded by _cv)
        self.submitted = 0
        self.admitted = 0
        self.served = 0
        self.shed = 0
        self.deadline_exceeded = 0      # resolved at submit or while queued
        self.pipeline_expired = 0       # resolved by the engine's stage gates
        self.failed = 0
        self.batches = 0
        self.flushes = {"full": 0, "deadline": 0, "age": 0, "close": 0}
        self.peak_depth = 0
        self._batcher: threading.Thread | None = None
        if start:
            self._batcher = threading.Thread(
                target=self._drain_loop, name="admission-batcher",
                daemon=True)
            self._batcher.start()

    # ------------------------------------------------------------ producers

    def submit(self, request, deadline_ms: float | None = None,
               priority: int = 0) -> AdmissionTicket:
        """Admit (or shed) one request; returns its ticket immediately.

        ``deadline_ms`` is the request's budget from now; zero or
        negative resolves the ticket ``deadline_exceeded`` on the spot —
        it is never enqueued.  ``request.deadline_ts`` is stamped from
        the budget so the engine's stage gates enforce the same clock.
        Over the high-watermark the lowest-priority ticket present
        (incoming included) resolves ``shed``; the producer never
        blocks."""
        now = self.clock()
        deadline_ts = None
        if deadline_ms is not None:
            deadline_ts = now + deadline_ms / 1e3
        request.deadline_ts = deadline_ts
        t = AdmissionTicket(request, deadline_ts, priority,
                            next(self._seq), now)
        if deadline_ts is not None and deadline_ts <= now:
            with self._cv:
                if self._closed:
                    raise QueueClosed("admission queue is closed")
                self.submitted += 1
                self.deadline_exceeded += 1
            t._resolve("deadline_exceeded", now)
            return t
        evicted = None
        with self._cv:
            if self._closed:
                raise QueueClosed("admission queue is closed")
            self.submitted += 1
            if len(self._pending) >= self.high_watermark:
                victim = self._shed_victim(t)
                if victim is t:
                    self.shed += 1
                else:
                    self._pending.remove(victim)
                    self._pending.append(t)
                    self.admitted += 1
                    self.shed += 1
                    evicted = victim
                self.events.emit("shed", priority=victim.priority,
                                 depth=len(self._pending),
                                 evicted=victim is not t)
            else:
                self._pending.append(t)
                self.admitted += 1
                self.peak_depth = max(self.peak_depth, len(self._pending))
                self._cv.notify_all()
                return t
        # resolve outside the lock: ticket waiters may run arbitrary code
        if evicted is not None:
            evicted._resolve("shed", now)
            with self._cv:
                self._cv.notify_all()
            return t
        t._resolve("shed", now)
        return t

    def _shed_victim(self, incoming: AdmissionTicket) -> AdmissionTicket:
        """Lowest priority loses; within a class the youngest (largest
        seq) goes first, so admitted work keeps its FIFO place and the
        incoming request — the youngest of all — sheds itself unless it
        strictly outranks something."""
        victim = min(self._pending, key=lambda p: (p.priority, -p.seq))
        if incoming.priority > victim.priority:
            return victim
        return incoming

    # ------------------------------------------------------------- batcher

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                if not self._pending:
                    if self._closed:
                        break
                    self._cv.wait(0.05)
                    continue
                batch, reason, wait_s = self._next_batch_locked()
                if batch is None:
                    self._cv.wait(wait_s)
                    continue
            self._dispatch(batch, reason)
        # the batcher owns the engine's serving stream: force its last
        # generation to completion and release the leases before exiting
        try:
            self.engine.drain()
        except Exception:
            pass

    def pump(self, force: bool = False) -> int:
        """Synchronously form and dispatch at most one batch (test /
        ``start=False`` driver).  ``force=True`` flushes whatever is
        pending without waiting for a trigger.  Returns the number of
        tickets dispatched or expired."""
        with self._cv:
            before = len(self._pending)
            batch, reason, _ = self._next_batch_locked(force=force)
            expired = before - len(self._pending) - (len(batch or ()))
        if batch is None:
            return max(expired, 0)
        self._dispatch(batch, reason)
        return len(batch) + max(expired, 0)

    def _next_batch_locked(self, force: bool = False):
        """Decide, under the lock, whether to batch now.

        Returns ``(batch, flush_reason, _)`` when a trigger fired, or
        ``(None, None, wait_s)`` with the next wake-up delay.  Expired
        pending tickets are swept first — they complete
        ``deadline_exceeded`` right here, without touching the
        pipeline."""
        now = self.clock()
        alive = []
        for p in self._pending:
            if p.deadline_ts is not None and p.deadline_ts <= now:
                self.deadline_exceeded += 1
                p._resolve("deadline_exceeded", now)
            else:
                alive.append(p)
        self._pending = alive
        if not alive:
            return None, None, 0.05
        target = self._target_batch(now)
        reason = None
        if len(alive) >= target:
            reason = "full"
        else:
            oldest = min(alive, key=lambda p: p.seq)
            age = now - oldest.submitted_ts
            slack = self._tightest_slack(now)
            est = self._service_estimate_s(min(len(alive), target))
            if slack is not None and slack <= est * self.slo_margin:
                reason = "deadline"
            elif age >= self.max_wait_s:
                reason = "age"
            elif force or self._closed:
                reason = "close" if self._closed else "age"
            else:
                wait = self.max_wait_s - age
                if slack is not None:
                    wait = min(wait, max(slack - est * self.slo_margin,
                                         1e-4))
                return None, None, min(max(wait, 1e-4), 0.05)
        batch = sorted(alive, key=lambda p: (-p.priority, p.seq))[:target]
        taken = set(map(id, batch))
        self._pending = [p for p in alive if id(p) not in taken]
        self.batches += 1
        self.flushes[reason] += 1
        return batch, reason, 0.0

    def _tightest_slack(self, now: float) -> float | None:
        slacks = [p.deadline_ts - now for p in self._pending
                  if p.deadline_ts is not None]
        return min(slacks) if slacks else None

    def _engines(self):
        sub = getattr(self.engine, "engines", None)
        return sub() if callable(sub) else [self.engine]

    def _per_request_estimate_s(self) -> float:
        """Observed per-request step cost: the ``"step"`` stage
        histogram's mean over the mean batch size, averaged across
        replicas (racy unlocked float reads — an estimate, not
        accounting)."""
        total_mean = n_hists = 0.0
        per_batch = 0.0
        for eng in self._engines():
            tel = getattr(eng, "telemetry", None)
            if tel is None:
                continue
            h = tel.stages.get("step")
            if h is None or not h.n:
                continue
            total_mean += h.mean
            n_hists += 1
            if tel.batches:
                per_batch += tel.requests / tel.batches
        if not n_hists:
            return self.default_service_s
        mean_step = total_mean / n_hists
        mean_batch = max(per_batch / n_hists, 1.0)
        return max(mean_step / mean_batch, 1e-6)

    def _inflight(self) -> int:
        """Backend in-flight depth across every replica — work queued
        ahead of the next batch (``BackendLoad``)."""
        total = 0
        for eng in self._engines():
            backends = getattr(eng, "backends", None)
            if backends is None:
                continue
            for load in backends.loads_by_tag().values():
                total += load.inflight
        return total

    def _service_estimate_s(self, n: int) -> float:
        """Estimated wall time to serve an ``n``-request batch: its own
        per-request cost (split across replicas) plus the backends'
        current in-flight depth as queue-ahead work."""
        per = self._per_request_estimate_s()
        replicas = max(len(self._engines()), 1)
        return per * (n / replicas + self._inflight())

    def _target_batch(self, now: float) -> int:
        """SLO-aware size: the largest batch (within [min_batch,
        max_batch]) whose estimated service time fits the tightest
        pending deadline slack.  No deadlines -> max_batch."""
        slack = self._tightest_slack(now)
        if slack is None:
            return self.max_batch
        per = self._per_request_estimate_s()
        replicas = max(len(self._engines()), 1)
        budget = slack / self.slo_margin \
            - per * self._inflight()
        fit = int(budget * replicas / per) if per > 0 else self.max_batch
        return max(self.min_batch, min(self.max_batch, fit))

    def _dispatch(self, batch: list[AdmissionTicket], reason: str) -> None:
        now = self.clock()
        try:
            responses = self.engine.step([p.request for p in batch])
        except BaseException as e:
            # a failed step fails its whole batch, loudly: every ticket
            # resolves with the error — never a silent drop
            now = self.clock()
            with self._cv:
                self.failed += len(batch)
            self.events.emit("batch_failed", n=len(batch),
                             error=type(e).__name__, reason=reason)
            for p in batch:
                p._resolve("failed", now, error=e)
            return
        now = self.clock()
        n_served = n_expired = 0
        for p, r in zip(batch, responses):
            if r.deadline_exceeded:
                n_expired += 1
                p._resolve("deadline_exceeded", now, response=r)
            else:
                n_served += 1
                p._resolve("served", now, response=r)
        with self._cv:
            self.served += n_served
            self.pipeline_expired += n_expired
            self.deadline_exceeded += n_expired

    # ----------------------------------------------------------- lifecycle

    def close(self, drain: bool = True) -> None:
        """Stop admitting, resolve everything pending, join the batcher.

        ``drain=True`` (default) serves the backlog first — every
        pending ticket still resolves ``served`` / ``deadline_exceeded``
        / ``failed``.  ``drain=False`` resolves the backlog ``shed``.
        Either way the engine stream the batcher owned is drained and no
        thread is left behind.  Idempotent."""
        with self._cv:
            if self._closed:
                self._cv.notify_all()
            self._closed = True
            dropped = []
            if not drain:
                dropped, self._pending = self._pending, []
                self.shed += len(dropped)
            self._cv.notify_all()
        now = self.clock()
        for p in dropped:
            p._resolve("shed", now)
        if self._batcher is not None:
            self._batcher.join()
            self._batcher = None
        else:
            # start=False: drain synchronously on the caller's thread
            while drain and self._pump_remaining():
                pass
            with self._cv:
                remaining, self._pending = self._pending, []
            for p in remaining:
                p._resolve("shed", self.clock())
                with self._cv:
                    self.shed += 1
            self.engine.drain()
        self.events.emit("queue_close", drained=drain)

    def _pump_remaining(self) -> int:
        with self._cv:
            if not self._pending:
                return 0
        return self.pump(force=True)

    def __enter__(self) -> "AdmissionQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------- observability

    def snapshot(self) -> dict:
        """Queue health: depth, oldest-age, every outcome counter, batch
        flush reasons — what ``export.admission_prometheus_text``
        renders."""
        with self._cv:
            now = self.clock()
            oldest = min((p.submitted_ts for p in self._pending),
                         default=None)
            return {
                "depth": len(self._pending),
                "capacity": self.capacity,
                "high_watermark": self.high_watermark,
                "oldest_age_ms": (now - oldest) * 1e3
                                 if oldest is not None else 0.0,
                "peak_depth": self.peak_depth,
                "submitted": self.submitted,
                "admitted": self.admitted,
                "served": self.served,
                "shed": self.shed,
                "deadline_exceeded": self.deadline_exceeded,
                "pipeline_expired": self.pipeline_expired,
                "failed": self.failed,
                "batches": self.batches,
                "flushes": dict(self.flushes),
                "closed": self._closed,
            }
