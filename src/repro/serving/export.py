"""Exporters — machine-readable views of the engine's observability state.

Four renderings, one source of truth (``SparseKernelEngine``'s telemetry,
flight recorder, event log, and generation log):

``prometheus_text(engine)``
    Prometheus/OpenMetrics-style text exposition of every counter and
    histogram — *bucket counts*, not just p50/p99: stage and per-backend
    latency histograms render as cumulative ``_bucket{le=...}`` series
    (plus ``_sum``/``_count``), counters as ``_total``, and the live
    signals (in-flight depth, breaker state, hit rate, calibration offset
    and **drift**) as gauges.  ``parse_prometheus_text`` is the matching
    minimal parser — what the tests and smoke gates validate the output
    with, and a reference for the exact grammar subset emitted (labels
    never contain quotes, commas, or backslashes).

``chrome_trace(traces, generations=...)``
    Chrome-trace (``chrome://tracing`` / Perfetto) JSON of span trees.
    Every span becomes a complete ("ph": "X") event with microsecond
    ``ts``/``dur`` on a per-generation ``tid`` row; passing
    ``engine.generation_log()`` adds each generation's dispatch->retire
    in-flight window to its row — consecutive generations' overlapping
    windows are the PR-5 async run-ahead, finally visible on a timeline
    instead of compressed into one ``overlap_ratio`` scalar.

``stats_delta(prev, cur)``
    Windowed rates from two ``stats()`` snapshots: req/s, batches/s,
    failovers/s, and *windowed* hit rate over the interval — what a
    dashboard plots, instead of lifetime counters that flatten every
    transient.  ``engine.stats_delta()`` wraps it with an internally-kept
    previous snapshot.

JSONL event export is ``EventLog.to_jsonl()``/``write()`` on
``engine.events`` (``repro.serving.trace``) — one JSON object per line:
breaker transitions, failovers, circuit fast-fails, persistence
quarantines, warm starts, router spills, sticky invalidations, drains.
"""
from __future__ import annotations

import re

__all__ = ["prometheus_text", "admission_prometheus_text",
           "parse_prometheus_text", "prom_get", "chrome_trace",
           "stats_delta"]


# --------------------------------------------------------------- prometheus

def _fmt(v: float) -> str:
    v = float(v)
    if v != v:                          # NaN
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return format(v, ".10g")


def _labels(d: dict) -> str:
    if not d:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in sorted(d.items())) + "}"


class _Writer:
    def __init__(self, namespace: str, base_labels: dict | None = None):
        self.ns = namespace
        self.base = dict(base_labels or {})
        self.lines: list[str] = []
        self._typed: set[str] = set()

    def head(self, name: str, kind: str, help_: str) -> str:
        full = f"{self.ns}_{name}"
        if full not in self._typed:
            self._typed.add(full)
            self.lines.append(f"# HELP {full} {help_}")
            self.lines.append(f"# TYPE {full} {kind}")
        return full

    def sample(self, full: str, value, labels: dict | None = None) -> None:
        merged = {**self.base, **(labels or {})}
        self.lines.append(f"{full}{_labels(merged)} {_fmt(value)}")

    def scalar(self, name: str, kind: str, help_: str, value,
               labels: dict | None = None) -> None:
        self.sample(self.head(name, kind, help_), value, labels)

    def histogram(self, name: str, help_: str, hist,
                  labels: dict | None = None) -> None:
        """One ``LatencyHistogram`` as cumulative buckets + sum + count."""
        full = self.head(name, "histogram", help_)
        labels = dict(labels or {})
        for edge, cum in hist.buckets():
            self.sample(f"{full}_bucket", cum, {**labels, "le": _fmt(edge)})
        self.sample(f"{full}_sum", hist.total, labels)
        self.sample(f"{full}_count", hist.n, labels)

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def prometheus_text(engine, namespace: str = "repro_serving",
                    labels: dict | None = None) -> str:
    """Render one engine's full telemetry as Prometheus text exposition.

    Histogram bucket counts are copied from under the telemetry lock
    (``EngineTelemetry.stage_histograms`` /
    ``backend_serve_histograms``) and rendered outside it; everything
    else reads from one ``stats()`` snapshot.  The output round-trips
    through ``parse_prometheus_text``.

    ``labels`` (optional) is merged into **every** emitted series —
    how ``ShardedEngine`` stamps each replica's exposition with its
    ``shard`` id so N replicas' scrapes concatenate into one multi-shard
    view without series collisions.  Per-series labels win on key clash.
    """
    s = engine.stats()
    w = _Writer(namespace, labels)

    for name, help_ in (("requests", "requests served"),
                        ("batches", "micro-batches served"),
                        ("hits", "autotune cache hits"),
                        ("misses", "autotune cache misses"),
                        ("score_dispatches", "batched scoring dispatches"),
                        ("arena_fallbacks", "arena-overrun fallback builds"),
                        ("warm_start_entries", "cache entries warm-started"),
                        ("warm_start_skipped", "persisted entries skipped"),
                        ("persist_saves", "cache files saved"),
                        ("persist_saved_entries",
                         "cache entries written by saves"),
                        ("persist_load_failures", "unreadable cache files"),
                        ("persist_quarantined", "cache files quarantined")):
        w.scalar(f"{name}_total", "counter", help_, s[name])
    w.scalar("hit_rate", "gauge", "lifetime cache hit rate", s["hit_rate"])

    bp = s["build_paths"]
    full = w.head("builds_total", "counter", "value-scatter builds by path")
    w.sample(full, bp["device"], {"path": "device"})
    w.sample(full, bp["host"], {"path": "host"})
    w.scalar("overlapped_builds_total", "counter",
             "builds issued over an in-flight generation", bp["overlapped"])
    w.scalar("overlap_ratio", "gauge", "overlapped / total builds",
             bp["overlap_ratio"])
    w.scalar("drain_waits_total", "counter", "drains that had to wait",
             bp["drain_waits"])

    wl = s["warm_lane"]
    for key, help_ in (("steps", "steps served via the warm fast path"),
                       ("requests", "requests served via the warm lane"),
                       ("sampled_steps",
                        "warm steps with per-request telemetry sampled"),
                       ("fallthroughs",
                        "warm candidates that fell through to routing"),
                       ("invalidations", "warm table entries invalidated"),
                       ("fused_builds", "fused aligned-buffer builds")):
        w.scalar(f"warm_{key}_total", "counter", help_, wl[key])
    w.scalar("warm_table_size", "gauge", "recorded warm decisions",
             wl["table"])

    dl = s.get("deadlines", {})
    w.scalar("deadline_expired_total", "counter",
             "requests expired by the pipeline deadline gates",
             dl.get("expired", 0))
    w.scalar("retry_deadline_exhausted_total", "counter",
             "failed requests whose remaining budget forbade a retry",
             dl.get("retry_exhausted", 0))

    h = s["health"]
    for name in ("execute_failures", "output_guard_failures",
                 "circuit_fast_fails", "failovers", "retry_failures"):
        w.scalar(f"{name}_total", "counter",
                 name.replace("_", " "), h[name])
    st_full = w.head("breaker_state", "gauge",
                     "circuit-breaker state one-hot per tag")
    for tag, br in h["breakers"].items():
        for state in ("closed", "open", "half_open"):
            w.sample(st_full, int(br["state"] == state),
                     {"tag": tag, "state": state})
    for key, kind, help_ in (
            ("failure_rate", "gauge", "rolling failure rate"),
            ("backoff_s", "gauge", "current open->probe backoff seconds"),
            ("opens", "counter", "breaker open trips"),
            ("transitions", "counter", "breaker state changes")):
        suffix = "_total" if kind == "counter" else ""
        full = w.head(f"breaker_{key}{suffix}", kind, f"breaker {help_}")
        for tag, br in h["breakers"].items():
            w.sample(full, br[key], {"tag": tag})

    r = s["routing"]
    full = w.head("route_decisions_total", "counter",
                  "routing decisions by reason")
    for reason, n in sorted(r["decisions"].items()):
        w.sample(full, n, {"reason": reason})
    full = w.head("routed_requests_total", "counter",
                  "requests routed per platform")
    for platform, n in sorted(r["by_platform"].items()):
        w.sample(full, n, {"platform": platform})
    w.scalar("route_config_installs_total", "counter",
             "router config hints installed", r["config_installs"])

    cal_obs = w.head("calibration_observed_ms", "gauge",
                     "EMA observed serve latency (ms)")
    cal_off = w.head("calibration_offset", "gauge",
                     "observed-vs-predicted additive offset")
    cal_drift = w.head("calibration_drift_ms", "gauge",
                       "EMA |observed - calibrated expectation| (ms)")
    for platform, c in sorted(r["calibration"].items()):
        rows = [({"platform": platform, "op": ""}, c)]
        rows += [({"platform": platform, "op": op}, co)
                 for op, co in sorted(c.get("by_op", {}).items())]
        for labels, cc in rows:
            w.sample(cal_obs, cc["observed_ms"], labels)
            w.sample(cal_off, cc["offset"], labels)
            w.sample(cal_drift, cc["drift_ms"], labels)

    for key, kind in (("inflight", "gauge"), ("peak", "gauge"),
                      ("total", "counter")):
        suffix = "_total" if kind == "counter" else ""
        full = w.head(f"backend_{key}{suffix}", kind,
                      f"per-backend load {key}")
        for tag, load in sorted(s["load"].items()):
            if key in load:
                w.sample(full, load[key], {"tag": tag})

    for key in ("size", "hits", "misses", "evictions"):
        kind = "gauge" if key == "size" else "counter"
        suffix = "" if kind == "gauge" else "_total"
        full = w.head(f"autotune_cache_{key}{suffix}", kind,
                      f"autotune cache {key} per platform")
        for platform, c in sorted(s["caches"].items()):
            w.sample(full, c[key], {"platform": platform})

    tr = s["tracing"]
    w.scalar("trace_sample_rate", "gauge", "head-sampling rate",
             tr["sample_rate"])
    for key in ("steps", "sampled_steps", "recorded", "error_recorded",
                "dropped", "error_dropped"):
        w.scalar(f"trace_{key}_total", "counter",
                 f"flight recorder {key}", tr[key])
    for key in ("buffered", "error_buffered"):
        w.scalar(f"trace_{key}", "gauge", f"flight recorder {key}", tr[key])

    full = w.head("events_total", "counter", "structured events by kind")
    for kind_, n in sorted(s["events"]["by_kind"].items()):
        w.sample(full, n, {"kind": kind_})

    for name, hist in engine.telemetry.stage_histograms().items():
        w.histogram("stage_duration_seconds", "pipeline stage latency",
                    hist, {"stage": name})
    for tag, hist in engine.telemetry.backend_serve_histograms().items():
        w.histogram("backend_serve_seconds", "per-backend serve latency",
                    hist, {"tag": tag})

    return w.text()


def admission_prometheus_text(queue, namespace: str = "repro_serving",
                              labels: dict | None = None) -> str:
    """One ``AdmissionQueue``'s health as Prometheus text exposition.

    Queue depth / capacity / oldest-age gauges, every outcome counter
    (submitted, admitted, served, shed, deadline-exceeded — split out
    into the pipeline-expired share — failed), and batch flushes by
    trigger.  Reads one ``snapshot()``; round-trips through
    ``parse_prometheus_text``.  ``labels`` merges into every series,
    same as the engine exposition."""
    s = queue.snapshot()
    w = _Writer(namespace, labels)
    for name, help_ in (("depth", "pending admitted requests"),
                        ("capacity", "maximum pending requests"),
                        ("high_watermark", "depth at which shedding starts"),
                        ("oldest_age_ms",
                         "age of the oldest pending request (ms)"),
                        ("peak_depth", "high-water pending depth")):
        w.scalar(f"admission_{name}", "gauge", help_, s[name])
    for name, help_ in (("submitted", "submit calls"),
                        ("admitted", "requests accepted into the queue"),
                        ("served", "requests served through the pipeline"),
                        ("shed", "requests shed under overload"),
                        ("deadline_exceeded",
                         "requests resolved deadline_exceeded"),
                        ("pipeline_expired",
                         "deadline_exceeded raised mid-pipeline"),
                        ("failed", "requests failed by a dispatch error"),
                        ("batches", "batches dispatched")):
        w.scalar(f"admission_{name}_total", "counter", help_, s[name])
    full = w.head("admission_flushes_total", "counter",
                  "batch flushes by trigger")
    for reason, n in sorted(s["flushes"].items()):
        w.sample(full, n, {"reason": reason})
    w.scalar("admission_closed", "gauge", "queue closed flag",
             int(s["closed"]))
    return w.text()


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_prometheus_text(text: str) -> list[tuple[str, dict, float]]:
    """Minimal Prometheus text parser: ``[(name, labels, value), ...]``.

    Handles exactly the grammar ``prometheus_text`` emits (and standard
    scrape output without escapes/exemplars/timestamps): ``# HELP`` /
    ``# TYPE`` / blank lines are skipped, every other line must be
    ``name[{labels}] value`` with ``k="v"`` label pairs whose values
    contain no quotes, commas, or backslashes.  Raises ``ValueError`` on
    the first malformed line — the validation hook the smoke gate uses.
    """
    out: list[tuple[str, dict, float]] = []
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {ln}: unparseable sample {raw!r}")
        name, labelstr, valstr = m.groups()
        labels = {}
        if labelstr:
            body = labelstr[1:-1].strip()
            if body:
                pairs = _LABEL_RE.findall(body)
                # every k="v" accounted for, or the line is malformed
                rebuilt = ",".join(f'{k}="{v}"' for k, v in pairs)
                if rebuilt.replace(" ", "") != body.replace(" ", ""):
                    raise ValueError(f"line {ln}: bad labels {labelstr!r}")
                labels = dict(pairs)
        try:
            value = float(valstr)
        except ValueError:
            raise ValueError(f"line {ln}: bad value {valstr!r}") from None
        out.append((name, labels, value))
    return out


def prom_get(samples: list[tuple[str, dict, float]], name: str,
             **labels) -> float | None:
    """First sample matching ``name`` whose labels include ``labels``."""
    for n, lab, v in samples:
        if n == name and all(lab.get(k) == v2 for k, v2 in labels.items()):
            return v
    return None


# ------------------------------------------------------------- chrome trace

def chrome_trace(traces, generations=None, *,
                 process_name: str = "repro.serving") -> dict:
    """Span trees (+ optional generation windows) as Chrome-trace JSON.

    Event schema (the documented subset): ``{"traceEvents": [...],
    "displayTimeUnit": "ms"}`` where every event is either a complete
    event — ``{"name", "cat": "serving", "ph": "X", "ts": µs, "dur": µs,
    "pid": 1, "tid": generation, "args": {...}}`` — or a ``"ph": "M"``
    process/thread-name metadata record.  ``ts`` is relative to the
    earliest trace in the export (Chrome renders absolute µs poorly);
    ``tid`` is the engine dispatch generation, so each generation gets
    its own row and the in-flight windows from
    ``engine.generation_log()`` visibly overlap when the async pipeline
    ran ahead.  Root spans carry ``trace_id``/``status`` in ``args``.
    """
    traces = list(traces)
    generations = list(generations or ())
    anchors = [t.wall_ts for t in traces] \
        + [g["dispatched"] for g in generations]
    base = min(anchors) if anchors else 0.0
    events: list[dict] = []
    tids: set[int] = set()

    def add_span(span, wall0: float, tid: int, extra: dict | None = None):
        events.append({"name": span.name, "cat": "serving", "ph": "X",
                       "ts": (wall0 - base + span.t0) * 1e6,
                       "dur": span.dur * 1e6, "pid": 1, "tid": tid,
                       "args": {**span.attrs, **(extra or {})}})
        for child in span.children:
            add_span(child, wall0, tid)

    for t in traces:
        tids.add(t.generation)
        add_span(t.root, t.wall_ts, t.generation,
                 {"trace_id": t.trace_id, "status": t.status, "op": t.op,
                  "platform": t.platform})
    for g in generations:
        tid = g["generation"]
        tids.add(tid)
        events.append({"name": f"generation {tid} in-flight",
                       "cat": "serving", "ph": "X",
                       "ts": (g["dispatched"] - base) * 1e6,
                       "dur": max(g["retired"] - g["dispatched"], 0.0) * 1e6,
                       "pid": 1, "tid": tid,
                       "args": {"wait_ms": g["wait_ms"],
                                "drained": g["drained"]}})
    events.sort(key=lambda e: e["ts"])
    meta = [{"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": process_name}}]
    meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
              "args": {"name": f"generation {tid}"}}
             for tid in sorted(tids)]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


# -------------------------------------------------------------- stats delta

def stats_delta(prev: dict, cur: dict) -> dict:
    """Windowed rates between two ``stats()`` snapshots (prev first).

    Returns ``{"interval_s", "requests", "requests_per_s", "batches",
    "batches_per_s", "hits", "misses", "hit_rate" (WINDOWED — hits /
    served within the interval, not lifetime), "failovers",
    "failovers_per_s", "execute_failures", "backends": {tag:
    {"requests", "requests_per_s", "hit_rate"}}}``.

    A ``cur`` whose lifetime request/batch counters sit *below* ``prev``'s
    means the engine restarted inside the window — the new process's
    counters began again at zero.  The window then **rebaselines to
    zero** (measuring the new engine's lifetime-so-far) instead of
    clamping every counter delta independently: per-counter clamping is
    wrong for *ratios* — after a warm-start restore, hits restart small
    (clamped to 0) while misses may still clear the old baseline, so the
    windowed hit rate collapses to garbage even though the restored
    cache is serving nearly all hits.  Ratios are additionally clamped
    into [0, 1] (top-level and per-backend), so no snapshot pair can
    report a negative or >1 rate."""
    dt = max(float(cur["ts"]) - float(prev.get("ts", cur["ts"])), 1e-9)
    if (float(cur.get("requests", 0)) < float(prev.get("requests", 0))
            or float(cur.get("batches", 0)) < float(prev.get("batches", 0))):
        prev = {"ts": prev.get("ts", cur["ts"])}   # restart: zero baseline

    def delta(*path) -> float:
        a, b = prev, cur
        for k in path:
            a = a.get(k, 0) if isinstance(a, dict) else 0
            b = b.get(k, 0) if isinstance(b, dict) else 0
        return max(float(b) - float(a), 0.0)

    def ratio(num: float, den: float) -> float:
        return min(max(num / den, 0.0), 1.0) if den else 0.0

    requests = delta("requests")
    batches = delta("batches")
    hits, misses = delta("hits"), delta("misses")
    failovers = delta("health", "failovers")
    out = {
        "interval_s": dt,
        "requests": requests, "requests_per_s": requests / dt,
        "batches": batches, "batches_per_s": batches / dt,
        "hits": hits, "misses": misses,
        "hit_rate": ratio(hits, hits + misses),
        "failovers": failovers, "failovers_per_s": failovers / dt,
        "execute_failures": delta("health", "execute_failures"),
        "backends": {},
    }
    for tag in cur.get("backends", {}):
        b_req = delta("backends", tag, "requests")
        b_hits = delta("backends", tag, "hits")
        b_miss = delta("backends", tag, "misses")
        out["backends"][tag] = {
            "requests": b_req, "requests_per_s": b_req / dt,
            "hit_rate": ratio(b_hits, b_hits + b_miss)}
    return out
