"""Deterministic fault injection for the serving stack.

Testing a fault-tolerance layer against *real* hardware failures is not
reproducible; testing it against ``unittest.mock`` side effects doesn't
exercise the pipeline.  This module sits in between: a seedable,
call-indexed fault schedule (``FaultPlan``) wraps any registered backend's
executor in place (``inject_faults`` — ``KernelBackend.run`` is plain
attribute assignment), so a test or benchmark can kill a backend on
exactly the Nth kernel launch, poison its outputs with NaNs, or spike its
latency — and replay the identical failure sequence on every run.

Faults are keyed on the executor's **call index** (0-based, counted under
a lock), not wall-clock time, so a schedule composes deterministically
with the engine's batching: "fail calls 16..39" is exactly one healthy
warm-up batch, one hard-down batch, and two failed half-open probes for
an 8-request micro-batch, independent of machine speed.  The optional
``prob`` knob keeps determinism by hashing ``(seed, call_index)`` into a
per-call Bernoulli draw — same seed, same faults, any interleaving.

Injected errors raise ``InjectedFault`` (a ``RuntimeError``), so tests can
distinguish scheduled failures from genuine bugs.  ``truncate_file`` /
``flip_byte`` are the matching *persistence* fault tools — torn and
bit-rotted cache files for ``repro.serving.persist``'s quarantine path.

Two *replica-level* kinds exist for the ``ReplicaSupervisor`` watchdog
tests: ``"hang"`` blocks the serving thread on an event until the test
calls ``FaultyExecutor.release_hangs()`` (or an optional per-window
timeout elapses) and then executes normally — a stuck-but-alive replica
whose heartbeat goes stale; ``"crash"`` raises ``ReplicaCrash``, a
``BaseException`` that deliberately escapes the engine's per-request
``except Exception`` fault isolation, killing the whole step the way a
dying serving thread would — the replica's work unwinds (leases roll
back) and the future carries the crash to the shard layer.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

__all__ = ["InjectedFault", "ReplicaCrash", "FaultWindow", "FaultPlan",
           "FaultyExecutor", "inject_faults", "truncate_file", "flip_byte"]


class InjectedFault(RuntimeError):
    """A scheduled executor failure (never raised by real serving code)."""


class ReplicaCrash(BaseException):
    """A scheduled serving-thread death.

    Derives from ``BaseException`` on purpose: the engine's execute stage
    isolates per-request ``Exception``s into the retry lane, but a crash
    must take the whole step down (leases roll back via ``step()``'s
    ``BaseException`` handler) so the shard layer sees a dead replica,
    not a degraded response."""


@dataclasses.dataclass(frozen=True)
class FaultWindow:
    """One fault rule over a half-open range of executor call indices.

    Args:
        kind: ``"error"`` (raise ``InjectedFault`` instead of executing),
            ``"nan"`` (execute, then poison the output with NaNs — what
            the engine's opt-in output guard must catch), ``"latency"``
            (sleep ``latency_s`` before executing), ``"hang"`` (block on
            the executor's release event — ``latency_s`` > 0 bounds the
            wait — then execute normally), or ``"crash"`` (raise
            ``ReplicaCrash``, taking the serving thread's step down).
        start: first call index (0-based) the rule applies to.
        stop: one past the last affected call; ``None`` = forever.
        every: within the window, apply to every ``every``-th call.
        prob: probability the rule fires on a matching call (drawn
            deterministically from the plan seed and the call index).
        latency_s: injected delay for ``kind="latency"``; maximum blocked
            wait for ``kind="hang"`` (0 = until released).
    """
    kind: str = "error"
    start: int = 0
    stop: int | None = None
    every: int = 1
    prob: float = 1.0
    latency_s: float = 0.0

    def matches(self, i: int) -> bool:
        return (i >= self.start
                and (self.stop is None or i < self.stop)
                and (i - self.start) % max(self.every, 1) == 0)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule: a set of windows + one seed.

    ``active(i)`` returns the fault kinds firing on call ``i`` — a pure
    function of ``(windows, seed, i)``, so a plan replays identically
    across runs and thread interleavings."""
    windows: tuple[FaultWindow, ...] = ()
    seed: int = 0

    @classmethod
    def fail_calls(cls, start: int, stop: int | None = None,
                   seed: int = 0) -> "FaultPlan":
        """Hard-fail every executor call in ``[start, stop)``."""
        return cls((FaultWindow("error", start, stop),), seed)

    @classmethod
    def nan_calls(cls, start: int, stop: int | None = None,
                  seed: int = 0) -> "FaultPlan":
        """Poison the output of every call in ``[start, stop)`` with NaNs."""
        return cls((FaultWindow("nan", start, stop),), seed)

    @classmethod
    def latency_calls(cls, start: int, stop: int | None, latency_s: float,
                      seed: int = 0) -> "FaultPlan":
        """Delay every call in ``[start, stop)`` by ``latency_s``."""
        return cls((FaultWindow("latency", start, stop,
                                latency_s=latency_s),), seed)

    @classmethod
    def hang_calls(cls, start: int, stop: int | None = None,
                   max_wait_s: float = 0.0, seed: int = 0) -> "FaultPlan":
        """Block every call in ``[start, stop)`` until the executor's
        ``release_hangs()`` fires (or ``max_wait_s`` elapses, if > 0),
        then execute normally — a hung-but-alive serving thread."""
        return cls((FaultWindow("hang", start, stop,
                                latency_s=max_wait_s),), seed)

    @classmethod
    def crash_calls(cls, start: int, stop: int | None = None,
                    seed: int = 0) -> "FaultPlan":
        """Kill the serving thread's step on every call in ``[start,
        stop)`` by raising ``ReplicaCrash`` (a ``BaseException``)."""
        return cls((FaultWindow("crash", start, stop),), seed)

    def active(self, i: int) -> list[FaultWindow]:
        out = []
        for w in self.windows:
            if not w.matches(i):
                continue
            if w.prob < 1.0:
                # per-call deterministic Bernoulli: keyed on (seed, i) so
                # the draw doesn't depend on evaluation order
                draw = np.random.default_rng((self.seed, i)).random()
                if draw >= w.prob:
                    continue
            out.append(w)
        return out


class FaultyExecutor:
    """A backend executor wrapped with a ``FaultPlan``.

    Drop-in for ``KernelBackend.run`` (``(config, matrix, operand) ->
    output``).  Counts calls under a lock and applies the plan's rules for
    each call index; per-kind injection counts live in ``injected``.
    ``block_event``, when set to a ``threading.Event``, makes every
    *faulted* error call block on the event before raising — the hook the
    drain-under-failure tests use to hold a failure in flight.  Hung
    calls (``kind="hang"``) park on the internal release event until
    ``release_hangs()``; ``hanging`` counts the threads currently parked
    so a watchdog test can wait for the hang to actually take hold.
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.calls = 0
        self.injected = {"error": 0, "nan": 0, "latency": 0,
                         "hang": 0, "crash": 0}
        self.block_event: threading.Event | None = None
        self.hanging = 0
        self._hang_released = threading.Event()
        self._lock = threading.Lock()
        self._backend = None
        self._orig_run = None

    def release_hangs(self) -> None:
        """Unblock every call parked (now or later) on a hang window."""
        self._hang_released.set()

    def __call__(self, config, matrix, operand):
        with self._lock:
            i = self.calls
            self.calls += 1
            acts = self.plan.active(i)
            for w in acts:
                self.injected[w.kind] += 1
        for w in acts:
            if w.kind == "latency":
                time.sleep(w.latency_s)
            elif w.kind == "hang":
                with self._lock:
                    self.hanging += 1
                try:
                    self._hang_released.wait(w.latency_s or None)
                finally:
                    with self._lock:
                        self.hanging -= 1
        if any(w.kind == "crash" for w in acts):
            raise ReplicaCrash(f"injected serving-thread crash on call {i}")
        if any(w.kind == "error" for w in acts):
            if self.block_event is not None:
                self.block_event.wait()
            raise InjectedFault(f"injected failure on call {i}")
        out = self.inner(config, matrix, operand)
        if any(w.kind == "nan" for w in acts):
            import jax.numpy as jnp
            out = jnp.asarray(out) * jnp.float32(float("nan"))
        return out

    def restore(self) -> None:
        """Un-inject: put the original executor back on the backend."""
        if self._backend is not None:
            self._backend.run = self._orig_run
            self._backend = None


def inject_faults(registry, platform: str, op: str,
                  plan: FaultPlan) -> FaultyExecutor:
    """Wrap the ``(platform, op)`` backend's executor with ``plan``.

    Swaps ``KernelBackend.run`` in place on the registered backend (every
    engine sharing the registry sees the faults — that's the point) and
    returns the wrapper for call/injection counts and ``restore()``."""
    be = registry.get(platform, op)
    fx = FaultyExecutor(be.run, plan)
    fx._backend, fx._orig_run = be, be.run
    be.run = fx
    return fx


# --------------------------------------------------------- persistence faults

def truncate_file(path, keep) -> None:
    """Tear a file: keep the first ``keep`` bytes (an ``int``) or fraction
    (a ``float`` in (0, 1)) — the shape a crash mid-write leaves behind."""
    import os
    size = os.path.getsize(path)
    n = int(size * keep) if isinstance(keep, float) else int(keep)
    with open(path, "r+b") as f:
        f.truncate(max(n, 0))


def flip_byte(path, offset: int, mask: int = 0xFF) -> None:
    """Bit-rot: XOR the byte at ``offset`` (negative = from the end) with
    ``mask``."""
    import os
    size = os.path.getsize(path)
    if offset < 0:
        offset += size
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ (mask & 0xFF)]))
