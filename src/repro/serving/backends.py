"""Backend registry — one serving engine fronting many (platform, op) kernels.

COGNATE's premise is that a single cost-model pipeline spans heterogeneous
hardware; the serving analogue is a single engine spanning heterogeneous
*kernel implementations*.  ``BackendRegistry`` maps a ``(platform, op)`` tag
— e.g. ``("tpu_interpret", "spmm")`` or ``("cpu_ref", "sddmm")`` — to a
``KernelBackend`` bundle:

* an **executor** that launches the op for that platform (compiled Pallas,
  Pallas interpreter, or the pure-jnp reference in ``repro.kernels.ref``),
* a ``KernelAutotuner`` owning that backend's pattern-keyed cache (so the
  same sparsity pattern tuned for two platforms yields two independent
  entries — configs never cross-contaminate between backends), and
* the **config space** the backend's tuner searches (``None`` for backends
  with no tile knobs, like the reference path).

``SparseKernelEngine.step`` partitions each micro-batch by tag, batches the
misses *within* each backend's autotuner (one ``scores_batch`` dispatch per
backend per step), executes through each backend's executor, and reports
per-backend hit rates and latency quantiles.  ``repro.serving.persist``
namespaces warm-start files by the platform tag so one file restores every
backend's cache.

Three concrete platforms ship by default (``default_registry``):

``tpu_pallas``
    Compiled Pallas kernels (Mosaic).  On hosts without a TPU this degrades
    to interpreter execution via ``repro.kernels.ops.resolve_interpret`` —
    the tag, tuner, and cache stay distinct so the routing and persistence
    behaviour is identical to a real accelerator deployment.
``tpu_interpret``
    Pallas interpreter mode — same kernels, any JAX backend.
``cpu_ref``
    The pure-jnp oracles from ``repro.kernels.ref``.  No tile knobs; its
    tuner runs the structural heuristic only to pick the plan's ``block_m``.

Adding a backend is three lines (see ``docs/serving.md``)::

    registry.register(KernelBackend("my_accel", "spmm", KernelAutotuner(),
                                    run=my_executor, space=my_space))

All registry operations are thread-safe for the engine's usage pattern:
registration happens before serving; lookups afterwards are read-only.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import jax.numpy as jnp

from repro.core.autotune import Autotuner, KernelAutotuner
from repro.kernels import ops

__all__ = ["KernelBackend", "BackendLoad", "BackendRegistry",
           "DEFAULT_PLATFORM", "pallas_backend", "cpu_ref_backend",
           "default_registry"]


class BackendLoad:
    """Thread-safe in-flight depth for one backend.

    ``inflight`` counts requests the engine has dispatched to the backend
    whose results are still outstanding — a request joins at partition time
    and leaves when its serving stream's arena leases are released (the next
    ``step`` on that thread, or ``release_stream()``).  This is the
    saturation signal ``LoadAwareRouter`` reads to decide when to spill
    traffic to a fallback backend; ``peak`` records the high-water mark and
    ``total`` the lifetime request count.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._inflight = 0
        self.peak = 0
        self.total = 0

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    def begin(self, n: int = 1) -> None:
        with self._lock:
            self._inflight += n
            self.total += n
            if self._inflight > self.peak:
                self.peak = self._inflight

    def end(self, n: int = 1) -> None:
        with self._lock:
            self._inflight = max(self._inflight - n, 0)

    def snapshot(self) -> dict:
        """Consistent point-in-time read of all three gauges — one lock
        acquisition instead of three racing property reads (what the
        exporters consume)."""
        with self._lock:
            return {"inflight": self._inflight, "peak": self.peak,
                    "total": self.total}

#: Platform tag requests without an explicit tag are routed to, and the
#: namespace legacy (version-1) persistence files are loaded under.
DEFAULT_PLATFORM = "tpu_interpret"


@dataclasses.dataclass
class KernelBackend:
    """Everything the engine needs to serve one ``(platform, op)`` tag.

    Args:
        platform: backend tag, e.g. ``"tpu_pallas"`` — the routing key
            carried by ``KernelRequest.platform`` and the namespace used by
            the persistence format.
        op: ``"spmm"`` or ``"sddmm"`` (anything ``run`` implements).
        tuner: the backend's ``KernelAutotuner``.  Owns the pattern-keyed
            LRU; two backends with distinct tuners never share entries.
            Backends of one platform may share a tuner across ops (cache
            keys already include the op).
        run: executor ``(config, matrix, operand) -> output``.  ``config``
            is the tuned kwargs dict from the backend's tuner, ``matrix``
            the built ``BsrMatrix``; never called with ``operand=None``
            (prepare-only requests skip execution).
        space: the config space the tuner searches (informational —
            ``None`` when the backend has no tile knobs).  Routers score
            candidate backends against these spaces.
        load: live in-flight depth (``BackendLoad``), maintained by the
            engine and read by load-aware routing policies.

    Thread-safety: immutable after construction (``load``'s counters are
    internally locked); ``run`` must be safe to call from concurrent engine
    steps (the shipped executors are).
    """
    platform: str
    op: str
    tuner: KernelAutotuner
    run: Callable
    space: object = None
    load: BackendLoad = dataclasses.field(default_factory=BackendLoad)

    @property
    def tag(self) -> tuple[str, str]:
        return (self.platform, self.op)


class BackendRegistry:
    """Maps ``(platform, op)`` tags to ``KernelBackend`` bundles.

    ``default_platform`` is where requests without an explicit tag (and
    legacy single-backend persistence files) are routed.

    Thread-safety: ``register`` before serving starts; all other methods
    are read-only and safe under concurrent ``step`` calls.
    """

    def __init__(self, default_platform: str = DEFAULT_PLATFORM):
        self.default_platform = default_platform
        self._by_tag: dict[tuple[str, str], KernelBackend] = {}

    def register(self, backend: KernelBackend) -> KernelBackend:
        """Add (or replace) the backend under its ``(platform, op)`` tag."""
        self._by_tag[backend.tag] = backend
        return backend

    def get(self, platform: str, op: str) -> KernelBackend:
        """Resolve a tag; raises ``KeyError`` naming the unknown tag and
        every registered backend (the engine calls this at *routing* time,
        so a request carrying a bad ``platform`` fails up front with a
        readable message instead of deep inside serving)."""
        be = self._by_tag.get((platform, op))
        if be is None:
            raise KeyError(
                f"no backend registered for ({platform!r}, {op!r}); "
                f"registered platforms: {self.platforms()}; "
                f"known tags: {sorted(self._by_tag)}")
        return be

    def __contains__(self, tag: tuple[str, str]) -> bool:
        return tuple(tag) in self._by_tag

    def __iter__(self):
        return iter(self._by_tag.values())

    def tags(self) -> list[tuple[str, str]]:
        return sorted(self._by_tag)

    def platforms(self) -> list[str]:
        return sorted({p for p, _ in self._by_tag})

    def tuners(self) -> list[KernelAutotuner]:
        """Distinct tuners across all backends (shared tuners listed once)."""
        seen: dict[int, KernelAutotuner] = {}
        for be in self._by_tag.values():
            seen.setdefault(id(be.tuner), be.tuner)
        return list(seen.values())

    def caches_by_platform(self) -> dict[str, list]:
        """platform -> distinct ``AutotuneCache`` objects of its backends —
        the unit ``repro.serving.persist.save_backends`` serializes."""
        out: dict[str, dict[int, object]] = {}
        for be in self._by_tag.values():
            out.setdefault(be.platform, {}).setdefault(
                id(be.tuner.cache), be.tuner.cache)
        return {p: list(c.values()) for p, c in out.items()}

    def loads_by_tag(self) -> dict[str, BackendLoad]:
        """``"platform/op"`` -> that backend's live ``BackendLoad`` counters
        (what ``SparseKernelEngine.stats()["load"]`` renders)."""
        return {f"{p}/{op}": be.load
                for (p, op), be in sorted(self._by_tag.items())}


# ------------------------------------------------------------ concrete backends

def _as_kernel_tuner(tuner, cache_size: int) -> KernelAutotuner:
    if isinstance(tuner, KernelAutotuner):
        return tuner
    return KernelAutotuner(tuner, cache_size=cache_size)


def pallas_backend(op: str, tuner: Autotuner | KernelAutotuner | None = None,
                   *, interpret: bool = True, platform: str | None = None,
                   cache_size: int = 128) -> KernelBackend:
    """Pallas kernel backend for ``op`` (``"spmm"`` | ``"sddmm"``).

    ``interpret=False`` requests compiled Mosaic execution; off-TPU it
    degrades to interpreter mode (``ops.resolve_interpret``) while keeping
    its own tag/tuner/cache.  ``platform`` defaults to ``"tpu_interpret"``
    or ``"tpu_pallas"`` accordingly.
    """
    platform = platform or ("tpu_interpret" if interpret else "tpu_pallas")
    kt = _as_kernel_tuner(tuner, cache_size)
    mode = ops.resolve_interpret(interpret)
    if op == "spmm":
        def run(config, matrix, operand):
            return ops.spmm(matrix, jnp.asarray(operand),
                            block_n=config["block_n"],
                            n_major=config["n_major"], interpret=mode)
    elif op == "sddmm":
        def run(config, matrix, operand):
            b, c = operand
            return ops.sddmm(matrix, jnp.asarray(b), jnp.asarray(c),
                             interpret=mode)
    else:
        raise ValueError(f"unknown op {op!r}")
    return KernelBackend(platform, op, kt, run, kt.space)


def cpu_ref_backend(op: str, tuner: KernelAutotuner | None = None,
                    *, cache_size: int = 128) -> KernelBackend:
    """Pure-jnp reference backend (platform tag ``"cpu_ref"``).

    Executes ``repro.kernels.ops.spmm_ref`` / ``sddmm_ref``.  The reference
    path has no tile knobs, so the tuned config only fixes the plan's
    ``block_m``; by default the tuner is a heuristic ``KernelAutotuner``
    (no cost-model dispatches at all).
    """
    kt = tuner if tuner is not None \
        else KernelAutotuner(None, cache_size=cache_size)
    if op == "spmm":
        def run(config, matrix, operand):
            return ops.spmm_ref(matrix, jnp.asarray(operand))
    elif op == "sddmm":
        def run(config, matrix, operand):
            b, c = operand
            return ops.sddmm_ref(matrix, jnp.asarray(b), jnp.asarray(c))
    else:
        raise ValueError(f"unknown op {op!r}")
    return KernelBackend("cpu_ref", op, kt, run, space=None)


def default_registry(tuner: Autotuner | KernelAutotuner | None = None,
                     cache_size: int = 128,
                     default_platform: str = DEFAULT_PLATFORM
                     ) -> BackendRegistry:
    """The stock three-platform registry the engine builds when handed no
    explicit one: ``tpu_interpret`` and ``tpu_pallas`` (compiled; degrades
    to interpret off-TPU) sharing the given learned tuner's cost model but
    each owning an independent cache, plus the knob-free ``cpu_ref``
    reference.  ``tuner`` (an ``Autotuner`` or prebuilt ``KernelAutotuner``)
    becomes the *default platform's* tuner, so pre-registry code that
    constructed ``SparseKernelEngine(KernelAutotuner(...))`` keeps observing
    the same object's counters.
    """
    kt_default = _as_kernel_tuner(tuner, cache_size)
    learned = kt_default.tuner
    reg = BackendRegistry(default_platform)
    for platform, interp in (("tpu_interpret", True), ("tpu_pallas", False)):
        kt = kt_default if platform == default_platform \
            else KernelAutotuner(learned, cache_size=cache_size)
        for op in ("spmm", "sddmm"):
            reg.register(pallas_backend(op, kt, interpret=interp,
                                        platform=platform))
    kt_ref = kt_default if default_platform == "cpu_ref" \
        else KernelAutotuner(None, cache_size=cache_size)
    for op in ("spmm", "sddmm"):
        reg.register(cpu_ref_backend(op, kt_ref))
    return reg
