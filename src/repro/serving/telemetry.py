"""Serving telemetry: counters + fixed-bucket latency histograms.

Histograms use log-spaced bucket edges (1 µs .. ~100 s) so p50/p99 come from
O(1)-memory bucket counts instead of unbounded sample lists — the structure a
long-running engine can keep forever.  Quantiles are read off the bucket
upper edges (conservative: reported latency >= true latency, error bounded by
the ~26% bucket ratio), which is the standard Prometheus-style trade.

``EngineTelemetry`` is what ``SparseKernelEngine`` owns: request/hit/miss
counters, one histogram per pipeline stage (route, partition, score, build,
execute, step), per-backend serve accounting (requests, hits, misses, and a
latency histogram per ``platform/op`` tag — how multi-backend dispatch
surfaces each backend's hit rate and p50/p99), routing-decision counters
(how many requests each ``Router`` policy sent where, and why — explicit
tag, default, cost-model pick, load spill, exploration), arena overflow
fallbacks, and warm-start/persistence events.  All mutation is lock-guarded
so concurrent engine steps can share one instance.

``RouteCalibration`` is the engine's observed-vs-predicted latency ledger:
for every served route it folds the request's observed serve latency (and,
for cost-model routes, the predicted rank score) into per-platform EMAs —
and, when the caller names the op, into finer per-``(platform, op)`` EMAs.
``offset(platform[, op])`` turns those into the additive correction
``CostModelRouter`` applies to the unitless cost-model score — once a
backend has been observed, its effective routing cost tracks its *real*
latency scale while the cost model keeps breaking ties per pattern.  The
per-platform aggregate is always maintained, so existing consumers (and
``stats()["routing"]["calibration"]``'s shape) are unchanged; per-op detail
nests under each platform's ``"by_op"`` key.
"""
from __future__ import annotations

import threading

import numpy as np

__all__ = ["LatencyHistogram", "EngineTelemetry", "RouteCalibration"]


class LatencyHistogram:
    """Fixed log-spaced latency histogram over (1e-6 s, ~1e2 s)."""

    def __init__(self, n_buckets: int = 72):
        # 72 buckets spanning 8 decades: ratio ~ 10^(8/72) ~ 1.29
        self.edges = np.logspace(-6, 2, n_buckets)     # bucket upper bounds
        self.counts = np.zeros(n_buckets + 1, np.int64)  # +overflow bucket
        self.total = 0.0
        self.n = 0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        i = int(np.searchsorted(self.edges, seconds, side="left"))
        self.counts[i] += 1
        self.total += seconds
        self.n += 1
        if seconds > self.max:
            self.max = seconds

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket containing the q-quantile sample."""
        if self.n == 0:
            return 0.0
        rank = q * (self.n - 1)
        # bucket i covers sorted-sample indices [cum[i-1], cum[i] - 1]
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum - 1, rank, side="left"))
        if i >= self.edges.size:        # overflow bucket: report the max seen
            return self.max
        return float(self.edges[i])

    def copy(self) -> "LatencyHistogram":
        """Independent point-in-time copy (bucket edges shared — they are
        immutable).  This is how ``EngineTelemetry.snapshot`` gets the
        counts out from under its lock before rendering quantiles."""
        out = LatencyHistogram.__new__(LatencyHistogram)
        out.edges = self.edges
        out.counts = self.counts.copy()
        out.total = self.total
        out.n = self.n
        out.max = self.max
        return out

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other``'s samples into this histogram in place (bucket
        layouts must match).  Merging is exact — bucket counts add — so
        it is associative and commutative: aggregating per-shard
        histograms in any order yields identical buckets and quantiles."""
        if self.edges.shape != other.edges.shape \
                or not np.array_equal(self.edges, other.edges):
            raise ValueError("cannot merge histograms with different "
                             "bucket edges")
        self.counts += other.counts
        self.total += other.total
        self.n += other.n
        if other.max > self.max:
            self.max = other.max
        return self

    def buckets(self) -> list[tuple[float, int]]:
        """Cumulative bucket counts, Prometheus-style: ``[(upper_edge_s,
        count_le), ...]`` ending with ``(inf, n)`` — each count is the
        number of samples <= that edge, monotone non-decreasing."""
        cum = np.cumsum(self.counts)
        out = [(float(e), int(c)) for e, c in zip(self.edges, cum[:-1])]
        out.append((float("inf"), int(cum[-1])))
        return out

    def snapshot(self) -> dict:
        return {"n": int(self.n), "mean_ms": self.mean * 1e3,
                "p50_ms": self.quantile(0.50) * 1e3,
                "p99_ms": self.quantile(0.99) * 1e3,
                "max_ms": self.max * 1e3}


STAGES = ("route", "partition", "score", "build", "execute", "retry",
          "warm", "step")


class RouteCalibration:
    """Per-platform online calibration of predicted cost vs observed latency.

    The cost model emits a unitless *rank score* per (pattern, config) —
    comparable within one platform's config space, but not across platforms
    and not in seconds.  Calibration closes that gap online: every served
    route contributes its observed per-request latency (milliseconds, EMA
    ``observed_ms`` — the engine feeds steady-state build+execute time,
    deliberately excluding one-time tuning cost, which would otherwise be
    charged to whichever backend just received fresh patterns), and every
    cost-model route also contributes the *raw* uncalibrated score the
    router predicted (EMA ``predicted``).  ``offset(platform)`` is then

        offset = EMA[observed_ms] - EMA[predicted_score]

    so a router computing ``score + offset`` gets a quantity that converges
    to the backend's observed latency scale (the score's platform-mean
    cancels) while per-pattern score deviations still break ties.  Platforms
    with no learned score (predicted 0) calibrate to their raw observed
    latency.  ``offset`` returns ``None`` until the platform has been
    observed — the policy layer decides the cold-start prior.

    Thread-safe; one instance lives on ``EngineTelemetry.calibration``.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self._lock = threading.Lock()
        self._by_platform: dict[str, dict] = {}
        self._by_op: dict[tuple[str, str], dict] = {}

    def _fold(self, c: dict, observed_s: float,
              predicted: float | None) -> None:
        a = self.alpha
        ms = observed_s * 1e3
        # Drift: EMA of |observed - calibrated expectation| *before* this
        # sample folds in.  For samples carrying a model prediction the
        # expectation is predicted + current offset (the calibrated cost
        # the router actually compared); for prediction-less samples it
        # degenerates to the observed EMA itself.  A stable workload keeps
        # drift near its noise floor; a backend whose latency regime moved
        # (thermal throttle, contention, model gone stale) pushes it up —
        # the re-routing trigger ROADMAP item 4 consumes.
        if c["n"]:
            if predicted is not None and c["n_pred"]:
                expected = float(predicted) \
                    + (c["observed_ms"] - c["predicted"])
            else:
                expected = c["observed_ms"]
            resid = abs(ms - expected)
            c["drift_ms"] = resid if c["n_drift"] == 0 \
                else (1 - a) * c["drift_ms"] + a * resid
            c["n_drift"] += 1
        c["observed_ms"] = ms if c["n"] == 0 \
            else (1 - a) * c["observed_ms"] + a * ms
        c["n"] += 1
        if predicted is not None:
            p = float(predicted)
            c["predicted"] = p if c["n_pred"] == 0 \
                else (1 - a) * c["predicted"] + a * p
            c["n_pred"] += 1

    @staticmethod
    def _fresh() -> dict:
        return {"n": 0, "observed_ms": 0.0, "n_pred": 0, "predicted": 0.0,
                "n_drift": 0, "drift_ms": 0.0}

    def observe(self, platform: str, observed_s: float,
                predicted: float | None = None, op: str | None = None) -> None:
        """Fold one served request: observed serve latency, and the routing
        score that predicted it (``None`` for routes made without one).
        With ``op`` given, the sample also feeds the finer ``(platform,
        op)`` ledger routers prefer when deciding per-op placement; the
        per-platform aggregate is maintained either way."""
        with self._lock:
            c = self._by_platform.setdefault(platform, self._fresh())
            self._fold(c, observed_s, predicted)
            if op is not None:
                co = self._by_op.setdefault((platform, op), self._fresh())
                self._fold(co, observed_s, predicted)

    def n_observed(self, platform: str, op: str | None = None) -> int:
        with self._lock:
            c = self._by_op.get((platform, op)) if op is not None \
                else self._by_platform.get(platform)
            return c["n"] if c else 0

    def offset(self, platform: str,
               op: str | None = None) -> float | None:
        """Additive score correction for ``platform``; ``None`` until it has
        been observed at least once.  With ``op`` given, the per-``(platform,
        op)`` offset when that pair has been observed, falling back to the
        platform aggregate (a new op on a measured platform starts from the
        platform's latency scale instead of cold)."""
        with self._lock:
            if op is not None:
                co = self._by_op.get((platform, op))
                if co is not None and co["n"]:
                    return co["observed_ms"] - co["predicted"]
            c = self._by_platform.get(platform)
            if c is None or c["n"] == 0:
                return None
            return c["observed_ms"] - c["predicted"]

    def drift(self, platform: str, op: str | None = None) -> float | None:
        """Calibration-drift gauge: EMA of the absolute residual between
        each observed latency and the calibrated expectation current when
        it arrived (milliseconds).  With ``op``, the per-``(platform,
        op)`` gauge, falling back to the platform aggregate; ``None``
        until at least two samples (one to set the expectation, one to
        measure against it)."""
        with self._lock:
            if op is not None:
                co = self._by_op.get((platform, op))
                if co is not None and co["n_drift"]:
                    return co["drift_ms"]
            c = self._by_platform.get(platform)
            if c is None or c["n_drift"] == 0:
                return None
            return c["drift_ms"]

    @staticmethod
    def _render(c: dict) -> dict:
        return {"n": c["n"], "observed_ms": c["observed_ms"],
                "predicted": c["predicted"],
                "offset": c["observed_ms"] - c["predicted"],
                "drift_ms": c["drift_ms"]}

    def snapshot(self) -> dict:
        """Per-platform aggregate view (the pre-per-op shape, unchanged),
        with per-op detail nested under each platform's ``"by_op"`` key."""
        with self._lock:
            out = {plat: self._render(c)
                   for plat, c in self._by_platform.items() if c["n"]}
            for (plat, op), c in self._by_op.items():
                if c["n"] and plat in out:
                    out[plat].setdefault("by_op", {})[op] = self._render(c)
            return out


class EngineTelemetry:
    """Counters + per-stage latency histograms for one engine."""

    def __init__(self):
        self._lock = threading.Lock()
        self.stages = {name: LatencyHistogram() for name in STAGES}
        self.requests = 0
        self.batches = 0
        self.hits = 0
        self.misses = 0
        self.score_dispatches = 0       # batched featurize+score round-trips
        self.arena_fallbacks = 0        # builds that couldn't get a slot
        self.device_builds = 0          # jitted device-scatter builds
        self.host_builds = 0            # numpy host-scatter builds
        self.fused_builds = 0           # zero-copy aligned-slot warm builds
        self.overlapped_builds = 0      # builds issued over an in-flight batch
        self.drain_waits = 0            # drain() calls that really had to wait
        self.warm_start_entries = 0     # cache entries restored from disk
        self.warm_start_skipped = 0     # persisted entries no backend claimed
        self.persist_saves = 0
        self.persist_saved_entries = 0  # cache entries written by saves
        self.persist_load_failures = 0  # corrupted/absent files -> cold start
        self.persist_quarantined = 0    # corrupt cache files renamed .corrupt
        self.execute_failures = 0       # executor raised (per request)
        self.output_guard_failures = 0  # opt-in NaN/inf/shape guard trips
        self.circuit_fast_fails = 0     # requests rerouted off an open circuit
        self.failovers = 0              # requests re-served via the retry lane
        self.retry_failures = 0         # retry-lane executions that also failed
        self.backends: dict = {}        # "platform/op" -> per-backend stats
        self.route_reasons: dict = {}   # reason -> requests routed that way
        self.route_platforms: dict = {} # platform -> requests routed to it
        self.route_config_installs = 0  # routing config hints installed
        self.warm_steps = 0             # steps with a warm-lane subset
        self.warm_requests = 0          # requests served through the lane
        self.warm_sampled_steps = 0     # warm steps with full per-request
                                        # telemetry (the counter sampler)
        self.warm_fallthroughs = 0      # warm candidates sent to the staged
                                        # pipeline (breaker/drift/saturation)
        self.warm_invalidations = 0     # warm entries dropped on a health-
                                        # generation change (sticky analogue)
        self.deadline_expired = 0       # requests completed deadline_exceeded
                                        # by the engine's stage gates
        self.retry_deadline_exhausted = 0  # failed requests whose budget ran
                                           # out before the retry lane
        self.calibration = RouteCalibration()

    def record_stage(self, name: str, seconds: float) -> None:
        with self._lock:
            self.stages[name].record(seconds)

    def record_route(self, platform: str, reason: str, n: int = 1) -> None:
        """Count ``n`` requests routed to ``platform`` because ``reason``
        (``explicit`` / ``default`` / ``cost_model`` / ``sticky`` /
        ``spill`` / ``explore`` — whatever the active router reports)."""
        with self._lock:
            self.route_reasons[reason] = self.route_reasons.get(reason, 0) + n
            self.route_platforms[platform] = \
                self.route_platforms.get(platform, 0) + n

    def record_backend(self, tag: str, *, requests: int = 0, hits: int = 0,
                       misses: int = 0, seconds: float | None = None) -> None:
        """Fold one step's serve accounting for backend ``tag`` (a
        ``"platform/op"`` string): request/hit/miss deltas plus the wall
        time the engine spent scoring+building+executing that backend's
        partition this step (one histogram sample per step per backend)."""
        with self._lock:
            b = self.backends.get(tag)
            if b is None:
                b = self.backends[tag] = {"requests": 0, "hits": 0,
                                          "misses": 0,
                                          "serve": LatencyHistogram()}
            b["requests"] += requests
            b["hits"] += hits
            b["misses"] += misses
            if seconds is not None:
                b["serve"].record(seconds)

    def count(self, **deltas: int) -> None:
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def stage_histograms(self) -> dict:
        """Point-in-time copies of every stage histogram (name -> copy) —
        bucket counts duplicated under the lock, safe to render (cumsum,
        quantiles, Prometheus buckets) without holding it."""
        with self._lock:
            return {name: h.copy() for name, h in self.stages.items()}

    def backend_serve_histograms(self) -> dict:
        """Point-in-time copies of every backend serve histogram
        (``"platform/op"`` tag -> copy), same contract as
        ``stage_histograms``."""
        with self._lock:
            return {tag: b["serve"].copy()
                    for tag, b in self.backends.items()}

    def snapshot(self, cache=None, evictions: int | None = None) -> dict:
        """Everything ``SparseKernelEngine.stats()`` renders.  Pass the
        engine's ``AutotuneCache`` to fold in its counters.

        Lock discipline: scalar counters and histogram *bucket counts*
        are copied under the telemetry lock, but all histogram rendering
        (one cumsum per quantile per histogram) happens after it is
        released — a concurrent ``stats()`` poll costs ``step()``
        accounting a dict copy, never a render."""
        with self._lock:
            served = self.hits + self.misses
            stage_copies = {k: h.copy() for k, h in self.stages.items()}
            backend_copies = {
                tag: (b["requests"], b["hits"], b["misses"],
                      b["serve"].copy())
                for tag, b in self.backends.items()}
            out = {
                "requests": self.requests,
                "batches": self.batches,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / served if served else 0.0,
                "score_dispatches": self.score_dispatches,
                "arena_fallbacks": self.arena_fallbacks,
                "build_paths": {
                    "device": self.device_builds,
                    "host": self.host_builds,
                    "fused": self.fused_builds,
                    "overlapped": self.overlapped_builds,
                    "overlap_ratio": (
                        self.overlapped_builds
                        / (self.device_builds + self.host_builds)
                        if self.device_builds + self.host_builds else 0.0),
                    "drain_waits": self.drain_waits,
                },
                "warm_lane": {
                    "steps": self.warm_steps,
                    "requests": self.warm_requests,
                    "sampled_steps": self.warm_sampled_steps,
                    "fallthroughs": self.warm_fallthroughs,
                    "invalidations": self.warm_invalidations,
                    "fused_builds": self.fused_builds,
                },
                "deadlines": {
                    "expired": self.deadline_expired,
                    "retry_exhausted": self.retry_deadline_exhausted,
                },
                "warm_start_entries": self.warm_start_entries,
                "warm_start_skipped": self.warm_start_skipped,
                "persist_saves": self.persist_saves,
                "persist_saved_entries": self.persist_saved_entries,
                "persist_load_failures": self.persist_load_failures,
                "persist_quarantined": self.persist_quarantined,
                "routing": {
                    "decisions": dict(self.route_reasons),
                    "by_platform": dict(self.route_platforms),
                    "spills": self.route_reasons.get("spill", 0),
                    "config_installs": self.route_config_installs,
                },
            }
        # rendering (one cumsum per quantile) runs outside the lock
        out["stages"] = {k: h.snapshot() for k, h in stage_copies.items()}
        out["backends"] = {
            tag: {"requests": reqs, "hits": hits, "misses": misses,
                  "hit_rate": (hits / (hits + misses)
                               if hits + misses else 0.0),
                  "serve": serve.snapshot()}
            for tag, (reqs, hits, misses, serve) in backend_copies.items()}
        out["routing"]["calibration"] = self.calibration.snapshot()
        if cache is not None:
            out["cache"] = {"size": len(cache), "hits": cache.hits,
                            "misses": cache.misses,
                            "evictions": cache.evictions}
        if evictions is not None:
            out.setdefault("cache", {})["evictions"] = evictions
        return out
