"""Backend health tracking — rolling outcome windows + circuit breakers.

COGNATE serves sparse kernels on *early-stage* hardware (PAPER.md), where
executors OOM, compiles fail, and latency spikes are routine — the exact
setting TLP and "Learning to Optimize Tensor Programs" assume when they
build measurement noise and hardware faults into their tuning loops.  This
module gives the serving stack the matching failure model:

``BackendHealth`` — one per ``(platform, op)`` tag — keeps a rolling
success/failure window, a latency EMA, and a three-state **circuit
breaker**:

* **closed** — the healthy steady state; every dispatch is admitted.
* **open** — entered when the rolling failure rate crosses
  ``failure_threshold`` (over at least ``min_samples`` outcomes) *or*
  ``consecutive_errors`` dispatches fail back to back.  While open, the
  engine fast-fails the backend's traffic into the failover lane without
  touching the executor — a dead backend costs a dict lookup, not a
  timeout.
* **half_open** — after the open backoff elapses, exactly one *probe*
  admission is granted.  A successful probe closes the breaker (and
  resets the backoff and the failure window — stale failures must not
  immediately re-trip it); a failed probe reopens it with the backoff
  escalated by ``backoff_factor`` (capped at ``max_backoff_s``), so a
  still-dead backend is probed at a decaying rate instead of hammered.

``HealthRegistry`` owns the per-tag breakers behind one lock and is what
the engine, the routers (via ``RoutingContext.health``), and
``stats()["health"]`` consult.  It is deterministic under test: inject a
fake ``clock`` (any ``() -> float`` monotonic source) and breaker
transitions become a pure function of recorded outcomes and clock reads.

Every state change bumps the tag's ``transitions`` counter;
``generation(platform)`` sums them per platform, which is how
``CostModelRouter`` invalidates sticky routing memos the moment a
backend's health changes (in either direction) — a memoized pick is only
as durable as the health snapshot it was made under.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

__all__ = ["CLOSED", "OPEN", "HALF_OPEN", "HealthConfig", "BackendHealth",
           "HealthRegistry"]

#: Circuit-breaker states (plain strings so they render in ``stats()``).
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Breaker thresholds and backoff schedule (shared by every tag).

    Args:
        window: rolling outcome window per tag — the failure *rate* is
            measured over the last ``window`` dispatches only, so a
            backend's ancient history can't keep a breaker open.
        failure_threshold: open when the window failure rate reaches this
            (and the window holds at least ``min_samples`` outcomes).
        min_samples: outcomes required before the rate can trip the
            breaker — one early failure on a cold backend is not a signal.
        consecutive_errors: open immediately after this many back-to-back
            failures, regardless of the windowed rate (hard-down detection
            for a backend that was healthy until just now).
        backoff_s: initial open -> half-open delay.
        backoff_factor: multiplier applied on every *failed* probe.
        max_backoff_s: escalation cap.
        latency_alpha: EMA coefficient for the per-tag latency ledger.
    """
    window: int = 32
    failure_threshold: float = 0.5
    min_samples: int = 4
    consecutive_errors: int = 3
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 30.0
    latency_alpha: float = 0.2


class BackendHealth:
    """Rolling health of one ``(platform, op)`` tag + its circuit breaker.

    Not locked itself — every mutation goes through the owning
    ``HealthRegistry``'s lock.
    """

    def __init__(self, config: HealthConfig):
        self.config = config
        self.state = CLOSED
        self.outcomes: deque = deque(maxlen=config.window)  # True = success
        self.consecutive_failures = 0
        self.latency_ms = 0.0           # EMA of successful serve latency
        self.successes = 0
        self.failures = 0
        self.opens = 0                  # closed/half_open -> open trips
        self.probes = 0                 # half-open admissions granted
        self.probe_successes = 0
        self.probe_failures = 0
        self.transitions = 0            # every state change (any direction)
        self._opened_at = 0.0
        self._backoff = config.backoff_s
        self._probe_inflight = False

    # Registry-internal helpers (caller holds the registry lock).

    def _set_state(self, state: str) -> None:
        if state != self.state:
            self.state = state
            self.transitions += 1

    def _tripped(self) -> bool:
        if self.consecutive_failures >= self.config.consecutive_errors:
            return True
        n = len(self.outcomes)
        return (n >= self.config.min_samples
                and self.failure_rate() >= self.config.failure_threshold)

    def failure_rate(self) -> float:
        """Failure fraction over the rolling window (0.0 when empty)."""
        n = len(self.outcomes)
        return (sum(1 for ok in self.outcomes if not ok) / n) if n else 0.0

    def snapshot(self) -> dict:
        return {"state": self.state,
                "successes": self.successes, "failures": self.failures,
                "failure_rate": self.failure_rate(),
                "consecutive_failures": self.consecutive_failures,
                "latency_ms": self.latency_ms,
                "opens": self.opens, "probes": self.probes,
                "probe_successes": self.probe_successes,
                "probe_failures": self.probe_failures,
                "transitions": self.transitions,
                "backoff_s": self._backoff}


class HealthRegistry:
    """Per-``(platform, op)`` breakers behind one lock.

    Args:
        config: shared ``HealthConfig`` (default thresholds).
        clock: monotonic time source — injectable so tests drive breaker
            timing deterministically (``time.monotonic`` by default).

    The admission protocol the engine follows per step and tag:
    ``allow(tag)`` — ``True`` admits the dispatch (closed breaker, or the
    one half-open probe); ``False`` means fast-fail into the failover
    lane.  Outcomes feed back through ``record_success(tag, latency_s)``
    / ``record_failure(tag)``.  A granted probe whose partition turns out
    to have nothing to execute is returned via ``cancel_probe(tag)`` so
    the next step can claim it.
    """

    def __init__(self, config: HealthConfig | None = None,
                 clock=time.monotonic):
        self.config = config or HealthConfig()
        self.clock = clock
        self._lock = threading.Lock()
        self._by_tag: dict[tuple[str, str], BackendHealth] = {}
        #: Transition listeners: callables receiving one dict per breaker
        #: state change (``tag``/``from``/``to``/``failure_rate``/
        #: ``backoff_s``).  Append, don't replace — a registry may be
        #: shared across engines, each observing it.  Listeners fire
        #: *after* the registry lock is released (a listener may call
        #: back into the registry without deadlocking); exceptions are
        #: swallowed — observability must never fail serving.
        self.listeners: list = []

    def _of(self, tag) -> BackendHealth:
        tag = tuple(tag)
        h = self._by_tag.get(tag)
        if h is None:
            h = self._by_tag[tag] = BackendHealth(self.config)
        return h

    def _transition_event(self, tag, old: str, h: BackendHealth) -> dict:
        """Snapshot a just-made transition (caller holds the lock)."""
        return {"tag": f"{tag[0]}/{tag[1]}", "from": old, "to": h.state,
                "failure_rate": h.failure_rate(), "backoff_s": h._backoff,
                "transitions": h.transitions}

    def _notify(self, events: list[dict]) -> None:
        """Fire transition listeners (caller has released the lock)."""
        for ev in events:
            for fn in list(self.listeners):
                try:
                    fn(ev)
                except Exception:
                    pass

    # ------------------------------------------------------------ admission

    def allow(self, tag) -> bool:
        """Admit one dispatch to ``tag``?  Closed: always.  Open: ``False``
        until the backoff elapses, then the breaker moves to half-open and
        this call *is* the probe grant.  Half-open: one probe at a time."""
        notes: list[dict] = []
        try:
            with self._lock:
                h = self._of(tag)
                if h.state == CLOSED:
                    return True
                if h.state == OPEN:
                    if self.clock() - h._opened_at < h._backoff:
                        return False
                    h._set_state(HALF_OPEN)
                    notes.append(self._transition_event(tuple(tag), OPEN, h))
                # half-open: grant a single outstanding probe
                if h._probe_inflight:
                    return False
                h._probe_inflight = True
                h.probes += 1
                return True
        finally:
            self._notify(notes)

    def cancel_probe(self, tag) -> None:
        """Return an unused probe grant (the admitted partition had nothing
        to execute, so no outcome will ever be recorded for it)."""
        with self._lock:
            h = self._of(tag)
            if h.state == HALF_OPEN and h._probe_inflight:
                h._probe_inflight = False
                h.probes -= 1

    def routable(self, tag) -> bool:
        """Whether a router should consider ``tag`` a live candidate:
        ``False`` only while the breaker is open *and* its backoff has not
        elapsed.  A probe-due open breaker (and half-open) stays routable —
        filtering it out entirely would starve the probe that lets the
        backend recover."""
        with self._lock:
            h = self._by_tag.get(tuple(tag))
            if h is None or h.state != OPEN:
                return True
            return self.clock() - h._opened_at >= h._backoff

    # ------------------------------------------------------------- outcomes

    def record_success(self, tag, latency_s: float = 0.0) -> None:
        notes: list[dict] = []
        with self._lock:
            h = self._of(tag)
            h.successes += 1
            h.outcomes.append(True)
            h.consecutive_failures = 0
            a = self.config.latency_alpha
            ms = latency_s * 1e3
            h.latency_ms = ms if h.successes == 1 \
                else (1 - a) * h.latency_ms + a * ms
            if h.state == HALF_OPEN:
                # probe succeeded: close, reset the escalation, and clear
                # the window — stale failures must not instantly re-trip
                h.probe_successes += 1
                h._probe_inflight = False
                h._backoff = self.config.backoff_s
                h.outcomes.clear()
                h._set_state(CLOSED)
                notes.append(self._transition_event(tuple(tag),
                                                    HALF_OPEN, h))
            # a straggler completing after the breaker opened is counted
            # but is NOT a probe — only half-open successes close
        self._notify(notes)

    def record_successes(self, tag, n: int, latency_s: float = 0.0) -> None:
        """Fold ``n`` identical successes at ``latency_s`` each into
        ``tag``'s health in one lock acquisition — semantically equivalent
        to ``n`` ``record_success`` calls (closed-form EMA: ``n`` steps
        toward the same sample collapse to ``(1-a)^n``), which is what the
        engine's warm lane uses to keep breaker accounting exact without
        paying a lock round-trip per request."""
        if n <= 0:
            return
        notes: list[dict] = []
        with self._lock:
            h = self._of(tag)
            first = h.successes == 0
            h.successes += n
            h.consecutive_failures = 0
            a = self.config.latency_alpha
            ms = latency_s * 1e3
            h.latency_ms = ms if first \
                else (1 - a) ** n * h.latency_ms \
                + (1 - (1 - a) ** n) * ms
            # the outcomes window is bounded — extending past its maxlen
            # just churns; cap the append at the window size
            cap = h.outcomes.maxlen or n
            if h.state == HALF_OPEN:
                # the first success is the probe: close and clear the
                # window; the remaining n-1 land in the fresh window —
                # same end state as n sequential record_success calls
                h.probe_successes += 1
                h._probe_inflight = False
                h._backoff = self.config.backoff_s
                h.outcomes.clear()
                h._set_state(CLOSED)
                notes.append(self._transition_event(tuple(tag),
                                                    HALF_OPEN, h))
                h.outcomes.extend([True] * min(n - 1, cap))
            else:
                h.outcomes.extend([True] * min(n, cap))
        self._notify(notes)

    def record_failure(self, tag) -> None:
        notes: list[dict] = []
        with self._lock:
            h = self._of(tag)
            h.failures += 1
            h.outcomes.append(False)
            h.consecutive_failures += 1
            if h.state == HALF_OPEN:
                # failed probe: reopen with the backoff escalated
                h.probe_failures += 1
                h._probe_inflight = False
                h._backoff = min(h._backoff * self.config.backoff_factor,
                                 self.config.max_backoff_s)
                h._opened_at = self.clock()
                h.opens += 1
                h._set_state(OPEN)
                notes.append(self._transition_event(tuple(tag),
                                                    HALF_OPEN, h))
            elif h.state == CLOSED and h._tripped():
                h._backoff = self.config.backoff_s
                h._opened_at = self.clock()
                h.opens += 1
                h._set_state(OPEN)
                notes.append(self._transition_event(tuple(tag), CLOSED, h))
        self._notify(notes)

    # ---------------------------------------------------------- observation

    def state(self, tag) -> str:
        """Current breaker state (no side effects, no transitions)."""
        with self._lock:
            h = self._by_tag.get(tuple(tag))
            return h.state if h is not None else CLOSED

    def failure_rate(self, tag) -> float:
        """Rolling-window failure rate for ``tag`` (0.0 if never seen) —
        the "healthiest surviving candidate" ordering key."""
        with self._lock:
            h = self._by_tag.get(tuple(tag))
            return h.failure_rate() if h is not None else 0.0

    def generation(self, platform: str) -> int:
        """Sum of breaker transitions across the platform's tags — the
        invalidation token health-aware memoization (sticky routing) keys
        on: any state change, in either direction, bumps it."""
        with self._lock:
            return sum(h.transitions for (p, _), h in self._by_tag.items()
                       if p == platform)

    def snapshot(self) -> dict:
        """``"platform/op" -> breaker stats`` — what
        ``SparseKernelEngine.stats()["health"]["breakers"]`` renders."""
        with self._lock:
            return {f"{p}/{op}": h.snapshot()
                    for (p, op), h in sorted(self._by_tag.items())}
