"""Sharded multi-replica serving — horizontal scale for the digest space.

A single ``SparseKernelEngine`` tops out at one host's cache capacity and
one warm lane's throughput.  ``ShardedEngine`` fronts N engine replicas
behind a **consistent-hash ring keyed on pattern digest**, so cache
capacity, autotune throughput, and build bandwidth all scale with replica
count while each digest keeps landing on the replica that already holds
its tuned entry, warm-lane decision, and arena buffers:

``HashRing``
    Deterministic consistent hashing (blake2b) with virtual nodes for
    balance.  Stability is the whole point: removing one of N nodes
    re-homes *only* that node's keys (to their ring successors — ~1/N of
    the space), and re-adding it restores the original assignment bit for
    bit, because ring points depend only on ``(node, vnode)`` — never on
    membership history.

``ShardedEngine.step(requests)``
    Slots in as a router *above* the engine's ``step()`` seam: the batch
    is digested once (identity-memoized, same trick as the engine's),
    partitioned by ring owner, and each sub-batch is served by its
    replica — staged pipeline, warm lane, circuit breakers, retry lane,
    and tracing all inherited unchanged.  Responses reassemble in request
    order.  **Bounded-load overflow**: when a replica's shard-level
    ``BackendLoad`` sits at ``max_inflight``, the request routes to its
    ring *successor* instead (counted in ``stats()["routing"]
    ["overflows"]``); if the successor is saturated too the owner serves
    it anyway — the ring degrades to plain consistent hashing and never
    drops a request.

    Each replica is served by its **own dedicated worker thread**: the
    engine's double-buffer lease protocol is per calling thread, so
    pinning one serving stream per replica preserves the two-generation
    run-ahead exactly as if each replica were driven by its own process.
    (``parallel=False`` serves sub-batches inline in the caller's thread
    — each engine still sees a single consistent stream.)

**Device placement.**  Replicas place their work over an honest
multi-device mesh: pass ``mesh=make_host_mesh()`` (``repro.launch.mesh``)
— stood up under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
this is 8 real XLA devices on one CPU host — and each replica executes
under ``jax.default_device(dev)`` for its ``parallel.sharding.
replica_devices`` slot (replica i -> data-slice i, round-robin when
replicas outnumber slices).

**Warm-start merge.**  ``ShardedEngine(persist_path=...)`` restores one
namespaced cache file (any engine's — or a previous shard layout's merged
``save()``) and routes every entry to its ring owner, so N replicas
warm-start from a single file regardless of who wrote it.  ``save()`` is
the inverse: every replica's caches merge (per-platform, digest-deduped)
into one atomically-committed file a future layout can re-split.

**Rebalance.**  ``add_replica()`` / ``remove_replica(rid)`` re-home *only*
the digests whose ring ownership actually moved (the consistent-hashing
guarantee): the source replica's caches round-trip through
``persist.save_backends``/``load_grouped`` — the same validated,
CRC-checked namespace view the warm-start path uses — and each moved
entry's autotune cache row is installed in its new owner's backend (the
source row is popped) with the dest arena prebuilt, so surviving replicas
never go cold and the moved digests' first post-rebalance request is a
cache hit, not a featurization.  Removal quiesces the leaving replica
first (ring exit -> queued work drains -> migrate -> teardown): requests
already assigned to it still complete — zero lost requests — and
everything it learned moves to the survivors.

**Supervision.**  Every replica's serving thread stamps a heartbeat
around each call (``busy_since`` marks a call in flight), and a
``ReplicaSupervisor`` — the PR-6 circuit-breaker state machine lifted to
replica granularity — watches them: a replica whose thread has been busy
past ``hang_timeout_s`` is **quarantined** (breaker *open*): evicted from
the ring, its warm state re-homed to the survivors through the same
migration path a ``remove_replica`` uses, while its thread is left alone
(it may still wake up).  After ``probation_s`` the supervisor **probes**
the thread (*half-open*); a responsive replica is re-admitted — ring
re-entry plus warm state migrating back (*closed*).  ``step()`` itself
failover-guards dispatch: a sub-batch whose future times out
(``step_timeout_s``) or dies with ``ReplicaCrash`` quarantines the
replica and **re-dispatches through the survivors**, so a hung or
crashed replica costs latency, never lost requests.  The watchdog runs
on its own thread (``supervise=True``) or deterministically via
``supervisor.poll_once()`` with an injected clock.

**Observability.**  ``stats()`` aggregates across replicas (plus a
``"by_shard"`` section of full per-replica snapshots, shard-router
counters, and the supervisor's state/heartbeat view);
``prometheus_text()`` concatenates every replica's exposition with a
``shard="<rid>"`` label stamped on *every* series (the
``export.prometheus_text(labels=...)`` hook) plus shard-router and
supervisor series, so one scrape shows the whole fleet without series
collisions.
"""
from __future__ import annotations

import bisect
import hashlib
import shutil
import tempfile
import threading
import time
import weakref
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from pathlib import Path

import jax

from repro.serving.engine import SparseKernelEngine
from repro.serving.export import _Writer, prometheus_text
from repro.serving.faults import ReplicaCrash
from repro.serving.persist import (LEGACY_NAMESPACE, load_grouped,
                                   save_backends)
from repro.serving.trace import EventLog

__all__ = ["HashRing", "ShardedEngine", "ReplicaSupervisor"]


class HashRing:
    """Consistent-hash ring with virtual nodes.

    ``vnodes`` points per node are placed at
    ``blake2b(f"{node}#{i}")`` positions on a 64-bit ring; a key is owned
    by the first point clockwise of ``blake2b(key)``.  Placement depends
    only on the node name, so membership changes move the minimum key
    range: ``remove(n)`` re-homes exactly the keys ``n`` owned (to their
    successors), and a later ``add(n)`` puts every one of them back.
    """

    def __init__(self, nodes=(), vnodes: int = 64):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = int(vnodes)
        self._points: list[tuple[int, str]] = []    # sorted (hash, node)
        self._nodes: set[str] = set()
        for node in nodes:
            self.add(node)

    @staticmethod
    def _hash(key: str) -> int:
        return int.from_bytes(
            hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    def nodes(self) -> list[str]:
        return sorted(self._nodes)

    def add(self, node: str) -> None:
        if node in self._nodes:
            raise ValueError(f"node {node!r} already on the ring")
        self._nodes.add(node)
        for v in range(self.vnodes):
            bisect.insort(self._points, (self._hash(f"{node}#{v}"), node))

    def remove(self, node: str) -> None:
        if node not in self._nodes:
            raise KeyError(f"node {node!r} not on the ring")
        self._nodes.discard(node)
        self._points = [p for p in self._points if p[1] != node]

    def _index(self, key: str) -> int:
        # ("" sorts before any node name, so a key whose hash collides
        # with a ring point maps to that point — deterministically)
        i = bisect.bisect_left(self._points, (self._hash(key), ""))
        return 0 if i == len(self._points) else i

    def owner(self, key: str) -> str:
        """The node owning ``key`` (first ring point clockwise)."""
        if not self._points:
            raise KeyError("ring is empty")
        return self._points[self._index(key)][1]

    def successor(self, key: str) -> str | None:
        """The first *distinct* node clockwise of ``key``'s owner — the
        bounded-load overflow target.  ``None`` on a single-node ring."""
        if len(self._nodes) < 2:
            return None
        pts = self._points
        i = self._index(key)
        own = pts[i][1]
        for j in range(1, len(pts)):
            node = pts[(i + j) % len(pts)][1]
            if node != own:
                return node
        return None

    def assignment(self, keys) -> dict[str, str]:
        """``{key: owner}`` for a batch of keys — what the stability
        property tests compare across membership changes."""
        return {k: self.owner(k) for k in keys}


class _MergedEntries:
    """Digest-deduped ``{key: entry}`` with the ``.items()`` face
    ``persist.save_backends`` serializes (last writer wins, like a load)."""

    def __init__(self):
        self._d: dict = {}

    def put(self, key, entry) -> None:
        self._d[key] = entry

    def __len__(self) -> int:
        return len(self._d)

    def items(self) -> list[tuple]:
        return list(self._d.items())


class _Replica:
    """One engine replica: its id, engine, placement device, shard-level
    load counter, heartbeat, and (in parallel mode) its dedicated serving
    thread."""

    def __init__(self, rid: str, engine: SparseKernelEngine, device,
                 parallel: bool, clock=time.monotonic):
        from repro.serving.backends import BackendLoad
        self.rid = rid
        self.engine = engine
        self.device = device
        self.load = BackendLoad()
        self._clock = clock
        self._hb_lock = threading.Lock()
        # stamped by the serving thread around every call it runs: a
        # heartbeat that stops advancing while busy_since stays set is a
        # hung thread — the supervisor's detection signal
        self.heartbeat_ts = clock()
        self.busy_since: float | None = None
        self.pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"shard-{rid}") \
            if parallel else None

    def heartbeat(self) -> tuple[float, float | None]:
        with self._hb_lock:
            return self.heartbeat_ts, self.busy_since

    def run(self, fn, *args):
        """Run ``fn`` on this replica's serving thread (inline when
        ``parallel=False``) under its placement device."""
        if self.pool is None:
            return self._placed(fn, *args)
        return self.pool.submit(self._placed, fn, *args).result()

    def submit(self, fn, *args):
        assert self.pool is not None
        return self.pool.submit(self._placed, fn, *args)

    def _placed(self, fn, *args):
        now = self._clock()
        with self._hb_lock:
            self.heartbeat_ts = now
            self.busy_since = now
        try:
            if self.device is not None:
                with jax.default_device(self.device):
                    return fn(*args)
            return fn(*args)
        finally:
            now = self._clock()
            with self._hb_lock:
                self.heartbeat_ts = now
                self.busy_since = None


class ReplicaSupervisor:
    """Replica-granularity circuit breaker: watch heartbeats, quarantine
    hung replicas, probe, re-admit.

    States mirror the PR-6 breaker vocabulary — ``live`` (closed),
    ``quarantined`` (open: off the ring, warm state re-homed to the
    survivors), probe (half-open: after ``probation_s`` the supervisor
    submits a no-op to the replica's serving thread with a short
    timeout), and back to ``live`` on a responsive probe (ring re-entry +
    warm state migrated back).  A failed probe restarts probation.

    ``poll_once()`` is the whole state machine, driven either by the
    watchdog thread (``start()`` / ``ShardedEngine(supervise=True)``) or
    directly by a test with an injected ``clock`` — hang detection
    compares the fake clock against ``busy_since``, so a hang injected
    with ``FaultPlan.hang_calls`` quarantines deterministically without
    real-time sleeps.  ``quarantine()`` is also the entry point
    ``ShardedEngine.step()``'s failover uses on a step timeout or
    ``ReplicaCrash``.  The last ring node is never quarantined (bounded
    degradation beats an empty fleet); the refusal is an event.
    """

    def __init__(self, shard: "ShardedEngine", *, hang_timeout_s: float = 2.0,
                 probation_s: float = 5.0, interval_s: float = 0.25,
                 probe_timeout_s: float = 0.5, clock=time.monotonic):
        self._shard = shard
        self.hang_timeout_s = float(hang_timeout_s)
        self.probation_s = float(probation_s)
        self.interval_s = float(interval_s)
        self.probe_timeout_s = float(probe_timeout_s)
        self.clock = clock
        self.events = EventLog(capacity=256)
        self._lock = threading.Lock()
        # rid -> {"state": "quarantined", "since": ts, "reason": str};
        # absent = live
        self._quarantined: dict[str, dict] = {}
        self.counters = {"hangs_detected": 0, "quarantines": 0,
                         "failed_probes": 0, "readmissions": 0}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def state(self, rid: str) -> str:
        with self._lock:
            return "quarantined" if rid in self._quarantined else "live"

    # ------------------------------------------------------- state machine

    def poll_once(self) -> int:
        """One watchdog pass: detect hangs, probe expired probations.
        Returns the number of state transitions taken."""
        now = self.clock()
        sh = self._shard
        with sh._lock:
            reps = list(sh._replicas.items())
            on_ring = set(sh._ring.nodes())
        acted = 0
        for rid, rep in reps:
            if self.state(rid) == "live":
                if rid not in on_ring:
                    continue            # mid-rebalance; not ours to touch
                _hb, busy = rep.heartbeat()
                if busy is not None and now - busy >= self.hang_timeout_s:
                    with self._lock:
                        self.counters["hangs_detected"] += 1
                    if self.quarantine(rid, "hang"):
                        acted += 1
            else:
                with self._lock:
                    st = self._quarantined.get(rid)
                if st is not None and now - st["since"] >= self.probation_s:
                    acted += self._probe(rid)
        return acted

    def quarantine(self, rid: str, reason: str) -> bool:
        """Evict ``rid`` from the ring and re-home its warm state to the
        survivors.  The replica object (and its possibly-hung thread)
        stays in the replica map for the later probe.  Returns ``False``
        when ``rid`` is already off the ring or is the last node."""
        sh = self._shard
        with sh._reb_lock:
            with sh._lock:
                rep = sh._replicas.get(rid)
                if rep is None or rid not in sh._ring:
                    return False
                if len(sh._ring) <= 1:
                    self.events.emit("quarantine_refused", rid=rid,
                                     reason=reason)
                    return False
                sh._ring.remove(rid)
            moved = sh._migrate([rep])
        with self._lock:
            self._quarantined[rid] = {"state": "quarantined",
                                      "since": self.clock(),
                                      "reason": reason}
            self.counters["quarantines"] += 1
        self.events.emit("replica_quarantined", rid=rid, reason=reason,
                         moved=moved)
        return True

    def _probe(self, rid: str) -> int:
        """Half-open: is the replica's serving thread responsive?  The
        probe is a no-op submitted to its pool — a still-hung worker
        can't run it before ``probe_timeout_s`` (real time: the hang
        itself, not the injected clock, holds the thread)."""
        sh = self._shard
        with sh._lock:
            rep = sh._replicas.get(rid)
        if rep is None:                      # removed while quarantined
            with self._lock:
                self._quarantined.pop(rid, None)
            return 0
        alive = True
        if rep.pool is not None:
            try:
                rep.pool.submit(lambda: True).result(
                    timeout=self.probe_timeout_s)
            except _FutTimeout:
                alive = False
            except RuntimeError:             # pool already shut down
                with self._lock:
                    self._quarantined.pop(rid, None)
                return 0
        if not alive:
            with self._lock:
                self.counters["failed_probes"] += 1
                st = self._quarantined.get(rid)
                if st is not None:
                    st["since"] = self.clock()   # probation restarts
            self.events.emit("replica_probe_failed", rid=rid)
            return 0
        return 1 if self.readmit(rid) else 0

    def readmit(self, rid: str) -> bool:
        """Close the breaker: put ``rid`` back on the ring and migrate
        its digests' warm state back (the ``add_replica`` path)."""
        sh = self._shard
        with sh._reb_lock:
            with sh._lock:
                rep = sh._replicas.get(rid)
                if rep is None or rid in sh._ring:
                    with self._lock:
                        self._quarantined.pop(rid, None)
                    return False
                sh._ring.add(rid)
                sources = [r for r in sh._replicas.values() if r.rid != rid]
            moved = sh._migrate(sources)
        with self._lock:
            self._quarantined.pop(rid, None)
            self.counters["readmissions"] += 1
        self.events.emit("replica_readmitted", rid=rid, moved=moved)
        return True

    # ----------------------------------------------------------- lifecycle

    def start(self) -> None:
        """Run ``poll_once`` every ``interval_s`` on a watchdog thread."""
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="replica-watchdog", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.poll_once()
            except Exception:
                pass

    def close(self) -> None:
        """Stop and join the watchdog thread (if running).  Idempotent."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None

    # ------------------------------------------------------- observability

    def snapshot(self) -> dict:
        """Per-replica supervisor state + heartbeat ages + counters —
        what the shard exposition's ``replica_*`` series render."""
        now = self.clock()
        sh = self._shard
        with sh._lock:
            reps = list(sh._replicas.items())
            on_ring = set(sh._ring.nodes())
        replicas = {}
        for rid, rep in reps:
            hb, busy = rep.heartbeat()
            with self._lock:
                st = self._quarantined.get(rid)
            replicas[rid] = {
                "state": "quarantined" if st is not None else "live",
                "reason": st["reason"] if st is not None else "",
                "on_ring": rid in on_ring,
                "heartbeat_age_ms": max(now - hb, 0.0) * 1e3,
                "busy_ms": max(now - busy, 0.0) * 1e3
                           if busy is not None else 0.0,
            }
        with self._lock:
            counters = dict(self.counters)
        return {"replicas": replicas, "counters": counters,
                "hang_timeout_s": self.hang_timeout_s,
                "probation_s": self.probation_s,
                "watchdog_running": self._thread is not None}


class ShardedEngine:
    """N ``SparseKernelEngine`` replicas behind a consistent-hash ring.

    Args:
        n_replicas: replicas to stand up at construction.
        engine_factory: ``(rid, device) -> SparseKernelEngine`` — build
            one replica (share a trained ``Autotuner`` across replicas
            here, give each its own ``KernelAutotuner`` cache).  Default
            builds ``SparseKernelEngine(**engine_kwargs)``.
        vnodes: virtual nodes per replica on the ring.
        max_inflight: shard-level bounded-load threshold — with a
            replica's in-flight depth (requests submitted to its serving
            thread and not yet returned, including this batch's prior
            assignments) at or past this, traffic overflows to the ring
            successor.  ``None`` (default) disables overflow.
        persist_path: warm-start merge source at construction and the
            default ``save()`` target.  Owned by the shard layer — pass
            replica persistence through ``engine_factory`` if you really
            want per-replica files.
        mesh: a ``jax`` Mesh (e.g. ``launch.mesh.make_host_mesh()``);
            replicas place round-robin over its
            ``parallel.sharding.replica_devices`` data slices.
        devices: explicit placement device list (overrides ``mesh``).
            Default: ``jax.devices()``.
        parallel: serve replicas on dedicated worker threads (default).
            ``False`` serves sub-batches inline, sequentially.
        step_timeout_s: per-sub-batch dispatch deadline — a replica
            future not done in time is abandoned (its load ends if the
            call ever returns), the replica quarantined, and the
            sub-batch re-dispatched through the survivors.  ``None``
            (default) waits forever, the pre-supervision behavior.
        hang_timeout_s / probation_s / watchdog_interval_s: the
            ``ReplicaSupervisor`` tunables (see its docstring).
        supervise: start the supervisor's watchdog thread.  ``False``
            (default) leaves the state machine to explicit
            ``supervisor.poll_once()`` calls — and to ``step()``'s own
            timeout/crash failover, which works either way.
        clock: monotonic clock shared by heartbeats and the supervisor
            (inject a fake for deterministic watchdog tests).
        engine_kwargs: forwarded to ``SparseKernelEngine`` by the default
            factory (``cache_size=...``, ``router=...``, ...).
    """

    def __init__(self, n_replicas: int = 2, *, engine_factory=None,
                 vnodes: int = 64, max_inflight: int | None = None,
                 persist_path: str | Path | None = None,
                 mesh=None, devices=None, parallel: bool = True,
                 step_timeout_s: float | None = None,
                 hang_timeout_s: float = 2.0, probation_s: float = 5.0,
                 watchdog_interval_s: float = 0.25, supervise: bool = False,
                 clock=time.monotonic, **engine_kwargs):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if "persist_path" in engine_kwargs:
            raise ValueError(
                "persist_path belongs to the shard layer (warm-start merge "
                "+ merged save); build per-replica persistence through "
                "engine_factory instead")
        if engine_factory is not None and engine_kwargs:
            raise ValueError("pass engine_kwargs only with the default "
                             "factory")
        self._factory = engine_factory or (
            lambda rid, device: SparseKernelEngine(**engine_kwargs))
        if devices is None:
            if mesh is not None:
                from repro.parallel.sharding import replica_devices
                devices = replica_devices(mesh)
            else:
                devices = jax.devices()
        self._devices = list(devices)
        self.max_inflight = max_inflight
        self.persist_path = Path(persist_path) if persist_path else None
        self._parallel = bool(parallel)
        self.step_timeout_s = step_timeout_s
        self._clock = clock
        self._closed = False
        self._lock = threading.Lock()       # ring + replica map + counters
        self._reb_lock = threading.Lock()   # serializes rebalances
        self._ring = HashRing(vnodes=vnodes)
        self._replicas: OrderedDict[str, _Replica] = OrderedDict()
        self._next_id = 0
        self._routed: dict[str, int] = {}
        self._counters = {"steps": 0, "requests": 0, "overflows": 0,
                          "rebalances": 0, "migrated_entries": 0,
                          "warm_start_entries": 0, "warm_start_skipped": 0,
                          "persist_saves": 0, "persist_saved_entries": 0,
                          "step_timeouts": 0, "replica_crashes": 0,
                          "redispatched": 0}
        # id(mat) -> (digest, weakref): the engine's identity memo, at the
        # shard layer — warm traffic pays the digest hash once, not once
        # per step per layer
        self._digest_memo: dict = {}
        for _ in range(n_replicas):
            rep = self._new_replica()
            self._replicas[rep.rid] = rep
            self._ring.add(rep.rid)
        if self.persist_path is not None:
            self._warm_start_merge()
        self.supervisor = ReplicaSupervisor(
            self, hang_timeout_s=hang_timeout_s, probation_s=probation_s,
            interval_s=watchdog_interval_s, clock=clock)
        if supervise:
            self.supervisor.start()

    # ------------------------------------------------------------ replicas

    def _new_replica(self, engine: SparseKernelEngine | None = None
                     ) -> _Replica:
        rid = f"r{self._next_id}"
        self._next_id += 1
        device = self._devices[len(self._replicas) % len(self._devices)] \
            if self._devices else None
        if engine is None:
            engine = self._factory(rid, device)
        return _Replica(rid, engine, device, self._parallel, self._clock)

    def engines(self) -> list[SparseKernelEngine]:
        """The live replica engines — the hook ``AdmissionQueue`` uses
        for SLO batch sizing (per-replica ``"step"`` histograms +
        ``BackendLoad`` depths)."""
        with self._lock:
            return [rep.engine for rep in self._replicas.values()]

    @property
    def replica_ids(self) -> list[str]:
        with self._lock:
            return list(self._replicas)

    def replica(self, rid: str) -> SparseKernelEngine:
        with self._lock:
            return self._replicas[rid].engine

    def owner_of(self, digest: str) -> str:
        """The replica id currently owning ``digest`` on the ring."""
        with self._lock:
            return self._ring.owner(digest)

    # ------------------------------------------------------------- serving

    def _digest(self, mat) -> str:
        from repro.core.autotune import matrix_digest
        memo = self._digest_memo
        key = id(mat)
        hit = memo.get(key)
        if hit is not None and hit[1]() is mat:
            return hit[0]
        dg = matrix_digest(mat)
        try:
            ref = weakref.ref(mat, lambda _r, _k=key: memo.pop(_k, None))
        except TypeError:
            return dg
        memo[key] = (dg, ref)
        return dg

    def step(self, requests: list) -> list:
        """Serve one micro-batch across the replicas; responses return in
        request order.  Assignment (ring owner + bounded-load overflow),
        load accounting, and sub-batch submission happen atomically under
        the shard lock, so a concurrent ``remove_replica`` can never strand
        a request: a replica leaves the ring *before* its queue is drained,
        and anything already queued still completes."""
        if not requests:
            return []
        digests = [self._digest(r.mat) for r in requests]
        with self._lock:
            if not self._replicas:
                raise RuntimeError("ShardedEngine has no replicas")
            groups: OrderedDict[str, list[int]] = OrderedDict()
            planned: dict[str, int] = {}
            for i, dg in enumerate(digests):
                rid = self._ring.owner(dg)
                if self.max_inflight is not None:
                    depth = (self._replicas[rid].load.inflight
                             + planned.get(rid, 0))
                    if depth >= self.max_inflight:
                        alt = self._ring.successor(dg)
                        if alt is not None and (
                                self._replicas[alt].load.inflight
                                + planned.get(alt, 0)) < self.max_inflight:
                            rid = alt
                            self._counters["overflows"] += 1
                        # both saturated: the owner serves it anyway —
                        # bounded load sheds to the successor, never drops
                planned[rid] = planned.get(rid, 0) + 1
                groups.setdefault(rid, []).append(i)
            dispatch = []
            for rid, idxs in groups.items():
                rep = self._replicas[rid]
                rep.load.begin(len(idxs))
                self._routed[rid] = self._routed.get(rid, 0) + len(idxs)
                sub = [requests[i] for i in idxs]
                fut = rep.submit(rep.engine.step, sub) \
                    if self._parallel else None
                dispatch.append((rep, idxs, sub, fut))
            self._counters["steps"] += 1
            self._counters["requests"] += len(requests)
        out: list = [None] * len(requests)
        err: BaseException | None = None
        for rep, idxs, sub, fut in dispatch:
            resp = None
            redo: tuple[str, BaseException] | None = None
            try:
                if fut is not None:
                    resp = fut.result(timeout=self.step_timeout_s)
                else:
                    resp = rep.run(rep.engine.step, sub)
                rep.load.end(len(idxs))
            except _FutTimeout as e:
                # the replica's serving thread is stuck mid-step: abandon
                # the future — its load ends if the call ever returns —
                # and fail over.  Responses a woken replica eventually
                # produces are discarded (the batch was re-served).
                fut.add_done_callback(
                    lambda _f, r=rep, n=len(idxs): r.load.end(n))
                redo = ("timeout", e)
            except ReplicaCrash as e:
                rep.load.end(len(idxs))
                redo = ("crash", e)
            except BaseException as e:      # noqa: BLE001 — re-raised below
                rep.load.end(len(idxs))
                if err is None:
                    err = e
            if redo is not None:
                reason, exc = redo
                with self._lock:
                    self._counters["step_timeouts"
                                   if reason == "timeout"
                                   else "replica_crashes"] += 1
                if self.supervisor.quarantine(rep.rid, reason):
                    try:
                        # re-route through the survivors: the ring no
                        # longer contains the quarantined replica, so the
                        # recursion terminates after at most n_replicas-1
                        # further quarantines
                        resp = self.step(sub)
                        with self._lock:
                            self._counters["redispatched"] += len(sub)
                    except BaseException as e:   # noqa: BLE001
                        resp = None
                        if err is None:
                            err = e
                elif err is None:
                    # last ring node: nowhere to fail over — surface the
                    # failure instead of re-dispatching into the same hang
                    err = TimeoutError(
                        f"replica {rep.rid} stuck past "
                        f"{self.step_timeout_s}s with no failover target"
                    ) if reason == "timeout" else exc
            if resp is not None:
                for k, i in enumerate(idxs):
                    out[i] = resp[k]
        if err is not None:
            raise err
        return out

    def drain(self) -> None:
        """Force completion of every live replica's in-flight work (each on
        its own serving thread, so the right stream's leases release).  A
        quarantined replica's serving thread may be hung mid-call, so it is
        skipped — draining it would block forever on its pool."""
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            if self.supervisor.state(rep.rid) != "live":
                continue
            rep.run(rep.engine.drain)

    def close(self, save: bool | None = None) -> None:
        """Graceful shutdown: watchdog joined, responsive replicas
        drained on their own serving threads, merged warm state saved,
        serving threads joined.  Idempotent; also the context-manager
        exit.

        ``save=None`` (default) saves iff a ``persist_path`` is
        configured; ``True``/``False`` force it.  A replica the
        supervisor holds in quarantine — its thread may be hung — is
        shut down without waiting, so ``close()`` never blocks on a dead
        thread; its warm state already moved to the survivors at
        quarantine time and is in the save."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            reps = list(self._replicas.values())
        self.supervisor.close()
        quarantined = {rid for rid, r in
                       self.supervisor.snapshot()["replicas"].items()
                       if r["state"] != "live"}
        for rep in reps:
            if rep.rid in quarantined:
                continue
            try:
                rep.run(rep.engine.drain)
            except Exception:
                pass
        do_save = (self.persist_path is not None) if save is None else save
        if do_save and self.persist_path is not None:
            try:
                self.save()
            except Exception:
                pass
        for rep in reps:
            if rep.pool is not None:
                hung = rep.rid in quarantined
                rep.pool.shutdown(wait=not hung, cancel_futures=hung)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ----------------------------------------------------------- rebalance

    def add_replica(self, engine: SparseKernelEngine | None = None) -> str:
        """Stand up one more replica and re-home *only* the digests whose
        ring ownership moved to it (their cache rows migrate warm, dest
        arenas prebuilt).  Serving continues throughout; a moved digest
        served mid-migration is a cold miss on the new owner, never an
        error.  Returns the new replica id."""
        with self._reb_lock:
            rep = self._new_replica(engine)
            with self._lock:
                self._replicas[rep.rid] = rep
                self._ring.add(rep.rid)
                sources = [r for r in self._replicas.values()
                           if r.rid != rep.rid]
                self._counters["rebalances"] += 1
            self._migrate(sources)
            return rep.rid

    def remove_replica(self, rid: str) -> int:
        """Quiesce and retire one replica: it leaves the ring (no new
        assignments), its queued work drains (zero lost requests), every
        cache row it owned migrates to the digests' new ring owners, and
        its serving thread shuts down.  Returns the number of migrated
        entries."""
        with self._reb_lock:
            with self._lock:
                rep = self._replicas.get(rid)
                if rep is None:
                    raise KeyError(f"no replica {rid!r}")
                if len(self._replicas) <= 1:
                    raise ValueError("cannot remove the last replica")
                self._ring.remove(rid)
                self._counters["rebalances"] += 1
            # anything assigned before the ring exit was already submitted
            # (assignment+submit are atomic under the lock) — drain it
            rep.run(rep.engine.drain)
            if rep.pool is not None:
                rep.pool.shutdown(wait=True)
            moved = self._migrate([rep])
            with self._lock:
                del self._replicas[rid]
            return moved

    def _migrate(self, sources: list[_Replica]) -> int:
        """Re-home every source cache row whose digest's ring owner is no
        longer the source, via a ``save_backends``/``load_grouped`` round
        trip — the same validated namespace view the warm-start path
        trusts, so a migration can never install an entry a cold load
        would have rejected.  Runs under ``_reb_lock``; the ring is stable
        while it works."""
        moved = 0
        tmpdir = None
        try:
            for src in sources:
                if not any(len(c) for caches in
                           src.engine.backends.caches_by_platform().values()
                           for c in caches):
                    continue
                if tmpdir is None:
                    tmpdir = Path(tempfile.mkdtemp(prefix="shard_migrate_"))
                tmp = tmpdir / f"{src.rid}.npz"
                # engine.save counts persist_saves/persist_saved_entries on
                # the source — migrations are observable in its stats()
                src.engine.save(tmp)
                loaded = load_grouped(tmp)
                if loaded is None:
                    continue
                with self._lock:
                    owner = {dg: self._ring.owner(dg)
                             for tag, items in loaded.entries.items()
                             for (_op, dg), _e in items}
                    reps = dict(self._replicas)
                for tag, items in loaded.entries.items():
                    for (op, dg), entry in items:
                        if owner[dg] == src.rid:
                            continue
                        dest = reps.get(owner[dg])
                        if dest is None:
                            continue
                        platform = src.engine.default_platform \
                            if tag is LEGACY_NAMESPACE else tag
                        if (platform, op) not in dest.engine.backends:
                            continue
                        be = dest.engine.backends.get(platform, op)
                        be.tuner.cache.put((op, dg), entry)
                        # prebuild the dest arena so the first post-
                        # rebalance request scatters into a live slot
                        dest.engine._arena_for((platform, op, dg), entry)
                        src_be = src.engine.backends.get(platform, op)
                        src_be.tuner.cache.pop((op, dg))
                        moved += 1
        finally:
            if tmpdir is not None:
                shutil.rmtree(tmpdir, ignore_errors=True)
        with self._lock:
            self._counters["migrated_entries"] += moved
        return moved

    # --------------------------------------------------------- persistence

    def _warm_start_merge(self) -> None:
        """Restore one cache file and route every entry to its ring owner
        — N replicas warm-start from a single file written by any previous
        layout (one engine, or a different replica count)."""
        loaded = load_grouped(self.persist_path, quarantine=True)
        if loaded is None:
            return
        restored = skipped = 0
        for tag, items in loaded.entries.items():
            for (op, dg), entry in items:
                rep = self._replicas[self._ring.owner(dg)]
                eng = rep.engine
                platform = eng.default_platform \
                    if tag is LEGACY_NAMESPACE else tag
                if (platform, op) in eng.backends:
                    eng.backends.get(platform, op).tuner.cache.put(
                        (op, dg), entry)
                    eng._arena_for((platform, op, dg), entry)
                    eng.telemetry.count(warm_start_entries=1)
                    restored += 1
                else:
                    skipped += 1
        with self._lock:
            self._counters["warm_start_entries"] += restored
            self._counters["warm_start_skipped"] += skipped + loaded.skipped

    def save(self, path: str | Path | None = None) -> Path:
        """Merge every replica's caches into one namespaced file (digest-
        deduped per platform, atomically committed) — the cross-replica
        warm-start artifact a future layout re-splits by ring ownership."""
        target = Path(path) if path is not None else self.persist_path
        if target is None:
            raise ValueError("no persist_path configured and none given")
        merged: dict[str, _MergedEntries] = {}
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            for plat, caches in \
                    rep.engine.backends.caches_by_platform().items():
                view = merged.setdefault(plat, _MergedEntries())
                for cache in caches:
                    for key, entry in cache.items():
                        view.put(key, entry)
        out = save_backends({plat: [view] for plat, view in merged.items()},
                            target)
        total = sum(len(v) for v in merged.values())
        with self._lock:
            self._counters["persist_saves"] += 1
            self._counters["persist_saved_entries"] += total
        return out

    # ------------------------------------------------------- observability

    @property
    def featurize_calls(self) -> int:
        with self._lock:
            reps = list(self._replicas.values())
        return sum(rep.engine.featurize_calls for rep in reps)

    def stats(self) -> dict:
        """Aggregate counters across replicas plus the shard router's own
        accounting.  ``"aggregate"`` sums the fleet; ``"routing"`` is the
        shard layer (per-shard request shares, bounded-load overflows,
        rebalances, migrated/warm-started entries, merged saves);
        ``"by_shard"`` holds each replica's full ``stats()`` snapshot."""
        with self._lock:
            reps = list(self._replicas.items())
            ring_nodes = self._ring.nodes()
            vnodes = self._ring.vnodes
            counters = dict(self._counters)
            routed = dict(self._routed)
            loads = {rid: rep.load.snapshot() for rid, rep in reps}
            devices = {rid: str(rep.device) for rid, rep in reps}
        per = {rid: rep.engine.stats() for rid, rep in reps}
        agg = {
            "requests": sum(s["requests"] for s in per.values()),
            "batches": sum(s["batches"] for s in per.values()),
            "hits": sum(s["hits"] for s in per.values()),
            "misses": sum(s["misses"] for s in per.values()),
            "featurize_calls": sum(s["featurize_calls"]
                                   for s in per.values()),
            "failovers": sum(s["health"]["failovers"] for s in per.values()),
            "execute_failures": sum(s["health"]["execute_failures"]
                                    for s in per.values()),
            "warm_start_entries": sum(s["warm_start_entries"]
                                      for s in per.values()),
            "persist_saves": sum(s["persist_saves"] for s in per.values()),
            "persist_saved_entries": sum(s["persist_saved_entries"]
                                         for s in per.values()),
            "cache_size": sum(c["size"] for s in per.values()
                              for c in s["caches"].values()),
            "cache_capacity": sum(c["maxsize"] for s in per.values()
                                  for c in s["caches"].values()),
        }
        served = agg["hits"] + agg["misses"]
        agg["hit_rate"] = agg["hits"] / served if served else 0.0
        return {
            "replicas": len(per),
            "ring": {"nodes": ring_nodes, "vnodes": vnodes},
            "routing": {
                "by_shard": routed,
                "steps": counters["steps"],
                "requests": counters["requests"],
                "overflows": counters["overflows"],
                "rebalances": counters["rebalances"],
                "migrated_entries": counters["migrated_entries"],
                "warm_start_entries": counters["warm_start_entries"],
                "warm_start_skipped": counters["warm_start_skipped"],
                "merged_saves": counters["persist_saves"],
                "merged_saved_entries": counters["persist_saved_entries"],
                "max_inflight": self.max_inflight,
                "step_timeouts": counters["step_timeouts"],
                "replica_crashes": counters["replica_crashes"],
                "redispatched": counters["redispatched"],
            },
            "load": loads,
            "devices": devices,
            "aggregate": agg,
            "by_shard": per,
            "supervisor": self.supervisor.snapshot(),
            "ts": time.monotonic(),
        }

    def prometheus_text(self, namespace: str = "repro_serving") -> str:
        """One exposition for the whole fleet: every replica's full
        ``export.prometheus_text`` with ``shard="<rid>"`` stamped on every
        series, followed by the shard router's own series.  Parses with
        ``parse_prometheus_text`` (duplicate HELP/TYPE headers across
        replica sections are comments to the parser)."""
        with self._lock:
            reps = list(self._replicas.items())
        parts = [prometheus_text(rep.engine, namespace,
                                 labels={"shard": rid})
                 for rid, rep in reps]
        s = self.stats()
        w = _Writer(namespace)
        w.scalar("shard_replicas", "gauge", "live engine replicas",
                 s["replicas"])
        full = w.head("shard_routed_requests_total", "counter",
                      "requests routed per shard")
        for rid, n in sorted(s["routing"]["by_shard"].items()):
            w.sample(full, n, {"shard": rid})
        full = w.head("shard_inflight", "gauge",
                      "shard-level in-flight depth")
        for rid, load in sorted(s["load"].items()):
            w.sample(full, load["inflight"], {"shard": rid})
        for name, help_ in (("overflows", "bounded-load overflow re-routes"),
                            ("rebalances", "replica add/remove events"),
                            ("migrated_entries",
                             "cache rows re-homed by rebalances"),
                            ("warm_start_entries",
                             "entries restored by the warm-start merge"),
                            ("step_timeouts",
                             "sub-batch dispatches abandoned on timeout"),
                            ("replica_crashes",
                             "serving-thread crashes seen by dispatch"),
                            ("redispatched",
                             "requests re-served through failover")):
            w.scalar(f"shard_{name}_total", "counter", help_,
                     s["routing"][name])
        w.scalar("shard_aggregate_hit_rate", "gauge",
                 "fleet-wide lifetime cache hit rate",
                 s["aggregate"]["hit_rate"])
        sup = s["supervisor"]
        hb = w.head("replica_heartbeat_age_ms", "gauge",
                    "ms since the replica's serving thread last stamped "
                    "its heartbeat")
        st_full = w.head("replica_state", "gauge",
                         "supervisor state one-hot per replica")
        for rid, r in sorted(sup["replicas"].items()):
            w.sample(hb, r["heartbeat_age_ms"], {"shard": rid})
            for state in ("live", "quarantined"):
                w.sample(st_full, int(r["state"] == state),
                         {"shard": rid, "state": state})
        for name, help_ in (("hangs_detected", "hung serving threads seen"),
                            ("quarantines", "replicas quarantined"),
                            ("failed_probes", "probation probes that hung"),
                            ("readmissions",
                             "replicas re-admitted after probation")):
            w.scalar(f"shard_replica_{name}_total", "counter", help_,
                     sup["counters"][name])
        parts.append(w.text())
        return "".join(parts)
