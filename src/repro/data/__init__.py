from repro.data.matrices import SparseMatrix, generate_matrix, generate_suite, FAMILIES
from repro.data.features import density_pyramid, matrix_stats, STAT_NAMES
from repro.data.dataset import CostDataset, collect_dataset, split_suite, CostMeter
