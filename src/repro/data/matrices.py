"""Synthetic SuiteSparse-like sparse matrix generation.

The paper draws inputs from the SuiteSparse Matrix Collection (Davis & Hu,
2011), which spans circuit, FEM/mesh, graph, optimization, and statistical
matrices.  Offline we synthesize structurally analogous families so that the
learning problem (sparsity pattern -> best program configuration) retains the
same diversity of row-length skew, bandedness, and block structure that makes
configuration selection input-sensitive.

Matrices are COO with deduplicated, sorted coordinates.  Generation is pure
numpy (fast on one core) and fully determined by (family, size, seed).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SparseMatrix", "generate_matrix", "generate_suite", "FAMILIES"]


@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """A COO sparse pattern. Values are implicit (pattern matters, not values)."""
    name: str
    family: str
    n_rows: int
    n_cols: int
    rows: np.ndarray  # int32 [nnz], sorted row-major
    cols: np.ndarray  # int32 [nnz]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.nnz / float(self.n_rows * self.n_cols)

    def row_counts(self) -> np.ndarray:
        return np.bincount(self.rows, minlength=self.n_rows)

    def col_counts(self) -> np.ndarray:
        return np.bincount(self.cols, minlength=self.n_cols)

    def to_csr_indptr(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.row_counts())]).astype(np.int64)

    def to_dense(self, dtype=np.float32, values: np.ndarray | None = None):
        d = np.zeros((self.n_rows, self.n_cols), dtype=dtype)
        d[self.rows, self.cols] = 1.0 if values is None else values
        return d


def _dedup(n_rows, n_cols, rows, cols):
    rows = np.clip(rows, 0, n_rows - 1).astype(np.int64)
    cols = np.clip(cols, 0, n_cols - 1).astype(np.int64)
    key = rows * n_cols + cols
    key = np.unique(key)
    return (key // n_cols).astype(np.int32), (key % n_cols).astype(np.int32)


def _finalize(name, family, n_rows, n_cols, rows, cols) -> SparseMatrix:
    rows, cols = _dedup(n_rows, n_cols, rows, cols)
    if rows.size == 0:  # degenerate fallback: main diagonal
        d = np.arange(min(n_rows, n_cols), dtype=np.int32)
        rows, cols = d, d
    return SparseMatrix(name, family, n_rows, n_cols, rows, cols)


# ---------------------------------------------------------------- families

def _uniform(rng, n, m, target_nnz):
    rows = rng.integers(0, n, target_nnz)
    cols = rng.integers(0, m, target_nnz)
    return rows, cols


def _powerlaw(rng, n, m, target_nnz):
    """Scale-free graph style: row degrees ~ Zipf (web/social graphs)."""
    alpha = rng.uniform(1.6, 2.6)
    deg = rng.zipf(alpha, n).astype(np.int64)
    deg = np.minimum(deg, m // 2 + 1)
    deg = (deg * (target_nnz / max(deg.sum(), 1))).astype(np.int64)
    deg = np.maximum(deg, 1)
    rows = np.repeat(np.arange(n), deg)
    # preferential attachment on columns too
    col_w = rng.zipf(alpha, m).astype(np.float64)
    col_p = col_w / col_w.sum()
    cols = rng.choice(m, size=rows.size, p=col_p)
    return rows, cols


def _banded(rng, n, m, target_nnz):
    """FEM / finite-difference style banded matrices."""
    half_bw = max(1, int(target_nnz / (2 * n)) + rng.integers(0, 4))
    rows = np.repeat(np.arange(n), 2 * half_bw + 1)
    offs = np.tile(np.arange(-half_bw, half_bw + 1), n)
    cols = (rows * m // n) + offs
    keep = (cols >= 0) & (cols < m)
    # random dropout to break perfect structure
    keep &= rng.random(rows.size) > 0.15
    return rows[keep], cols[keep]


def _block_diag(rng, n, m, target_nnz):
    """Block-diagonal (circuit / multi-body) with dense-ish blocks."""
    bs = int(rng.choice([8, 16, 32, 64]))
    nb = max(1, min(n, m) // bs)
    density = min(1.0, target_nnz / (nb * bs * bs))
    rows_l, cols_l = [], []
    for b in range(nb):
        cnt = rng.binomial(bs * bs, density)
        if cnt == 0:
            continue
        rows_l.append(rng.integers(0, bs, cnt) + b * bs)
        cols_l.append(rng.integers(0, bs, cnt) + b * bs)
    if not rows_l:
        return np.array([], np.int64), np.array([], np.int64)
    return np.concatenate(rows_l), np.concatenate(cols_l)


def _rmat(rng, n, m, target_nnz):
    """R-MAT / Kronecker-style recursive graph (power-law + community)."""
    a, b, c = 0.57, 0.19, 0.19
    levels_r = int(np.ceil(np.log2(max(n, 2))))
    levels_c = int(np.ceil(np.log2(max(m, 2))))
    levels = max(levels_r, levels_c)
    k = target_nnz
    rows = np.zeros(k, np.int64)
    cols = np.zeros(k, np.int64)
    for _ in range(levels):
        r = rng.random(k)
        quad_b = (r >= a) & (r < a + b)
        quad_c = (r >= a + b) & (r < a + b + c)
        quad_d = r >= a + b + c
        rows = rows * 2 + (quad_c | quad_d)
        cols = cols * 2 + (quad_b | quad_d)
    return rows % n, cols % m


def _clustered(rng, n, m, target_nnz):
    """Row-clustered: dense row blocks + sparse background (stat/ML)."""
    n_clusters = int(rng.integers(2, 8))
    rows_l, cols_l = [], []
    per = target_nnz // (n_clusters + 1)
    for _ in range(n_clusters):
        r0 = rng.integers(0, max(1, n - n // 8))
        c0 = rng.integers(0, max(1, m - m // 8))
        h, w = max(1, n // 8), max(1, m // 8)
        rows_l.append(rng.integers(r0, r0 + h, per))
        cols_l.append(rng.integers(c0, c0 + w, per))
    rows_l.append(rng.integers(0, n, per))
    cols_l.append(rng.integers(0, m, per))
    return np.concatenate(rows_l), np.concatenate(cols_l)


def _mesh2d(rng, n, m, target_nnz):
    """5-point stencil on a 2D grid (PDE discretizations)."""
    side = int(np.sqrt(min(n, m)))
    side = max(side, 2)
    idx = np.arange(side * side)
    x, y = idx % side, idx // side
    nbrs = [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)]
    rows_l, cols_l = [], []
    for dx, dy in nbrs:
        nx, ny = x + dx, y + dy
        keep = (nx >= 0) & (nx < side) & (ny >= 0) & (ny < side)
        rows_l.append(idx[keep])
        cols_l.append((ny * side + nx)[keep])
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    return rows % n, cols % m


def _arrow(rng, n, m, target_nnz):
    """Arrowhead / bordered-diagonal (optimization KKT systems)."""
    d = np.arange(min(n, m))
    border = max(1, min(n, m) // 64)
    b_rows = np.repeat(np.arange(border), m // 2)
    b_cols = rng.integers(0, m, b_rows.size)
    b2_cols = np.repeat(np.arange(border), n // 2)
    b2_rows = rng.integers(0, n, b2_cols.size)
    rows = np.concatenate([d, b_rows, b2_rows])
    cols = np.concatenate([d, b_cols, b2_cols])
    return rows, cols


FAMILIES = {
    "uniform": _uniform,
    "powerlaw": _powerlaw,
    "banded": _banded,
    "blockdiag": _block_diag,
    "rmat": _rmat,
    "clustered": _clustered,
    "mesh2d": _mesh2d,
    "arrow": _arrow,
}


def generate_matrix(family: str, seed: int, n_rows: int | None = None,
                    n_cols: int | None = None, target_nnz: int | None = None,
                    size_range=(256, 16384)) -> SparseMatrix:
    rng = np.random.default_rng(seed)
    if n_rows is None:
        lo, hi = np.log2(size_range[0]), np.log2(size_range[1])
        n_rows = int(2 ** rng.uniform(lo, hi))
    if n_cols is None:
        n_cols = n_rows if rng.random() < 0.7 else int(n_rows * 2 ** rng.uniform(-1, 1))
        n_cols = max(64, n_cols)
    if target_nnz is None:
        avg_deg = 2 ** rng.uniform(1.5, 6.0)  # 3..64 nnz per row on average
        target_nnz = int(min(n_rows * avg_deg, n_rows * n_cols * 0.25))
    rows, cols = FAMILIES[family](rng, n_rows, n_cols, max(target_nnz, 8))
    return _finalize(f"{family}_{seed}", family, n_rows, n_cols, rows, cols)


def generate_suite(n_matrices: int, seed: int = 0,
                   size_range=(256, 16384)) -> list[SparseMatrix]:
    """A balanced suite across families and log-size bins (paper §4.1 binning)."""
    fams = list(FAMILIES)
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_matrices):
        fam = fams[i % len(fams)]
        out.append(generate_matrix(fam, int(rng.integers(0, 2**31)) + i,
                                   size_range=size_range))
    return out
