"""Label-collection pipeline and dataset containers (paper §4.1, App. A).

``collect_dataset`` runs a platform's runtime model over sampled program
configurations for each matrix and meters the data-collection cost
(DCE = beta_platform * |D|), reproducing the paper's asymmetric label economy
(CPU samples cost 1 unit; SPADE simulator samples cost 1000).

A ``CostDataset`` keeps per-matrix featurizations (density pyramid + config
feature views) plus flat (matrix_idx, config_idx, runtime) samples, ready for
the pairwise-ranking trainer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from typing import TYPE_CHECKING

from repro.data.features import density_pyramid, matrix_stats
from repro.data.matrices import SparseMatrix, generate_suite

if TYPE_CHECKING:  # avoid circular import (hw.platforms uses data.features)
    from repro.hw.platforms import Platform

__all__ = ["CostMeter", "CostDataset", "collect_dataset", "split_suite"]


@dataclasses.dataclass
class CostMeter:
    """Tracks the paper's Data Collection Expense objective."""
    units: float = 0.0

    def charge(self, platform: "Platform", n_samples: int):
        self.units += platform.beta * n_samples

    @property
    def dce_millions(self) -> float:
        return self.units / 1e6


@dataclasses.dataclass
class CostDataset:
    platform: str
    op: str
    pyramids: np.ndarray        # (n_matrices, C, R, R) float32
    homog: np.ndarray           # (n_matrices, n_space_configs, 53) float32
    het: np.ndarray             # (n_space_configs, het_dim) float32
    stats: np.ndarray           # (n_matrices, n_stats)
    runtimes_full: np.ndarray   # (n_matrices, n_space_configs) float32, ms
    sample_matrix: np.ndarray   # (n_samples,) int32 — observed label subset
    sample_config: np.ndarray   # (n_samples,) int32
    matrix_names: list[str]
    default_index: int

    @property
    def n_matrices(self) -> int:
        return self.pyramids.shape[0]

    @property
    def n_samples(self) -> int:
        return int(self.sample_matrix.shape[0])

    def sample_runtime(self) -> np.ndarray:
        return self.runtimes_full[self.sample_matrix, self.sample_config]

    def observed_mask(self) -> np.ndarray:
        m = np.zeros(self.runtimes_full.shape, bool)
        m[self.sample_matrix, self.sample_config] = True
        return m

    def subset_matrices(self, idx) -> "CostDataset":
        idx = np.asarray(idx)
        remap = -np.ones(self.n_matrices, np.int64)
        remap[idx] = np.arange(idx.size)
        keep = np.isin(self.sample_matrix, idx)
        return CostDataset(
            self.platform, self.op, self.pyramids[idx], self.homog[idx],
            self.het, self.stats[idx], self.runtimes_full[idx],
            remap[self.sample_matrix[keep]].astype(np.int32),
            self.sample_config[keep], [self.matrix_names[i] for i in idx],
            self.default_index)


def collect_dataset(platform: "Platform", matrices: list[SparseMatrix], op: str,
                    n_configs_per_matrix: int, seed: int = 0,
                    resolution: int = 64, meter: CostMeter | None = None,
                    full_labels: bool = True) -> CostDataset:
    """Evaluate sampled configurations of each matrix on ``platform``.

    ``runtimes_full`` holds the exhaustive ground truth (used only for the
    oracle/optimal speedup evaluation, as the paper does for its 'optimal'
    line); the *observed* training samples are the random subset recorded in
    ``sample_matrix``/``sample_config`` and only those are charged to the
    cost meter.
    """
    rng = np.random.default_rng(seed)
    space = platform.space
    n_cfg = space.n_configs
    n_configs_per_matrix = min(n_configs_per_matrix, n_cfg)

    pyramids, homogs, stats_l, full_l = [], [], [], []
    sm, sc = [], []
    for mi, mat in enumerate(matrices):
        st = matrix_stats(mat)
        pyramids.append(density_pyramid(mat, resolution))
        homogs.append(space.homogeneous(mat.n_cols))
        stats_l.append(st)
        rt = platform.runtime(st, op, matrix_key=hash(mat.name) & 0xFFFF,
                              n_cols=mat.n_cols)
        full_l.append(rt.astype(np.float32))
        cfg_idx = rng.choice(n_cfg, size=n_configs_per_matrix, replace=False)
        sm.append(np.full(n_configs_per_matrix, mi, np.int32))
        sc.append(cfg_idx.astype(np.int32))
        if meter is not None:
            meter.charge(platform, n_configs_per_matrix)

    return CostDataset(
        platform.name, op,
        np.stack(pyramids), np.stack(homogs).astype(np.float32),
        space.heterogeneous().astype(np.float32),
        np.stack(stats_l), np.stack(full_l),
        np.concatenate(sm), np.concatenate(sc),
        [m.name for m in matrices], space.default_index)


def split_suite(n_train: int, n_eval: int, seed: int = 0,
                size_range=(256, 16384)):
    """Disjoint train/eval matrix suites (paper: 1,500 total, 715 eval)."""
    suite = generate_suite(n_train + n_eval, seed=seed, size_range=size_range)
    return suite[:n_train], suite[n_train:]
