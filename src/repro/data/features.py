"""Featurization of sparsity patterns.

Two representations:

1. ``density_pyramid`` — the fixed-resolution log-density grid consumed by the
   CNN input featurizer (TPU-native replacement for WACO's 256x256 submanifold
   point cloud, see DESIGN.md §4). Channels: [log1p density, binary presence,
   row-marginal, col-marginal].

2. ``matrix_stats`` — a vector of structural summary statistics consumed by the
   analytical platform models in ``repro/hw/platforms.py`` (tile-reuse proxies
   at several block sizes, row-length skew, bandedness).
"""
from __future__ import annotations

import numpy as np

from repro.data.matrices import SparseMatrix

__all__ = ["density_pyramid", "matrix_stats", "STAT_NAMES", "PYRAMID_CHANNELS"]

PYRAMID_CHANNELS = 4


def density_pyramid(mat: SparseMatrix, resolution: int = 64) -> np.ndarray:
    """Return (C=4, R, R) float32 canonical grid for any matrix size.

    Every matrix is stretched onto an RxR grid; cell value is the nnz count in
    that bucket. This is the dense analogue of WACO's coordinate downsampling.
    """
    R = resolution
    gr = (mat.rows.astype(np.int64) * R) // max(mat.n_rows, 1)
    gc = (mat.cols.astype(np.int64) * R) // max(mat.n_cols, 1)
    flat = gr * R + gc
    counts = np.bincount(flat, minlength=R * R).astype(np.float32).reshape(R, R)
    # normalize: cell capacity differs with matrix size; use log scale
    cap = (mat.n_rows / R) * (mat.n_cols / R)
    density = np.log1p(counts) / np.log1p(max(cap, 2.0))
    presence = (counts > 0).astype(np.float32)
    row_marg = presence.mean(axis=1, keepdims=True) * np.ones((1, R), np.float32)
    col_marg = presence.mean(axis=0, keepdims=True) * np.ones((R, 1), np.float32)
    return np.stack([density, presence, row_marg, col_marg]).astype(np.float32)


STAT_NAMES = [
    "log_rows", "log_cols", "log_nnz", "log_density",
    "row_mean", "row_cv", "row_max_ratio",
    "col_cv", "bandwidth", "diag_frac",
    "block8_fill", "block32_fill", "block128_fill",
    "seg_locality",
]


def _block_fill(mat: SparseMatrix, bs: int) -> float:
    """Fraction of touched (bs x bs) blocks that are touched — reuse proxy.

    Returns mean nnz per non-empty block normalized by bs (higher => more
    spatial clustering => more dense-operand reuse per tile).
    """
    br = mat.rows.astype(np.int64) // bs
    bc = mat.cols.astype(np.int64) // bs
    nb_cols = (mat.n_cols + bs - 1) // bs
    key = br * nb_cols + bc
    uniq, cnt = np.unique(key, return_counts=True)
    if uniq.size == 0:
        return 0.0
    return float(cnt.mean()) / float(bs)


def _block_fills_8_32_128(mat: SparseMatrix) -> tuple[float, float, float]:
    """All three fill stats from ONE sort instead of three unique() passes.

    8/32/128 blocks nest on an aligned grid (32 = 4x8, 128 = 4x32), so a
    hierarchical key — (128-block id, 32-sub-block, 8-sub-block) packed into
    an int64 — groups every level contiguously after a single sort.  The
    number of distinct blocks at level ``bs`` is then the number of runs of
    the key prefix that drops the finer-level bits.  Values are bit-identical
    to per-level ``_block_fill`` (mean count = nnz / n_unique exactly).
    """
    nnz = mat.nnz
    if nnz == 0:
        return 0.0, 0.0, 0.0
    r = mat.rows.astype(np.int64)
    c = mat.cols.astype(np.int64)
    nbc128 = (mat.n_cols + 127) // 128
    key = (r // 128) * nbc128 + (c // 128)
    key = (key << 4) | (((r >> 5) & 3) << 2) | ((c >> 5) & 3)   # 32-sub-block
    key = (key << 4) | (((r >> 3) & 3) << 2) | ((c >> 3) & 3)   # 8-sub-block
    key.sort()
    diff = key[1:] != key[:-1]
    n8 = 1 + int(np.count_nonzero(diff))
    k32 = key >> 4
    n32 = 1 + int(np.count_nonzero(k32[1:] != k32[:-1]))
    k128 = key >> 8
    n128 = 1 + int(np.count_nonzero(k128[1:] != k128[:-1]))
    return nnz / n8 / 8.0, nnz / n32 / 32.0, nnz / n128 / 128.0


def matrix_stats(mat: SparseMatrix) -> np.ndarray:
    """(len(STAT_NAMES),) float64 structural summary used by hw models."""
    rc = mat.row_counts().astype(np.float64)
    cc = mat.col_counts().astype(np.float64)
    rmean = rc.mean() if rc.size else 0.0
    rstd = rc.std() if rc.size else 0.0
    row_cv = rstd / max(rmean, 1e-9)
    row_max_ratio = rc.max() / max(rmean, 1e-9) if rc.size else 0.0
    cmean = cc.mean() if cc.size else 0.0
    col_cv = (cc.std() / max(cmean, 1e-9)) if cc.size else 0.0
    # normalized mean distance from the (stretched) diagonal
    diag_col = mat.rows.astype(np.float64) * (mat.n_cols / max(mat.n_rows, 1))
    band = np.abs(mat.cols.astype(np.float64) - diag_col)
    bandwidth = float(band.mean()) / max(mat.n_cols, 1)
    diag_frac = float((band < max(mat.n_cols, 1) * 0.01).mean())
    # locality: mean column gap between consecutive nnz within a row (sorted COO)
    same_row = mat.rows[1:] == mat.rows[:-1]
    if same_row.any():
        gaps = (mat.cols[1:].astype(np.float64) - mat.cols[:-1])[same_row]
        seg_locality = float(np.clip(np.abs(gaps), 0, None).mean()) / max(mat.n_cols, 1)
    else:
        seg_locality = 1.0
    fill8, fill32, fill128 = _block_fills_8_32_128(mat)
    vals = [
        np.log2(mat.n_rows), np.log2(mat.n_cols), np.log2(max(mat.nnz, 1)),
        np.log2(max(mat.density, 1e-12)),
        rmean, row_cv, row_max_ratio,
        col_cv, bandwidth, diag_frac,
        fill8, fill32, fill128,
        seg_locality,
    ]
    return np.asarray(vals, dtype=np.float64)
