"""Step builders: training (grad + AdamW + optional accumulation + remat) and
serving (prefill / cached decode). These are the functions the dry-run lowers
and the launcher jits — sharding is supplied by the caller via in_shardings.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import settings
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: AdamWConfig = AdamWConfig(lr=3e-4, weight_decay=0.1)
    remat: bool = True
    accum_steps: int = 1          # gradient accumulation microbatches
    warmup_steps: int = 100
    total_steps: int = 10000


def make_train_step(model, cfg: TrainStepConfig = TrainStepConfig()):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With accum_steps > 1 the batch's leading dim is split into microbatches
    scanned sequentially — same global batch, 1/accum activation memory (the
    standard throughput/memory trade at scale).
    """
    sched = warmup_cosine(cfg.warmup_steps, cfg.total_steps)

    def loss_fn(params, batch):
        with settings.remat(cfg.remat):
            loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if cfg.accum_steps > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            split = lambda x: x.reshape((cfg.accum_steps,
                                         x.shape[0] // cfg.accum_steps)
                                        + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / cfg.accum_steps, grads)
            loss = loss / cfg.accum_steps
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr_scale = sched(opt_state["step"] + 1)   # step is 0-based pre-update
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             cfg.optimizer, lr_scale=lr_scale)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def init_optimizer(params, cfg: TrainStepConfig = TrainStepConfig()):
    return adamw_init(params, cfg.optimizer)


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens)
    return serve_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        with settings.remat(False):
            return model.prefill_step(params, batch)
    return prefill_step
